"""Serve a small model with batched requests through the
continuous-batching engine (deliverable b, serving flavor).

Each request prefills (filling KV + hash-code caches), then all active
slots decode together with HATA top-k attention. Prints per-request
TTFT/latency and engine throughput — first on the dense slab engine,
then on the paged scheduler (``--paged``: page pools + block tables
addressed through the ``core.cache_view`` view API; same model entry
points, chunked prefill + prefix sharing on top).

Run:  PYTHONPATH=src python examples/serve_longcontext.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    common = ["--arch", "qwen1.5-0.5b", "--requests", "8",
              "--max-batch", "4", "--max-len", "192", "--prompt-len",
              "64", "--new-tokens", "24"]
    main(common)
    main(common + ["--paged"])
