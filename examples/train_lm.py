"""End-to-end driver (deliverable b): train a ~20M-param LM for a few
hundred steps on the synthetic induction task, then hash-train HATA
weights on the model's own q/k (paper §3.1 + App. B) and report
selection recall vs random-projection LSH.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
A full ~100M-param run: --d-model 512 --layers 8 --steps 500 (slower).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.launch.hash_train import train_layer_hash
from repro.launch.train import main as train_main
from repro.data.synthetic import SyntheticLM
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # 1) pretrain
    losses = train_main([
        "--arch", "llama3.1-8b", "--reduced",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--ckpt-dir", args.ckpt,
        "--log-every", "25"])
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # 2) hash-train on the trained model's q/k and measure recall
    cfg = get_reduced("llama3.1-8b",
                      d_model=args.d_model, n_layers=args.layers)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, 96, 1, seed=5)
    batches = [{"tokens": jnp.asarray(src.batch_at(i))}
               for i in range(3)]
    for layer in (cfg.n_layers - 1,):
        w, rec, rec_lsh = train_layer_hash(model, params, batches,
                                           layer, rbit=64)
        print(f"[example] layer {layer} top-10% recall: "
              f"trained-hash={rec:.3f} random-lsh={rec_lsh:.3f}")
    print("[example] done")


if __name__ == "__main__":
    main()
