"""Quickstart: HATA end-to-end in miniature.

1. Build a small GQA model (reduced qwen1.5-0.5b config).
2. Prefill a prompt — the KV cache fills and keys are hash-encoded
   (paper Alg. 1).
3. Decode with HATA top-k attention (Alg. 3) vs dense attention, and
   compare outputs + the HBM bytes each moves.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.baselines import decode_bytes_per_kv_head
from repro.models import Model

cfg = get_reduced("qwen1.5-0.5b")
cfg = dataclasses.replace(cfg, dtype="float32")
print(f"model: {cfg.name}  layers={cfg.n_layers} d_model={cfg.d_model} "
      f"heads={cfg.n_heads}/{cfg.n_kv_heads} "
      f"hata: rbit={cfg.hata.rbit} budget={cfg.hata.budget(64)}@64")

model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
prompt = rng.integers(0, cfg.vocab_size, (1, 48), dtype=np.int32)

outputs = {}
for enabled in (False, True):
    cfg2 = dataclasses.replace(
        cfg, hata=dataclasses.replace(cfg.hata, enabled=enabled,
                                      budget_min=16, budget_max=16))
    m2 = Model(cfg2)
    caches = m2.init_caches(1, 64)
    logits, caches = m2.prefill(params, {"tokens": jnp.asarray(prompt)},
                                caches, jnp.int32(0))
    toks = [int(jnp.argmax(logits[0]))]
    pos = 48
    for _ in range(8):
        logits, caches = m2.decode_step(
            params, jnp.asarray(toks[-1:], jnp.int32), caches,
            jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    outputs["hata" if enabled else "dense"] = toks

agree = np.mean([a == b for a, b in zip(outputs["dense"],
                                        outputs["hata"])])
print(f"dense decode: {outputs['dense']}")
print(f"hata  decode: {outputs['hata']}   (agreement {agree:.0%} at a "
      f"{16 / 64:.0%} token budget, untrained hash weights)")

for s in (32768, 262144):
    d_ = decode_bytes_per_kv_head("dense", s, 128, budget=512)
    h_ = decode_bytes_per_kv_head("hata", s, 128, budget=512)
    print(f"decode step @{s:>7} ctx: dense={d_/2**20:7.1f} MiB/kv-head  "
          f"hata={h_/2**20:5.2f} MiB/kv-head  ({d_/h_:.1f}x less HBM "
          f"traffic — the paper's speedup mechanism)")
print("next: examples/train_lm.py trains + hash-trains; "
      "examples/serve_longcontext.py runs the serving engine")
