"""Mamba-2 SSD (state-space duality) mixer — used by mamba2-130m and the
SSM half of Hymba's hybrid blocks.

Chunked SSD (Dao & Gu 2024): within chunks of length Q the recurrence is
computed as a masked (Q, Q) matmul (the "attention-like" dual form, MXU
friendly); across chunks a sequential lax.scan carries the (hd, N) state.
Per-step decode is the O(1) recurrence — the attention-free analogue of
the paper's cache problem: state is constant-size, so HATA is
inapplicable (DESIGN.md §Arch-applicability) and decode is already
memory-minimal.

Notation: l_t = Δ_t·A_h; cum = inclusive cumsum(l); for j<=i
  y_i  = Σ_j exp(cum_i - cum_j)·(C_i·B_j)·Δ_j·x_j  (intra)
       + exp(cum_i)·C_i·S_in                        (inter)
  S_out = exp(cum_Q)·S_in + Σ_j exp(cum_Q - cum_j)·Δ_j·x_j ⊗ B_j
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.core.kvcache import SSMState
from repro.models.layers import init_linear, rms_norm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return di, nh, conv_dim


def ssm_init(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    s = cfg.ssm
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    di, nh, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + nh   # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[1], (nh,), jnp.float32)
                 * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
                 + jnp.log(s.dt_min))
    return {
        "in_proj": init_linear(ks[0], d, in_dim, dtype),
        "conv_w": (jax.random.normal(ks[2], (s.d_conv, conv_dim),
                                     jnp.float32) / s.d_conv).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),       # inv softplus
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": init_linear(ks[3], di, d, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    di, nh, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xs, bm, cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn], axis=-1)
    return z, xs, bm, cm, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None]
              for i in range(k))
    return out + b[None, None]


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                cm: jax.Array, s0: jax.Array, chunk: int,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, S, nh, hd), dt: (B, S, nh) post-softplus, a: (nh,) negative,
    bm/cm: (B, S, nh, N) (groups pre-broadcast), s0: (B, nh, hd, N).
    Returns y: (B, S, nh, hd), s_final.
    """
    b, s, nh, hd = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # zero-pad: Δt=0 rows neither emit nor alter the state
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    nc = s // q

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, nc, q, *t.shape[2:]), 1, 0)

    xc, dtc, bc, cc = map(to_chunks, (x, dt, bm, cm))

    def chunk_step(carry, xs):
        s_in = carry                                   # (B, nh, hd, N)
        xq, dtq, bq, cq = xs                           # (B, q, nh, ...)
        l = dtq * a[None, None]                        # (B, q, nh)
        cum = jnp.cumsum(l, axis=1)
        total = cum[:, -1]                             # (B, nh)
        u = xq * dtq[..., None]                        # Δx (B,q,nh,hd)
        # intra-chunk masked dual form
        cb = jnp.einsum("bihn,bjhn->bhij", cq, bq)     # (B,nh,q,q)
        diff = cum[:, :, None] - cum[:, None, :]       # (B, i, j, nh)
        diff = jnp.moveaxis(diff, 3, 1)                # (B, nh, i, j)
        tri = jnp.tril(jnp.ones((q, q), bool))
        m = jnp.where(tri[None, None], jnp.exp(diff) * cb, 0.0)
        y_intra = jnp.einsum("bhij,bjhd->bihd", m, u)
        # inter-chunk from incoming state
        y_inter = jnp.einsum("bihn,bhdn->bihd", cq, s_in) \
            * jnp.exp(cum)[..., None]
        # state update
        decay_out = jnp.exp(total[:, None] - cum)      # (B, q, nh)
        st = jnp.einsum("bjhd,bjhn,bjh->bhdn", u, bq, decay_out)
        s_out = jnp.exp(total)[..., None, None] * s_in + st
        return s_out, y_intra + y_inter

    s_fin, yc = jax.lax.scan(chunk_step, s0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s, nh, hd)
    if pad:
        y = y[:, :s - pad]
    return y, s_fin


def ssm_forward(cfg: ModelConfig, p, x: jax.Array,
                state: SSMState = None, *, return_state: bool = False):
    """Full-sequence SSM mixer (train / prefill).

    x: (B, S, D) -> y: (B, S, D) (+ final SSMState for prefill)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di, nh, conv_dim = ssm_dims(cfg)
    hd = s_cfg.head_dim
    z, xs, bm, cm, dt = _split_proj(cfg, x @ p["in_proj"])
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, bm, cm = jnp.split(conv_out, [di, di + s_cfg.n_groups
                                      * s_cfg.d_state], axis=-1)
    a = -jnp.exp(p["a_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(b, s, nh, hd).astype(jnp.float32)
    rep = nh // s_cfg.n_groups
    bmh = jnp.repeat(bm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state),
                     rep, axis=2).astype(jnp.float32)
    cmh = jnp.repeat(cm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state),
                     rep, axis=2).astype(jnp.float32)
    s0 = jnp.zeros((b, nh, hd, s_cfg.d_state), jnp.float32)
    y, s_fin = ssd_chunked(xh, dt, a, bmh, cmh, s0, s_cfg.chunk)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_w"],
                 cfg.norm_eps)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    # conv state: last (d_conv - 1) *pre-activation* conv inputs
    tail = conv_in[:, -(s_cfg.d_conv - 1):, :]
    return out, SSMState(conv=tail, ssm=s_fin)


def ssm_decode(cfg: ModelConfig, p, x: jax.Array, state: SSMState,
               ) -> Tuple[jax.Array, SSMState]:
    """One-token recurrent step. x: (B, 1, D)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    di, nh, conv_dim = ssm_dims(cfg)
    hd = s_cfg.head_dim
    z, xs, bm, cm, dt = _split_proj(cfg, x[:, 0] @ p["in_proj"])
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)   # (B, conv_dim)
    window = jnp.concatenate([state.conv, conv_in[:, None]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xs, bm, cm = jnp.split(conv_out, [di, di + s_cfg.n_groups
                                      * s_cfg.d_state], axis=-1)
    a = -jnp.exp(p["a_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    xh = xs.reshape(b, nh, hd).astype(jnp.float32)
    rep = nh // s_cfg.n_groups
    bmh = jnp.repeat(bm.reshape(b, s_cfg.n_groups, s_cfg.d_state), rep,
                     axis=1).astype(jnp.float32)
    cmh = jnp.repeat(cm.reshape(b, s_cfg.n_groups, s_cfg.d_state), rep,
                     axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * a[None])                      # (B, nh)
    s_new = state.ssm * decay[..., None, None] + jnp.einsum(
        "bhd,bhn,bh->bhdn", xh, bmh, dt)
    y = jnp.einsum("bhdn,bhn->bhd", s_new, cmh) \
        + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_w"],
                 cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, SSMState(conv=window[:, 1:], ssm=s_new)
