"""Attention mixers: GQA/MHA (+HATA), MLA (+beyond-paper HATA-over-latent),
and gated cross-attention (VLM).

Every mixer exposes four pure functions closed over the static config:
  init(cfg, key)                         -> layer params
  forward_train(cfg, p, w_h, x, pos0)    -> y           (full attention)
  prefill(cfg, p, w_h, x, cache, pos)    -> (y, cache)  (Alg. 1)
  decode(cfg, p, w_h, x, view, pos, use_hata) -> (y, view)  (Alg. 3)

``use_hata`` is a *traced* bool so the first-N dense layers (paper §5.1)
stay inside one scanned layer structure; ``lax.cond`` picks the scoring
path. Cache/code updates happen outside the cond so both branches share
cache structure.

Cache addressing goes through :mod:`repro.core.cache_view`: every
decode/chunked-prefill entry point takes a *view* (``ContiguousView``
over a plain cache, ``PagedView`` over a page pool + block table) — or
a raw ``LayerKVCache``/``MLACache``, which is coerced for free. There
is exactly ONE attend / decode / prefill-chunk function per family; the
former ``*_paged`` twins are gone (``Model.decode_step_paged`` /
``prefill_chunk_paged`` remain only as deprecation shims).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache_view as cv
from repro.core import hash_attention as ha
from repro.core import hash_weights as hw
from repro.core.kvcache import LayerKVCache, MLACache, append_kv, append_mla
from repro.core.topk import chunked_topk
from repro.distributed.strategy import get_decode_strategy
from repro.kernels import ops
from repro.models.layers import apply_rope, init_linear


# ===========================================================================
# GQA / MHA
# ===========================================================================
def gqa_init(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    dtype = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": init_linear(ks[0], d, cfg.n_heads * hd, dtype),
         "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dtype),
         "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dtype),
         "wo": init_linear(ks[3], cfg.n_heads * hd, d, dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _mlp_hash_init(key, n_heads: int, d: int, hidden: int,
                   rbit: int) -> dict:
    """Seed MLP hash weights (core/hash_weights.py dict form)."""
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (n_heads, d, hidden), jnp.float32)
        / jnp.sqrt(d),
        "b1": jnp.zeros((n_heads, hidden), jnp.float32),
        "w2": jax.random.normal(k2, (n_heads, hidden, rbit), jnp.float32)
        / jnp.sqrt(hidden),
    }


def gqa_hash_init(cfg: ModelConfig, key):
    if not cfg.hata.enabled:
        return None
    if cfg.hata.hash_hidden:
        return _mlp_hash_init(key, cfg.n_kv_heads, cfg.head_dim,
                              cfg.hata.hash_hidden, cfg.hata.rbit)
    w = jax.random.normal(key, (cfg.n_kv_heads, cfg.head_dim,
                                cfg.hata.rbit), jnp.float32)
    return w / jnp.sqrt(cfg.head_dim)


def _project_qkv(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary)
    return q, k, v


def gqa_forward_train(cfg: ModelConfig, p, w_h, x: jax.Array,
                      pos0: int = 0) -> jax.Array:
    b, s, _ = x.shape
    positions = jnp.arange(s) + pos0
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = ops.flash_attention(q, k, v, causal=True,
                              window=cfg.sliding_window)
    return out.reshape(b, s, -1) @ p["wo"]


def gqa_prefill_parts(cfg: ModelConfig, p, w_h, x: jax.Array,
                      pos: jax.Array):
    """Projections + key codes for prefill (Alg. 1 lines 2-3)."""
    b, s, _ = x.shape
    positions = jnp.arange(s) + pos
    q, k, v = _project_qkv(cfg, p, x, positions)
    codes = None
    if w_h is not None and cfg.hata.enabled:
        codes = ops.hash_encode_heads(k, w_h)
    return q, k, v, codes


def gqa_prefill(cfg: ModelConfig, p, w_h, x: jax.Array,
                cache: LayerKVCache, pos: jax.Array,
                ) -> Tuple[jax.Array, LayerKVCache]:
    b, s, _ = x.shape
    q, k, v, codes = gqa_prefill_parts(cfg, p, w_h, x, pos)
    if cache.codes is None:
        codes = None
    cache = append_kv(cache, k, v, codes, pos)
    out = ops.flash_attention(q, k, v, causal=True,
                              window=cfg.sliding_window)
    return out.reshape(b, s, -1) @ p["wo"], cache


def _dense_decode(cfg: ModelConfig, q, k: jax.Array, v: jax.Array,
                  n_valid):
    """Full-cache decode with length (and SWA window) masking.

    k/v: (B, S, H_kv, d) — a view's logical K/V read (contiguous
    buffers, or the gathered logical view of a paged pool; garbage rows
    land past ``n_valid`` and mask identically). n_valid: scalar or (B,).
    """
    if cfg.sliding_window is None:
        return ops.decode_attention(q, k, v, n_valid)
    b, h, d = q.shape
    h_kv = k.shape[2]
    s = k.shape[1]
    pos = jnp.arange(s)
    nv = jnp.reshape(n_valid, (-1, 1))                  # (1|B, 1)
    valid = (pos[None] < nv) & (pos[None] > nv - 1 - cfg.sliding_window)
    valid = jnp.broadcast_to(valid, (b, s))
    qg = q.reshape(b, h_kv, h // h_kv, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(k.dtype), k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype),
                     v, preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


def _hata_score_select(cfg: ModelConfig, q, w_h, view: cv.KVView,
                       n_valid, layer: Optional[int] = None):
    """Alg. 3 lines 6,10-17 via the shared batched pipeline over any
    cache view: encode q, batched Hamming scores (the view routes the
    contiguous or block-table score kernel), top-k, fused masked gather
    (ditto). ``n_valid`` may be scalar or (B,) — the serving engine's
    decode wave advances slots sitting at different depths in one call.
    Selection math is identical across layouts: a :class:`PagedView`
    only changes the score kernel's page fetch and translates the
    winners to physical rows at the gather boundary. ``layer`` (a
    python int on the unrolled decode paths, None in scanned stacks)
    routes the budget through the per-layer table when one is
    installed."""
    budget = ha.clamped_budget(cfg.hata, view.capacity,
                               cfg.sliding_window, layer=layer)
    q_codes = ha.aggregate_q_codes(q, w_h, cfg.n_kv_heads)
    scores = view.hamming_scores(q_codes, n_valid, rbit=cfg.hata.rbit,
                                 window=cfg.sliding_window)
    top_scores, idx = chunked_topk(scores, budget)
    return view.gather_decode(q, idx, top_scores >= 0)


def _project_qkv_perrow(cfg: ModelConfig, p, x: jax.Array,
                        pos: jax.Array):
    """Decode projections with per-row positions. x: (B, 1, D),
    pos: (B,) — continuous-batching slots sit at different depths."""
    b = x.shape[0]
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, cfg.n_heads, hd)
    k = k.reshape(b, 1, cfg.n_kv_heads, hd)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd)
    rope = jax.vmap(lambda xx, pp: apply_rope(
        xx, pp[None], cfg.rope_theta, cfg.partial_rotary))
    return rope(q, pos), rope(k, pos), v


def gqa_decode_project(cfg: ModelConfig, p, w_h, x: jax.Array,
                       pos: jax.Array):
    """Alg. 3 lines 3-9 minus the cache write: project + encode.
    x: (B, 1, D) -> (q1 (B,H,d), k_new (B,1,Hkv,d), v_new, codes|None).
    pos: scalar or (B,) per-slot positions."""
    if jnp.ndim(pos) == 1:
        q, k, v = _project_qkv_perrow(cfg, p, x, pos)
    else:
        q, k, v = _project_qkv(cfg, p, x, pos[None])
    codes = None
    if w_h is not None and cfg.hata.enabled:
        codes = ops.hash_encode_heads(k, w_h)
    return q[:, 0], k, v, codes


def gqa_decode_attend(cfg: ModelConfig, p, w_h, q1: jax.Array,
                      view, pos: jax.Array, use_hata,
                      layer: Optional[int] = None) -> jax.Array:
    """Alg. 3 lines 10-17 over ANY cache view — contiguous, paged, or
    sequence-sharded (a raw ``LayerKVCache`` coerces to
    ``ContiguousView`` for free). Returns the block output (B, 1, D)
    (Wo applied). ``layer``: concrete layer index on the unrolled
    decode paths (enables the calibrated per-layer budget table); None
    inside scanned stacks and the SP strategies, whose selection shape
    must be layer-invariant."""
    view = cv.as_gqa_view(view)
    b = q1.shape[0]
    n_valid = pos + 1
    hata_on = view.has_codes and cfg.hata.enabled
    strat = get_decode_strategy()
    out = None
    if strat is not None:
        out = strat.gqa(cfg, q1, w_h, view, n_valid,
                        use_hata if hata_on else False)
    if out is None:
        def dense_path():
            k_log, v_log = view.kv_logical()
            return _dense_decode(cfg, q1, k_log, v_log, n_valid)

        if not hata_on:
            out = dense_path()
        elif isinstance(use_hata, bool):
            # static layer split (segmented scan): only one branch is
            # lowered — the dry-run sees steady-state HATA cost
            out = (_hata_score_select(cfg, q1, w_h, view, n_valid, layer)
                   if use_hata else dense_path())
        else:
            out = jax.lax.cond(
                use_hata,
                lambda: _hata_score_select(cfg, q1, w_h, view, n_valid,
                                           layer),
                dense_path)
    return out.reshape(b, 1, -1) @ p["wo"]


def gqa_decode(cfg: ModelConfig, p, w_h, x: jax.Array, cache,
               pos: jax.Array, use_hata,
               layer: Optional[int] = None):
    """One decode step over any view (or raw cache). x: (B, 1, D) one
    new token; pos: scalar cache fill, or (B,) per-slot fills (the
    paged engine's decode wave — inactive slots' block-table rows point
    at the scratch page). Returns (y, view-or-cache) matching the input
    container type."""
    view = cv.as_gqa_view(cache)
    q1, k, v, codes = gqa_decode_project(cfg, p, w_h, x, pos)
    if not view.has_codes:
        codes = None
    view = view.append(k, v, codes, pos)
    out = gqa_decode_attend(cfg, p, w_h, q1, view, pos, use_hata, layer)
    return out, (view if cv.is_view(cache) else view.unwrap())


def gqa_prefill_chunk(cfg: ModelConfig, p, w_h, x: jax.Array, view,
                      ctx: jax.Array):
    """One chunk of a chunked prefill (Alg. 1 in pieces) over any view.

    x: (1, C, D) — the chunk's hidden states — at absolute positions
    [ctx, ctx + C). The fresh K/V/code rows are appended at ``ctx``,
    then the chunk's queries attend causally over the cached context
    *in place* (the block-table flash-prefill kernel on a
    ``PagedView``; rows past ctx + C are garbage, excluded by
    causality). ``ctx`` is traced: one compiled chunk shape serves
    every chunk of every prompt. ``ctx`` may also be (B,) per-row
    starts — the speculative verify wave scores one d+1-token chunk
    per *slot*, each at its own committed length (x is then (B, C, D)
    and every row appends + attends at its own offset).
    """
    view = cv.as_gqa_view(view)
    b, c, _ = x.shape
    if jnp.ndim(ctx) == 1:
        positions = ctx[:, None] + jnp.arange(c)[None]       # (B, C)
        q, k, v = jax.vmap(
            lambda xr, pr: _project_qkv(cfg, p, xr[None], pr))(
                x, positions)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
    else:
        positions = jnp.arange(c) + ctx
        q, k, v = _project_qkv(cfg, p, x, positions)
    codes = None
    if w_h is not None and cfg.hata.enabled and view.has_codes:
        codes = ops.hash_encode_heads(k, w_h)
    view = view.append_chunk(k, v, codes, ctx)
    a = view.prefill_attend(q, ctx, window=cfg.sliding_window)
    return a.reshape(b, c, -1) @ p["wo"], view


def gqa_verify_chunk(cfg: ModelConfig, p, w_h, x: jax.Array, view,
                     ctx: jax.Array, use_hata,
                     layer: Optional[int] = None):
    """Speculative verify through one GQA layer: append the (B, C)
    chunk like the per-row branch of :func:`gqa_prefill_chunk`, then
    attend every position through the DECODE path
    (:func:`gqa_decode_attend`) — position j of row b runs with
    pos = ctx_b + j under the layer's HATA flag, so a hash-aware layer
    scores/selects the same top-k rows as the sequential decode the
    wave replaces. A dense ``prefill_attend`` here would silently
    diverge from decode the moment the context outgrows the layer
    budget (verify attending ALL rows, decode only top-k), breaking
    the spec ≡ non-spec guarantee. The C positions fold into the BATCH
    (``view.tile_rows``: slot b's position j reads as batch row
    b*C + j at pos ctx_b + j), so the whole verify wave is ONE batched
    score→select→gather per layer — the same dispatch count as a
    plain decode wave, and per-row math identical to it bit-for-bit.
    """
    view = cv.as_gqa_view(view)
    b, c, _ = x.shape
    positions = ctx[:, None] + jnp.arange(c)[None]           # (B, C)
    q, k, v = jax.vmap(
        lambda xr, pr: _project_qkv(cfg, p, xr[None], pr))(x, positions)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    codes = None
    if w_h is not None and cfg.hata.enabled and view.has_codes:
        codes = ops.hash_encode_heads(k, w_h)
    view = view.append_chunk(k, v, codes, ctx)
    a = gqa_decode_attend(cfg, p, w_h,
                          q.reshape((b * c,) + q.shape[2:]),
                          view.tile_rows(c), positions.reshape(b * c),
                          use_hata, layer)              # (B*C, 1, D)
    return a.reshape(b, c, -1), view


# ===========================================================================
# MLA (DeepSeek-V2) — HATA over the compressed latent (beyond-paper)
# ===========================================================================
def mla_init(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    m = cfg.mla
    dtype = jnp.dtype(cfg.dtype)
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, h * (m.qk_nope_dim + m.qk_rope_dim),
                          dtype),
        "wdkv": init_linear(ks[1], d, m.kv_lora_rank, dtype),
        "wkr": init_linear(ks[2], d, m.qk_rope_dim, dtype),
        # up-projections from the latent
        "wuk": init_linear(ks[3], m.kv_lora_rank, h * m.qk_nope_dim,
                           dtype),
        "wuv": init_linear(ks[4], m.kv_lora_rank, h * m.v_head_dim,
                           dtype),
        "wo": init_linear(ks[5], h * m.v_head_dim, d, dtype),
    }


def mla_hash_init(cfg: ModelConfig, key):
    if not cfg.hata.enabled:
        return None
    m = cfg.mla
    dim = m.kv_lora_rank + m.qk_rope_dim
    # one shared latent stream per layer -> one weight (leading axis 1
    # keeps the (H_kv, d, rbit) convention)
    if cfg.hata.hash_hidden:
        return _mlp_hash_init(key, 1, dim, cfg.hata.hash_hidden,
                              cfg.hata.rbit)
    w = jax.random.normal(key, (1, dim, cfg.hata.rbit), jnp.float32)
    return w / jnp.sqrt(dim)


def _mla_qkv(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array):
    """Returns per-head q (nope+rope) and the latent streams."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = (x @ p["wq"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ p["wdkv"]                              # (B, S, r)
    krope = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]   # (B, S, rd)
    return q_nope, q_rope, ckv, krope


def mla_forward_train(cfg: ModelConfig, p, w_h, x: jax.Array,
                      pos0: int = 0) -> jax.Array:
    """Materialized form: per-head K = [W_uk c ; k_rope], V = W_uv c."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    positions = jnp.arange(s) + pos0
    q_nope, q_rope, ckv, krope = _mla_qkv(cfg, p, x, positions)
    k_nope = (ckv @ p["wuk"]).reshape(b, s, h, m.qk_nope_dim)
    v = (ckv @ p["wuv"]).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (b, s, h, m.qk_rope_dim))], axis=-1)
    # MLA scales by sqrt(qk_nope + rope) total dim
    out = ops.flash_attention(q, k, v, causal=True)
    return out.reshape(b, s, -1) @ p["wo"]


def mla_prefill_parts(cfg: ModelConfig, p, w_h, x: jax.Array,
                      pos: jax.Array):
    """-> (q, k, v materialized per head; ckv, krope, codes latents)."""
    m = cfg.mla
    b, s, _ = x.shape
    positions = jnp.arange(s) + pos
    q_nope, q_rope, ckv, krope = _mla_qkv(cfg, p, x, positions)
    codes = None
    if w_h is not None and cfg.hata.enabled:
        latent = jnp.concatenate([ckv, krope], axis=-1)  # (B, S, r+rd)
        codes = ops.hash_encode(latent, hw.head0(w_h))
    h = cfg.n_heads
    k_nope = (ckv @ p["wuk"]).reshape(b, s, h, m.qk_nope_dim)
    v = (ckv @ p["wuv"]).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (b, s, h, m.qk_rope_dim))], axis=-1)
    return q, k, v, ckv, krope, codes


def mla_prefill(cfg: ModelConfig, p, w_h, x: jax.Array, cache: MLACache,
                pos: jax.Array) -> Tuple[jax.Array, MLACache]:
    b, s, _ = x.shape
    q, k, v, ckv, krope, codes = mla_prefill_parts(cfg, p, w_h, x, pos)
    if cache.codes is None:
        codes = None
    cache = append_mla(cache, ckv, krope, codes, pos)
    out = ops.flash_attention(q, k, v, causal=True)
    return out.reshape(b, s, -1) @ p["wo"], cache


def _mla_latent_q(cfg: ModelConfig, p, q_nope: jax.Array,
                  q_rope: jax.Array) -> jax.Array:
    """Absorb W_uk: map q into latent space. Any leading batch shape:
    q_nope/q_rope (..., H, dims) -> (..., H, r + rope_dim) — (B, H, d)
    for decode, (B, C, H, d) for the chunked prefill."""
    m = cfg.mla
    wuk = p["wuk"].reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_dim)
    q_lat = jnp.einsum("...hd,rhd->...hr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    return jnp.concatenate(
        [q_lat, q_rope.astype(jnp.float32)], axis=-1)


def _mla_attend(cfg: ModelConfig, p, q_lat: jax.Array, ckv_rows,
                krope_rows, mask) -> jax.Array:
    """Attention in latent space over (B, k, r) rows. q_lat: (B,H,r+rd).

    Cache operands stay in their storage dtype with f32 MXU
    accumulation (an .astype(f32) on the cache would make XLA hoist an
    f32 copy of the whole latent cache out of the decode layer scan).
    """
    m = cfg.mla
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    kv = jnp.concatenate([ckv_rows, krope_rows], axis=-1)  # (B,k,r+rd)
    logits = jnp.einsum("bhr,bkr->bhk", q_lat.astype(kv.dtype), kv,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", probs.astype(ckv_rows.dtype),
                       ckv_rows,
                       preferred_element_type=jnp.float32)  # (B,H,r)
    h = cfg.n_heads
    wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
    return o


def _apply_wuv(cfg: ModelConfig, p, o_lat: jax.Array) -> jax.Array:
    m = cfg.mla
    wuv = p["wuv"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    return jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))


def mla_decode_project(cfg: ModelConfig, p, w_h, x: jax.Array,
                       pos: jax.Array):
    """-> (q_lat (B,H,r+rd) f32, ckv (B,1,r), krope (B,1,rd),
    codes (B,1,W)|None). pos: scalar or (B,) per-slot."""
    if jnp.ndim(pos) == 1:
        qn, qr, cvv, kr = jax.vmap(
            lambda xr, pp: _mla_qkv(cfg, p, xr[None], pp[None]))(x, pos)
        q_nope, q_rope = qn[:, 0], qr[:, 0]
        ckv, krope = cvv[:, 0], kr[:, 0]
    else:
        q_nope, q_rope, ckv, krope = _mla_qkv(cfg, p, x, pos[None])
    codes = None
    if w_h is not None and cfg.hata.enabled:
        latent = jnp.concatenate([ckv, krope], axis=-1)
        codes = ops.hash_encode(latent, hw.head0(w_h))
    q_lat = _mla_latent_q(cfg, p, q_nope[:, 0], q_rope[:, 0])
    return q_lat, ckv, krope, codes


def _hata_mla_select(cfg: ModelConfig, p, w_h, q_lat: jax.Array,
                     view: cv.MLAView, n_valid,
                     layer: Optional[int] = None) -> jax.Array:
    """The same batched score -> select -> gather pipeline as the GQA
    decode, over the single shared latent stream (G = all H heads):
    one batched Hamming dispatch (contiguous or block-table, routed by
    the view), top-k, one split-latent paged fused-gather dispatch. No
    (B, S) popcount tensor, no XLA row gather — see
    kernels/flash_decode.mla_decode_gathered_batched and its paged twin.
    """
    m = cfg.mla
    q_codes = ops.hash_encode(q_lat, hw.head0(w_h))    # (B, H, W)
    scores = view.hamming_scores(q_codes, n_valid, rbit=cfg.hata.rbit,
                                 window=cfg.sliding_window)  # (B, S_log)
    budget = ha.clamped_budget(cfg.hata, view.capacity,
                               cfg.sliding_window, layer=layer)
    top_scores, idx = chunked_topk(scores, budget)     # (B, k)
    o_lat = view.gather_latent(
        q_lat, idx, lora_rank=m.kv_lora_rank,
        scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5,
        n_valid=jnp.sum((top_scores >= 0).astype(jnp.int32), -1))
    return _apply_wuv(cfg, p, o_lat)


def mla_decode_attend(cfg: ModelConfig, p, w_h, q_lat: jax.Array,
                      view, pos: jax.Array, use_hata,
                      x_dtype, layer: Optional[int] = None) -> jax.Array:
    """MLA decode attention over ANY latent view (raw ``MLACache``
    coerces to ``ContiguousMLAView``)."""
    view = cv.as_mla_view(view)
    b = q_lat.shape[0]
    n_valid = pos + 1
    s_log = view.capacity

    def dense_path():
        ckv_log, kr_log = view.latents_logical()
        mask = jnp.arange(s_log)[None] < jnp.reshape(n_valid, (-1, 1))
        mask = jnp.broadcast_to(mask, (b, s_log))
        return _mla_attend(cfg, p, q_lat, ckv_log, kr_log, mask)

    hata_on = view.has_codes and cfg.hata.enabled
    strat = get_decode_strategy()
    o = None
    if strat is not None:
        o = strat.mla(cfg, p, w_h, q_lat, view, n_valid,
                      use_hata if hata_on else False)
    if o is None:
        if not hata_on:
            o = dense_path()
        elif isinstance(use_hata, bool):
            o = (_hata_mla_select(cfg, p, w_h, q_lat, view, n_valid,
                                  layer)
                 if use_hata else dense_path())
        else:
            o = jax.lax.cond(
                use_hata,
                lambda: _hata_mla_select(cfg, p, w_h, q_lat, view,
                                         n_valid, layer),
                dense_path)
    return o.reshape(b, 1, -1).astype(x_dtype) @ p["wo"]


def mla_decode(cfg: ModelConfig, p, w_h, x: jax.Array, cache,
               pos: jax.Array, use_hata,
               layer: Optional[int] = None):
    """One MLA decode step over any view (or raw cache); pos scalar or
    (B,). Returns (y, view-or-cache) matching the input container."""
    view = cv.as_mla_view(cache)
    q_lat, ckv, krope, codes = mla_decode_project(cfg, p, w_h, x, pos)
    if not view.has_codes:
        codes = None
    view = view.append(ckv, krope, codes, pos)
    out = mla_decode_attend(cfg, p, w_h, q_lat, view, pos, use_hata,
                            x.dtype, layer)
    return out, (view if cv.is_view(cache) else view.unwrap())


def mla_prefill_chunk(cfg: ModelConfig, p, w_h, x: jax.Array, view,
                      ctx: jax.Array):
    """One chunk of a chunked MLA prefill over any view: append the
    chunk's latents, then attend *in latent space* with absorbed
    queries — the chunk's queries carry W_uk, logits are q_c·c + q_r·k_r
    over the (ckv, krope) streams (read in place on a ``PagedMLAView``),
    and W_uv is applied to the attended latents. No per-head context
    tensor exists at all."""
    view = cv.as_mla_view(view)
    m = cfg.mla
    b, c, _ = x.shape
    if jnp.ndim(ctx) == 1:
        # per-row chunk starts (speculative verify wave): vmap the
        # projection so each slot ropes at its own absolute positions
        positions = ctx[:, None] + jnp.arange(c)[None]       # (B, C)
        qn, qr, cl, kr = jax.vmap(
            lambda xr, pr: _mla_qkv(cfg, p, xr[None], pr))(x, positions)
        q_nope, q_rope = qn[:, 0], qr[:, 0]
        ckv, krope = cl[:, 0], kr[:, 0]
    else:
        positions = jnp.arange(c) + ctx
        q_nope, q_rope, ckv, krope = _mla_qkv(cfg, p, x, positions)
    codes = None
    if w_h is not None and cfg.hata.enabled and view.has_codes:
        latent = jnp.concatenate([ckv, krope], axis=-1)
        codes = ops.hash_encode(latent, hw.head0(w_h))
    view = view.append_chunk(ckv, krope, codes, ctx)
    q_lat = _mla_latent_q(cfg, p, q_nope, q_rope)       # (1, C, H, r+rd)
    o_lat = view.prefill_attend(
        q_lat, ctx, lora_rank=m.kv_lora_rank,
        scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5)
    wuv = p["wuv"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    a = jnp.einsum("bchr,rhd->bchd", o_lat, wuv.astype(jnp.float32))
    return a.reshape(b, c, -1).astype(x.dtype) @ p["wo"], view


def mla_verify_chunk(cfg: ModelConfig, p, w_h, x: jax.Array, view,
                     ctx: jax.Array, use_hata,
                     layer: Optional[int] = None):
    """MLA twin of :func:`gqa_verify_chunk`: per-row chunk append, then
    ONE position-folded batched DECODE-path attend
    (:func:`mla_decode_attend` over ``view.tile_rows``) so hash-aware
    layers run the same latent top-k selection as the sequential
    decode the verify wave replaces."""
    view = cv.as_mla_view(view)
    b, c, _ = x.shape
    positions = ctx[:, None] + jnp.arange(c)[None]           # (B, C)
    qn, qr, cl, kr = jax.vmap(
        lambda xr, pr: _mla_qkv(cfg, p, xr[None], pr))(x, positions)
    q_nope, q_rope = qn[:, 0], qr[:, 0]
    ckv, krope = cl[:, 0], kr[:, 0]
    codes = None
    if w_h is not None and cfg.hata.enabled and view.has_codes:
        latent = jnp.concatenate([ckv, krope], axis=-1)
        codes = ops.hash_encode(latent, hw.head0(w_h))
    view = view.append_chunk(ckv, krope, codes, ctx)
    q_lat = _mla_latent_q(cfg, p, q_nope, q_rope)       # (B, C, H, ·)
    a = mla_decode_attend(cfg, p, w_h,
                          q_lat.reshape((b * c,) + q_lat.shape[2:]),
                          view.tile_rows(c), positions.reshape(b * c),
                          use_hata, x.dtype, layer)     # (B*C, 1, D)
    return a.reshape(b, c, -1), view


# ===========================================================================
# Gated cross-attention (Llama-3.2-Vision style; frontend stubbed)
# ===========================================================================
def cross_init(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    dtype = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": init_linear(ks[0], d, cfg.n_heads * hd, dtype),
            "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dtype),
            "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dtype),
            "wo": init_linear(ks[3], cfg.n_heads * hd, d, dtype),
            "gate_attn": jnp.zeros((), dtype),
            "gate_ffn": jnp.zeros((), dtype)}


def cross_kv(cfg: ModelConfig, p, img: jax.Array):
    """img: (B, T_img, D) already projected to d_model."""
    b, t, _ = img.shape
    hd = cfg.head_dim
    k = (img @ p["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
    v = (img @ p["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
    return k, v


def cross_attend(cfg: ModelConfig, p, x: jax.Array, k: jax.Array,
                 v: jax.Array) -> jax.Array:
    """Gated cross-attention. x: (B, S, D); k/v: (B, T_img, H_kv, hd).
    The image token set is small (~1.6k) and fixed, so this stays dense
    (no HATA) — see DESIGN.md §Arch-applicability."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    out = ops.flash_attention(q, k, v, causal=False)
    out = out.reshape(b, s, -1) @ p["wo"]
    return jnp.tanh(p["gate_attn"]) * out
