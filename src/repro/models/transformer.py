"""Model assembly: embeddings -> scanned residual blocks -> LM head(s).

One :class:`Model` serves all 12 configs. Layer stacks are lax.scan'ed
over stacked params (compact HLO at 126 layers); heterogeneous layers
are handled structurally:

  * deepseek-v2: ``first_dense_layers`` unrolled before the scanned MoE
    stack (different FFN param shape),
  * vlm: scan over groups of (cross_every-1 self + 1 cross) layers,
  * hata dense-layers (paper §5.1): traced per-layer ``use_hata`` flags
    inside one homogeneous scan,
  * hymba meta tokens: learnable embeddings prepended to the stream
    (prefill caches them like ordinary tokens; they act as learned
    sinks, per the Hymba paper).

Steps:
  loss(params, batch)                      training objective
  prefill(params, batch, caches)           Alg. 1 (+ modality frontends)
  decode_step(params, tok, caches, pos)    Alg. 3
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import runtime
from repro.models import blocks
from repro.models.layers import chunked_ce_loss, init_linear, rms_norm


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class Model:
    """Stateless model: all methods are pure functions of params."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kind = {"dense": "dense", "moe": "moe", "ssm": "ssm",
                     "hybrid": "hybrid", "vlm": "dense",
                     "audio": "dense"}[cfg.family]
        self.n_pre = (cfg.moe.first_dense_layers
                      if cfg.moe is not None else 0)
        if cfg.family == "vlm":
            self.per_group = cfg.vlm.cross_every - 1
            self.n_groups = cfg.n_layers // cfg.vlm.cross_every
            self.n_stack = 0
        else:
            self.per_group = self.n_groups = 0
            self.n_stack = cfg.n_layers - self.n_pre

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        vp = cfg.padded_vocab()
        keys = jax.random.split(key, cfg.n_layers + 8)
        p: Dict[str, Any] = {}
        if cfg.family == "audio":
            nb = cfg.audio.n_codebooks
            p["embed"] = (jax.random.normal(
                keys[0], (nb, cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(dtype)
            p["lm_head"] = jnp.stack([
                init_linear(k, cfg.d_model, cfg.vocab_size, dtype)
                for k in jax.random.split(keys[1], nb)])
        else:
            p["embed"] = (jax.random.normal(
                keys[0], (vp, cfg.d_model), jnp.float32) * 0.02
                ).astype(dtype)
            if not cfg.tie_embeddings:
                p["lm_head"] = init_linear(keys[1], cfg.d_model, vp,
                                           dtype)
        if cfg.meta_tokens:
            p["meta"] = (jax.random.normal(
                keys[2], (cfg.meta_tokens, cfg.d_model), jnp.float32)
                * 0.02).astype(dtype)
        if cfg.vlm is not None:
            p["img_proj"] = init_linear(keys[3], cfg.vlm.vision_dim,
                                        cfg.d_model, dtype)
        p["final_norm"] = jnp.ones((cfg.d_model,), dtype)

        lk = keys[8:]
        li = 0
        if self.n_pre:
            p["pre"] = [blocks.block_init(cfg, lk[li + i], self.kind,
                                          dense_ffn=True)
                        for i in range(self.n_pre)]
            p["hash_pre"] = [blocks.hash_init(cfg, lk[li + i])
                             for i in range(self.n_pre)]
            li += self.n_pre
        if cfg.family == "vlm":
            selfs, crosses, hself = [], [], []
            for g in range(self.n_groups):
                gk = jax.random.split(lk[li + g], self.per_group + 1)
                selfs.append(_stack([blocks.block_init(cfg, gk[i], "dense")
                                     for i in range(self.per_group)]))
                hself.append(_stack([blocks.hash_init(cfg, gk[i])
                                     for i in range(self.per_group)]))
                crosses.append(blocks.block_init(cfg, gk[-1], "cross"))
            p["stack"] = _stack(selfs)            # (G, per_group, ...)
            p["hash_stack"] = _stack(hself)
            p["cross_stack"] = _stack(crosses)    # (G, ...)
        elif self.n_stack:
            p["stack"] = _stack([blocks.block_init(cfg, lk[li + i],
                                                   self.kind)
                                 for i in range(self.n_stack)])
            hw = [blocks.hash_init(cfg, lk[li + i])
                  for i in range(self.n_stack)]
            p["hash_stack"] = None if hw[0] is None else _stack(hw)
        return p

    # ------------------------------------------------------------------
    # embedding / head helpers
    # ------------------------------------------------------------------
    def embed(self, params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            # tokens: (B, S, n_codebooks)
            xs = [jnp.take(params["embed"][i], tokens[..., i], axis=0)
                  for i in range(cfg.audio.n_codebooks)]
            x = sum(xs)
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        from repro.distributed.strategy import get_activation_constraint
        ac = get_activation_constraint()
        if ac is not None:
            x = ac(x)
        if cfg.meta_tokens:
            b = x.shape[0]
            meta = jnp.broadcast_to(params["meta"][None],
                                    (b,) + params["meta"].shape)
            x = jnp.concatenate([meta, x.astype(meta.dtype)], axis=1)
        return x

    def head_weight(self, params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _use_hata_flags(self) -> jax.Array:
        cfg = self.cfg
        return jnp.arange(cfg.n_layers) >= cfg.hata.dense_layers

    def _split_stack(self, stack):
        """-> (kv_stack | None, ssm_states | None) per family."""
        if self.kind == "ssm":
            return None, stack
        if self.kind == "hybrid":
            return stack
        return stack, None

    def _join_stack(self, kv, states):
        if self.kind == "ssm":
            return states
        if self.kind == "hybrid":
            return (kv, states)
        return kv

    # ------------------------------------------------------------------
    # training forward
    # ------------------------------------------------------------------
    def _backbone_train(self, params, x: jax.Array,
                        img: Optional[jax.Array]) -> Tuple[jax.Array,
                                                           jax.Array]:
        cfg = self.cfg
        aux_total = jnp.float32(0)
        for bp in params.get("pre", []):
            x, aux = blocks.block_train(cfg, bp, None, x, self.kind)
            aux_total += aux

        if cfg.family == "vlm":
            imgp = img.astype(x.dtype) @ params["img_proj"]

            def group(x, xs):
                gp, cp = xs
                for i in range(self.per_group):
                    bp = jax.tree.map(lambda t: t[i], gp)
                    x, _ = blocks.block_train(cfg, bp, None, x, "dense")
                x, _ = blocks.block_train(cfg, cp, None, x, "cross",
                                          img=imgp)
                return x, jnp.float32(0)

            body = group
            if cfg.remat != "none":
                body = jax.checkpoint(group,
                                      policy=self._remat_policy())
            x, auxs = jax.lax.scan(body, x,
                                   (params["stack"],
                                    params["cross_stack"]))
            return x, aux_total + auxs.sum()

        def body_fn(x, bp):
            x, aux = blocks.block_train(cfg, bp, None, x, self.kind)
            return x, aux

        body = body_fn
        if cfg.remat != "none":
            body = jax.checkpoint(body_fn, policy=self._remat_policy())
        x, auxs = jax.lax.scan(body, x, params["stack"])
        return x, aux_total + auxs.sum()

    def _remat_policy(self):
        if self.cfg.remat == "dots":
            return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint_policies.nothing_saveable

    def loss(self, params, batch: Dict[str, jax.Array]) -> Tuple[
            jax.Array, Dict[str, jax.Array]]:
        """batch: tokens (B, S) [audio: (B, S, nb)], optional
        image_embeds (B, T, vision_dim). Next-token CE."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed(params, tokens)
        x, aux = self._backbone_train(params, x,
                                      batch.get("image_embeds"))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.meta_tokens:
            x = x[:, cfg.meta_tokens:]
        if cfg.family == "audio":
            w = params["lm_head"]                    # (nb, D, V)
            ce = jnp.float32(0)
            for i in range(cfg.audio.n_codebooks):
                ce += chunked_ce_loss(x[:, :-1], w[i], tokens[:, 1:, i])
            ce = ce / cfg.audio.n_codebooks
        else:
            ce = chunked_ce_loss(x[:, :-1], self.head_weight(params),
                                 tokens[:, 1:],
                                 n_vocab=cfg.vocab_size)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, *,
                    layout: str = "stacked"):
        """``stacked``: one (L, ...) array per cache field — used by the
        scanned prefill. ``list``: one buffer per layer — used by the
        unrolled decode (per-buffer donation keeps row appends in place;
        a scan-carried stack makes XLA copy the whole cache per step —
        EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        if cfg.meta_tokens:
            # pad so the sequence axis stays divisible by any mesh
            # sharding (<= 512 shards) after the meta-token extension
            max_len = -(-(max_len + cfg.meta_tokens) // 512) * 512 \
                if max_len + cfg.meta_tokens > 512 \
                else max_len + cfg.meta_tokens
        caches: Dict[str, Any] = {}
        if self.n_pre:
            caches["pre"] = [blocks.init_block_cache(cfg, self.kind,
                                                     batch, max_len)
                             for _ in range(self.n_pre)]
        if cfg.family == "vlm":
            per = [[blocks.init_block_cache(cfg, "dense", batch, max_len)
                    for _ in range(self.per_group)]
                   for _ in range(self.n_groups)]
            ck = jnp.zeros((batch, cfg.vlm.n_image_tokens,
                            cfg.n_kv_heads, cfg.head_dim),
                           jnp.dtype(cfg.dtype))
            if layout == "list":
                caches["stack"] = per
                caches["cross"] = [(ck, ck) for _ in
                                   range(self.n_groups)]
            else:
                caches["stack"] = _stack([_stack(g) for g in per])
                caches["cross"] = (jnp.broadcast_to(
                    ck[None], (self.n_groups,) + ck.shape),) * 2
        elif self.n_stack:
            per = [blocks.init_block_cache(cfg, self.kind, batch,
                                           max_len)
                   for _ in range(self.n_stack)]
            caches["stack"] = per if layout == "list" else _stack(per)
        return caches

    def caches_to_list(self, caches):
        """Convert a stacked cache tree to list layout (one-time static
        slices; used when a prefill feeds an unrolled decode loop)."""
        if isinstance(caches.get("stack"), list):
            return caches
        out = dict(caches)
        if self.cfg.family == "vlm":
            out["stack"] = [
                [jax.tree.map(lambda t: t[g][i], caches["stack"])
                 for i in range(self.per_group)]
                for g in range(self.n_groups)]
        elif self.n_stack:
            out["stack"] = [jax.tree.map(lambda t: t[i], caches["stack"])
                            for i in range(self.n_stack)]
        return out

    # ------------------------------------------------------------------
    # paged caches (block-table serving; see serving/scheduler.py)
    # ------------------------------------------------------------------
    @property
    def supports_paged(self) -> bool:
        """Paged serving covers the attention-KV families (GQA and MLA,
        dense or MoE FFNs). SSM/hybrid state is O(1) per slot (nothing
        to page), VLM carries static image KV, audio/meta-token streams
        keep the slot engine.

        MoE caveat: chunked prefill routes experts per chunk-sized
        group while monolithic prefill groups over the whole prompt,
        so paged ≡ dense outputs are guaranteed only under *dropless*
        capacity (capacity_factor >= n_experts / top_k — the serving
        setting; with capacity dropping, the two paths may drop
        different tokens)."""
        cfg = self.cfg
        return (cfg.family in ("dense", "moe") and cfg.n_heads > 0
                and cfg.meta_tokens == 0)

    def init_paged_pools(self, num_pages: int,
                         page_size: Optional[int] = None):
        """One shared (num_pages, page_size, ...) pool per layer —
        K/V and hash codes paged together. ``page_size=None`` consults
        the kernel tuning table (``runtime.pool_page_size``): the paged
        kernels tile kv at the pool page size, so pool construction is
        their block-size decision."""
        assert self.supports_paged, self.cfg.family
        page_size = runtime.pool_page_size(page_size)
        return [blocks.init_block_pool(self.cfg, num_pages, page_size)
                for _ in range(self.cfg.n_layers)]

    def init_offloaded_pools(self, num_pages: int,
                             page_size: Optional[int] = None, *,
                             pipeline=None):
        """Tiered pools for the offload serving mode: HATA layers keep
        only their hash codes in HBM (K/V rows live on host, fetched
        per wave through one shared
        :class:`~repro.core.offload.PrefetchPipeline`); the leading
        dense layers (``li < hata.dense_layers``) attend over the whole
        context every step, so offloading them would stream the full
        cache over PCIe — they stay fully HBM-resident. Returns
        (pools, pipeline)."""
        assert self.supports_paged, self.cfg.family
        cfg = self.cfg
        assert cfg.hata.enabled, (
            f"{cfg.name}: offload serving requires HATA (the resident "
            "codes are what makes host K/V affordable)")
        from repro.core.offload import PrefetchPipeline
        page_size = runtime.pool_page_size(page_size)
        pipeline = pipeline or PrefetchPipeline()
        pools = [
            blocks.init_block_pool(cfg, num_pages, page_size)
            if li < cfg.hata.dense_layers
            else blocks.init_offload_pool(cfg, num_pages, page_size,
                                          pipeline=pipeline)
            for li in range(cfg.n_layers)]
        return pools, pipeline

    def _flat_layer_params(self, params):
        """(block params, hash weights) per layer, pre + stack — the
        unrolled iteration order the view-typed serving paths use."""
        for i in range(self.n_pre):
            yield params["pre"][i], params["hash_pre"][i]
        for j in range(self.n_stack):
            yield (jax.tree.map(lambda t: t[j], params["stack"]),
                   jax.tree.map(lambda t: t[j], params["hash_stack"]))

    def _decode_views(self, params, tokens: jax.Array, views,
                      pos: jax.Array, layer_limit: Optional[int] = None):
        """One decode wave over per-layer cache views. tokens: (B,);
        pos: scalar or (B,) per-request fill (a ``PagedView``'s
        inactive slots point at the scratch page). Returns
        (logits (B, V), views). ``layer_limit`` runs only the first N
        layers straight into the head — the layer-subset draft of the
        speculative plane (skipped layers' views pass through
        untouched; their stale rows are rewritten by the verify wave
        before anything reads them)."""
        cfg = self.cfg
        x = self.embed_decode(params, tokens)
        hata_on = cfg.hata.enabled
        new_views = []
        for li, (bp, w_h) in enumerate(self._flat_layer_params(params)):
            if layer_limit is not None and li >= layer_limit:
                new_views.append(views[li])
                continue
            flag = hata_on and li >= cfg.hata.dense_layers
            # li is a python int -> the calibrated per-layer budget
            # table (core/budgets.py) applies on this unrolled path
            x, view = blocks.block_decode(cfg, bp, w_h, x, views[li],
                                          self.kind, pos, flag, layer=li)
            new_views.append(view)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._head_last(params, x[:, 0]), new_views

    def prefill_chunk(self, params, tokens: jax.Array, views,
                      ctx: jax.Array, last: jax.Array):
        """One chunk of a chunked prefill (B=1) over per-layer cache
        views. tokens: (1, C) — the chunk, zero-padded past the prompt;
        ctx: traced token count already in the cache (page-aligned when
        the prefix cache contributed pages); last: traced index of the
        last *real* token within the chunk. Returns (logits (1, V) at
        ``last``, views) — only the final chunk's logits are consumed.
        ``ctx``/``last`` being traced means one compiled shape serves
        every chunk of every prompt."""
        cfg = self.cfg
        from repro.core import cache_view as cv
        # one stacked context upload for ALL offloaded MLA layers
        # instead of a per-layer logical upload inside each attend
        # (no-op for non-offloaded view stacks)
        views = cv.stage_mla_ctx_uploads(views)
        x = self.embed(params, tokens)
        new_views = []
        for li, (bp, w_h) in enumerate(self._flat_layer_params(params)):
            x, view = blocks.block_prefill_chunk(cfg, bp, w_h, x,
                                                 views[li], ctx)
            new_views.append(view)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        x_last = jax.lax.dynamic_index_in_dim(x, last, axis=1,
                                              keepdims=False)
        return self._head_last(params, x_last), new_views

    def verify_chunk(self, params, tokens: jax.Array, views,
                     ctx: jax.Array):
        """Speculative verify wave: score a (B, C) token block in one
        chunked-prefill-shaped pass, each row at its OWN committed
        context length ``ctx`` (B,), and return logits at ALL C
        positions — position j of row b runs the DECODE attention path
        at pos = ctx_b + j (dense or hash top-k per the layer's HATA
        flag), exactly what the non-speculative decode would compute
        after committing j more tokens; attending the chunk densely
        (``prefill_attend``) would diverge from decode the moment a
        hash-aware layer's context outgrows its budget. The chunk's
        exact K/V rows overwrite whatever the draft waves appended at
        [ctx_b, ctx_b + C) before any query reads them (append before
        attend inside every ``*_verify_chunk``). Differences from
        :meth:`prefill_chunk`: per-row ``ctx``, every position's
        logits, and no offloaded-MLA staged-context splice (that splice
        is a scalar-ctx ``dynamic_update_slice``; the per-row path
        takes the plain logical upload). Returns
        (logits (B, C, V) f32, views)."""
        cfg = self.cfg
        assert cfg.family != "audio" and not cfg.meta_tokens, (
            f"{cfg.name}: speculative verify supports token-embedding "
            "families without meta rows")
        x = self.embed(params, tokens)
        hata_on = cfg.hata.enabled
        new_views = []
        for li, (bp, w_h) in enumerate(self._flat_layer_params(params)):
            flag = hata_on and li >= cfg.hata.dense_layers
            x, view = blocks.block_verify_chunk(cfg, bp, w_h, x,
                                                views[li], ctx, flag,
                                                layer=li)
            new_views.append(view)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x.astype(jnp.float32) @ self.head_weight(
            params).astype(jnp.float32)
        return logits[..., :cfg.vocab_size], new_views

    # -- deprecation shims (the pools+block_table twin surface) --------
    def decode_step_paged(self, params, tokens: jax.Array, pools,
                          block_table: jax.Array, pos: jax.Array):
        """Deprecated: build ``PagedView``s and call ``decode_step``."""
        import warnings
        from repro.core import cache_view as cv
        warnings.warn(
            "Model.decode_step_paged is deprecated: wrap each layer's "
            "pool in core.cache_view.paged_view(pool, block_table) and "
            "call decode_step with the view list.",
            DeprecationWarning, stacklevel=2)
        views = [cv.paged_view(p_, block_table) for p_ in pools]
        logits, views = self.decode_step(params, tokens, views, pos)
        return logits, [v.unwrap() for v in views]

    def prefill_chunk_paged(self, params, tokens: jax.Array, pools,
                            block_table: jax.Array, ctx: jax.Array,
                            last: jax.Array):
        """Deprecated: build ``PagedView``s and call ``prefill_chunk``."""
        import warnings
        from repro.core import cache_view as cv
        warnings.warn(
            "Model.prefill_chunk_paged is deprecated: wrap each layer's "
            "pool in core.cache_view.paged_view(pool, block_table) and "
            "call prefill_chunk with the view list.",
            DeprecationWarning, stacklevel=2)
        views = [cv.paged_view(p_, block_table) for p_ in pools]
        logits, views = self.prefill_chunk(params, tokens, views, ctx,
                                           last)
        return logits, [v.unwrap() for v in views]

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array], caches,
                pos) -> Tuple[jax.Array, Any]:
        """Returns (last-position logits (B, V[, nb]), caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed(params, tokens)
        if self.n_pre:
            new_pre = []
            for i, (bp, c) in enumerate(zip(params["pre"],
                                            caches["pre"])):
                x, c = blocks.block_prefill(cfg, bp,
                                            params["hash_pre"][i], x, c,
                                            self.kind, pos)
                new_pre.append(c)
            caches = dict(caches, pre=new_pre)

        if isinstance(caches.get("stack"), list):
            return self._prefill_unrolled(params, batch, x, caches, pos)

        if cfg.family == "vlm":
            imgp = batch["image_embeds"].astype(x.dtype) \
                @ params["img_proj"]

            def group(carry, xs):
                x, cstack = carry
                g, gp, hw, cp = xs
                for i in range(self.per_group):
                    bp = jax.tree.map(lambda t: t[i], gp)
                    whi = jax.tree.map(lambda t: t[i], hw)
                    x, cstack, _ = blocks.block_prefill_stacked(
                        cfg, bp, whi, x, cstack, (g, i), "dense", pos)
                x, _, ckv = blocks.block_prefill_stacked(
                    cfg, cp, None, x, cstack, (g,), "cross", pos,
                    img=imgp)
                return (x, cstack), ckv

            (x, new_stack), cross_kvs = jax.lax.scan(
                group, (x, caches["stack"]),
                (jnp.arange(self.n_groups), params["stack"],
                 params["hash_stack"], params["cross_stack"]))
            caches = dict(caches, stack=new_stack, cross=cross_kvs)
        elif self.n_stack:
            kv0, _ = self._split_stack(caches["stack"])

            def body(carry, xs):
                x, kvs = carry
                i, bp, w_h = xs
                x, kvs, state = blocks.block_prefill_stacked(
                    cfg, bp, w_h, x, kvs, (i,), self.kind, pos)
                return (x, kvs), state

            (x, kv_new), states = jax.lax.scan(
                body, (x, kv0),
                (jnp.arange(self.n_stack), params["stack"],
                 params["hash_stack"]))
            caches = dict(caches,
                          stack=self._join_stack(kv_new, states))

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head_last(params, x[:, -1])
        return logits, caches

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def decode_step(self, params, tokens: jax.Array, caches, pos, *,
                    layer_limit: Optional[int] = None
                    ) -> Tuple[jax.Array, Any]:
        """tokens: (B,) [audio: (B, nb)] the last generated token;
        pos: scalar count of tokens already in the cache (incl. meta),
        or (B,) per-slot fills when ``caches`` is a per-layer list of
        cache *views* (``core.cache_view`` — the serving engines'
        continuous-batching waves; contiguous and paged layouts route
        through the same step). ``layer_limit``: run only the first N
        layers (speculative layer-subset draft; view-list path only)."""
        from repro.core import cache_view as cv
        if isinstance(caches, (list, tuple)) and caches \
                and cv.is_view(caches[0]):
            return self._decode_views(params, tokens, list(caches), pos,
                                      layer_limit=layer_limit)
        assert layer_limit is None, (
            "layer_limit drafting needs the per-layer view-list decode "
            "path (serving engines) — not the dict-cache entry")
        cfg = self.cfg
        x = self.embed_decode(params, tokens)
        if self.n_pre:
            new_pre = []
            for i, (bp, c) in enumerate(zip(params["pre"],
                                            caches["pre"])):
                w_h = params["hash_pre"][i]
                x, c = blocks.block_decode(cfg, bp, w_h, x, c, self.kind,
                                           pos,
                                           bool(i >= cfg.hata.dense_layers),
                                           layer=i)
                new_pre.append(c)
            caches = dict(caches, pre=new_pre)

        if isinstance(caches.get("stack"), list):
            return self._decode_unrolled(params, x, caches, pos)

        if cfg.family == "vlm":
            flags = self._use_hata_flags()
            gflags = flags.reshape(self.n_groups, cfg.vlm.cross_every)

            def group(carry, xs):
                x, cstack = carry
                g, gp, hw, cp, ckv, fl = xs
                for i in range(self.per_group):
                    bp = jax.tree.map(lambda t: t[i], gp)
                    whi = jax.tree.map(lambda t: t[i], hw)
                    x, cstack, _ = blocks.block_decode_stacked(
                        cfg, bp, whi, x, cstack, (g, i), "dense", pos,
                        fl[i])
                x, _ = blocks.block_decode(cfg, cp, None, x, None,
                                           "cross", pos, False,
                                           cross_kv=ckv)
                return (x, cstack), None

            (x, new_stack), _ = jax.lax.scan(
                group, (x, caches["stack"]),
                (jnp.arange(self.n_groups), params["stack"],
                 params["hash_stack"], params["cross_stack"],
                 caches["cross"], gflags))
            caches = dict(caches, stack=new_stack)
        elif self.n_stack:
            # Static HATA/dense split over a carried KV stack: the
            # first (dense_layers - n_pre) layers scan with
            # use_hata=False, the rest with True — only the executed
            # branch is lowered (paper §5.1's outlier-layer rule with
            # zero dead code). KV caches are CARRIED (in-place appends);
            # SSM states stream through xs->ys (fully rewritten each
            # step anyway). See EXPERIMENTS.md §Perf iterations 1-2.
            hata_on = cfg.hata.enabled and not cfg.attention_free
            nd = (min(max(cfg.hata.dense_layers - self.n_pre, 0),
                      self.n_stack) if hata_on else self.n_stack)
            kv0, states0 = self._split_stack(caches["stack"])

            def seg(x, kvstack, lo, hi, flag):
                if lo == hi:
                    return x, kvstack, None
                sl = lambda t: jax.tree.map(lambda a: a[lo:hi], t)
                xs = (jnp.arange(lo, hi), sl(params["stack"]),
                      sl(params["hash_stack"]),
                      sl(states0) if states0 is not None else None)

                def body(carry, xs_):
                    x, kvs = carry
                    i, bp, w_h, st = xs_
                    x, kvs, nst = blocks.block_decode_stacked(
                        cfg, bp, w_h, x, kvs, (i,), self.kind, pos,
                        flag, sstate=st)
                    return (x, kvs), nst

                (x, kvstack), new_states = jax.lax.scan(
                    body, (x, kvstack), xs)
                return x, kvstack, new_states

            if not hata_on or nd == self.n_stack:
                x, kv_new, st_new = seg(x, kv0, 0, self.n_stack, False)
            else:
                x, kv_new, st_a = seg(x, kv0, 0, nd, False)
                x, kv_new, st_b = seg(x, kv_new, nd, self.n_stack, True)
                st_new = (None if st_a is None else jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], 0), st_a, st_b))
            caches = dict(caches,
                          stack=self._join_stack(kv_new, st_new))

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head_last(params, x[:, 0])
        return logits, caches

    def _prefill_unrolled(self, params, batch, x, caches, pos):
        """Unrolled prefill over list-layout caches (serving path)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            imgp = batch["image_embeds"].astype(x.dtype) \
                @ params["img_proj"]
            new_groups, new_cross = [], []
            for g in range(self.n_groups):
                gcs = []
                for i in range(self.per_group):
                    bp = jax.tree.map(lambda t: t[g][i], params["stack"])
                    whi = jax.tree.map(lambda t: t[g][i],
                                       params["hash_stack"])
                    x, c = blocks.block_prefill(
                        cfg, bp, whi, x, caches["stack"][g][i], "dense",
                        pos)
                    gcs.append(c)
                cp = jax.tree.map(lambda t: t[g], params["cross_stack"])
                x, ckv = blocks.block_prefill(cfg, cp, None, x, None,
                                              "cross", pos, img=imgp)
                new_groups.append(gcs)
                new_cross.append(ckv)
            caches = dict(caches, stack=new_groups, cross=new_cross)
        else:
            new_list = []
            for j, c in enumerate(caches["stack"]):
                bp = jax.tree.map(lambda t: t[j], params["stack"])
                w_h = jax.tree.map(lambda t: t[j], params["hash_stack"])
                x, c = blocks.block_prefill(cfg, bp, w_h, x, c,
                                            self.kind, pos)
                new_list.append(c)
            caches = dict(caches, stack=new_list)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head_last(params, x[:, -1])
        return logits, caches

    def _decode_unrolled(self, params, x, caches, pos):
        """Unrolled decode over list-layout caches: every layer's cache
        is its own (donated) buffer, so row appends stay in place with
        no scan-carry copies — the serving/dry-run decode path
        (EXPERIMENTS.md §Perf iteration 2)."""
        cfg = self.cfg
        hata_on = cfg.hata.enabled and not cfg.attention_free
        if cfg.family == "vlm":
            new_groups = []
            for g in range(self.n_groups):
                group_caches = []
                for i in range(self.per_group):
                    li = g * cfg.vlm.cross_every + i
                    bp = jax.tree.map(lambda t: t[g][i], params["stack"])
                    whi = jax.tree.map(lambda t: t[g][i],
                                       params["hash_stack"])
                    flag = hata_on and li >= cfg.hata.dense_layers
                    x, c = blocks.block_decode(
                        cfg, bp, whi, x, caches["stack"][g][i], "dense",
                        pos, flag, layer=li)
                    group_caches.append(c)
                cp = jax.tree.map(lambda t: t[g], params["cross_stack"])
                ckv = (caches["cross"][g]
                       if isinstance(caches["cross"], list) else
                       jax.tree.map(lambda t: t[g], caches["cross"]))
                x, _ = blocks.block_decode(cfg, cp, None, x, None,
                                           "cross", pos, False,
                                           cross_kv=ckv)
                new_groups.append(group_caches)
            caches = dict(caches, stack=new_groups)
        else:
            new_list = []
            for j, c in enumerate(caches["stack"]):
                li = self.n_pre + j
                bp = jax.tree.map(lambda t: t[j], params["stack"])
                w_h = jax.tree.map(lambda t: t[j], params["hash_stack"])
                flag = hata_on and li >= cfg.hata.dense_layers
                x, c = blocks.block_decode(cfg, bp, w_h, x, c,
                                           self.kind, pos, flag,
                                           layer=li)
                new_list.append(c)
            caches = dict(caches, stack=new_list)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head_last(params, x[:, 0])
        return logits, caches

    def embed_decode(self, params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            x = sum(jnp.take(params["embed"][i], tokens[:, i], axis=0)
                    for i in range(cfg.audio.n_codebooks))[:, None, :]
        else:
            x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]
        from repro.distributed.strategy import get_activation_constraint
        ac = get_activation_constraint()
        if ac is not None:
            x = ac(x)
        return x

    def _head_last(self, params, x_last: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            return jnp.einsum("bd,ndv->bnv",
                              x_last.astype(jnp.float32),
                              params["lm_head"].astype(jnp.float32))
        logits = x_last.astype(jnp.float32) @ self.head_weight(
            params).astype(jnp.float32)
        return logits[..., :cfg.vocab_size]
