"""Per-family transformer blocks with train / prefill / decode paths.

A "block" is one residual layer. Kinds:
  dense   — (MLA-aware) attention + SwiGLU FFN      [llama*, qwen, stablelm,
             granite, musicgen backbone, vlm self layers]
  moe     — attention + MoE FFN                     [mixtral, deepseek]
  ssm     — Mamba2 SSD mixer only                   [mamba2]
  hybrid  — parallel attention + SSD heads + FFN    [hymba]
  cross   — gated cross-attention + FFN             [vlm cross layers]

All paths are pure functions of (cfg, params, state) so layer stacks can
be lax.scan'ed with stacked params/caches; heterogeneity inside a stack
is expressed by *traced* per-layer flags (use_hata), never by structure.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.kvcache import (LayerKVCache, MLACache, SSMState,
                                init_kv_cache, init_mla_cache,
                                init_ssm_state)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ffn, init_ffn, rms_norm


def _is_mla(cfg: ModelConfig) -> bool:
    return cfg.mla is not None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def block_init(cfg: ModelConfig, key, kind: str, *,
               dense_ffn: bool = False) -> Dict:
    """dense_ffn=True forces a dense FFN in a 'moe' kind (DeepSeek's
    first layer)."""
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict = {"ln1": jnp.ones((d,), dtype)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[0])
        return p
    p["ln2"] = jnp.ones((d,), dtype)
    if kind == "cross":
        p["attn"] = attn.cross_init(cfg, ks[0])
        p["ffn"] = init_ffn(ks[1], d, cfg.d_ff, dtype)
        return p
    p["attn"] = (attn.mla_init(cfg, ks[0]) if _is_mla(cfg)
                 else attn.gqa_init(cfg, ks[0]))
    if kind == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[1])
        p["beta_attn"] = jnp.ones((d,), dtype)
        p["beta_ssm"] = jnp.ones((d,), dtype)
        p["ffn"] = init_ffn(ks[2], d, cfg.d_ff, dtype)
    elif kind == "moe" and not dense_ffn:
        p["moe"] = moe_mod.moe_init(cfg, ks[1])
    else:
        d_ff = cfg.d_ff
        if kind == "moe" and dense_ffn:
            d_ff = cfg.moe.d_ff_dense or cfg.d_ff
        p["ffn"] = init_ffn(ks[1], d, d_ff, dtype)
    return p


def hash_init(cfg: ModelConfig, key) -> Optional[jax.Array]:
    """Per-layer hash weights (H_kv, d_hash, rbit)."""
    if not cfg.hata.enabled or cfg.attention_free:
        return None
    if _is_mla(cfg):
        return attn.mla_hash_init(cfg, key)
    return attn.gqa_hash_init(cfg, key)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int,
                     max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    rbit = cfg.hata.rbit if (cfg.hata.enabled and kind != "ssm") else 0
    if kind == "ssm":
        di, nh, conv_dim = ssm_mod.ssm_dims(cfg)
        return init_ssm_state(batch, conv_dim, cfg.ssm.d_conv, nh,
                              cfg.ssm.head_dim, cfg.ssm.d_state)
    if _is_mla(cfg):
        return init_mla_cache(batch, max_len, cfg.mla.kv_lora_rank,
                              cfg.mla.qk_rope_dim, rbit=rbit, dtype=dtype)
    kv = init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim,
                       rbit=rbit, dtype=dtype)
    if kind == "hybrid":
        di, nh, conv_dim = ssm_mod.ssm_dims(cfg)
        return (kv, init_ssm_state(batch, conv_dim, cfg.ssm.d_conv, nh,
                                   cfg.ssm.head_dim, cfg.ssm.d_state))
    return kv


# ---------------------------------------------------------------------------
# mixer dispatch helpers
# ---------------------------------------------------------------------------
def _attn_train(cfg, p, w_h, x, pos0=0):
    if _is_mla(cfg):
        return attn.mla_forward_train(cfg, p, w_h, x, pos0)
    return attn.gqa_forward_train(cfg, p, w_h, x, pos0)


def _attn_prefill(cfg, p, w_h, x, cache, pos):
    if _is_mla(cfg):
        return attn.mla_prefill(cfg, p, w_h, x, cache, pos)
    return attn.gqa_prefill(cfg, p, w_h, x, cache, pos)


def _attn_decode(cfg, p, w_h, x, cache, pos, use_hata, layer=None):
    if _is_mla(cfg):
        return attn.mla_decode(cfg, p, w_h, x, cache, pos, use_hata,
                               layer)
    return attn.gqa_decode(cfg, p, w_h, x, cache, pos, use_hata, layer)


# ---------------------------------------------------------------------------
# train (full sequence, no cache)
# ---------------------------------------------------------------------------
def block_train(cfg: ModelConfig, p, w_h, x: jax.Array, kind: str, *,
                img: Optional[jax.Array] = None, pos0: int = 0,
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    aux = jnp.float32(0)
    if kind == "ssm":
        return x + ssm_mod.ssm_forward(
            cfg, p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps)), aux
    if kind == "cross":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        k, v = attn.cross_kv(cfg, p["attn"], img)
        x = x + attn.cross_attend(cfg, p["attn"], h, k, v)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + jnp.tanh(p["attn"]["gate_ffn"]) * ffn(p["ffn"], h), aux
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "hybrid":
        a = _attn_train(cfg, p["attn"], w_h, h, pos0)
        s = ssm_mod.ssm_forward(cfg, p["ssm"], h)
        mix = 0.5 * (p["beta_attn"] * rms_norm(a, jnp.ones_like(
            p["beta_attn"]), cfg.norm_eps) + p["beta_ssm"] * rms_norm(
            s, jnp.ones_like(p["beta_ssm"]), cfg.norm_eps))
        x = x + mix
    else:
        x = x + _attn_train(cfg, p["attn"], w_h, h, pos0)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_mod.moe_ffn(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + ffn(p["ffn"], h)
    return x, aux


# ---------------------------------------------------------------------------
# prefill (full sequence, fills caches; Alg. 1)
# ---------------------------------------------------------------------------
def block_prefill(cfg: ModelConfig, p, w_h, x: jax.Array, cache,
                  kind: str, pos, *, img: Optional[jax.Array] = None):
    if kind == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, state = ssm_mod.ssm_forward(cfg, p["ssm"], h,
                                       return_state=True)
        return x + y, state
    if kind == "cross":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        k, v = attn.cross_kv(cfg, p["attn"], img)
        x = x + attn.cross_attend(cfg, p["attn"], h, k, v)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + jnp.tanh(p["attn"]["gate_ffn"]) * ffn(p["ffn"], h)
        return x, (k, v)                      # static cross KV cache
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "hybrid":
        kv, sstate = cache
        a, kv = _attn_prefill(cfg, p["attn"], w_h, h, kv, pos)
        s, sstate = ssm_mod.ssm_forward(cfg, p["ssm"], h,
                                        return_state=True)
        mix = 0.5 * (p["beta_attn"] * rms_norm(a, jnp.ones_like(
            p["beta_attn"]), cfg.norm_eps) + p["beta_ssm"] * rms_norm(
            s, jnp.ones_like(p["beta_ssm"]), cfg.norm_eps))
        x = x + mix
        cache = (kv, sstate)
    else:
        a, cache = _attn_prefill(cfg, p["attn"], w_h, h, cache, pos)
        x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + ffn(p["ffn"], h)
    return x, cache


# ---------------------------------------------------------------------------
# stacked-cache decode (carry-based; in-place appends)
# ---------------------------------------------------------------------------
def _stack_append(stack_leaf: jax.Array, new: jax.Array, lead,
                  pos) -> jax.Array:
    """Write ``new`` (B, S_new, ...) into a layer-stacked cache leaf
    (*lead, B, S_max, ...) at sequence offset ``pos``.

    When a sequence-parallel strategy is installed the write happens
    inside shard_map (masked local row writes, O(row) traffic — GSPMD's
    own DUS lowering on a sharded dim does a whole-buffer ownership
    select; EXPERIMENTS.md §Perf). Locally it's a plain in-place DUS.
    """
    from repro.distributed.strategy import get_decode_strategy
    strat = get_decode_strategy()
    if strat is not None and hasattr(strat, "append_leaf"):
        return strat.append_leaf(stack_leaf, new, tuple(lead), pos)
    lead = tuple(lead)
    new = new.astype(stack_leaf.dtype)
    new = new.reshape((1,) * len(lead) + new.shape)
    idx = lead + (0, pos) + (0,) * (stack_leaf.ndim - len(lead) - 2)
    return jax.lax.dynamic_update_slice(stack_leaf, new, idx)


def _layer_view(stack, lead):
    """Slice one layer's cache out of the stacked pytree."""
    def one(t):
        for i in tuple(lead):
            t = jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)
        return t
    return jax.tree.map(one, stack)


def block_decode_stacked(cfg: ModelConfig, p, w_h, x: jax.Array,
                         kv_stack, lead, kind: str, pos, use_hata, *,
                         sstate: Optional[SSMState] = None,
                         cross_kv: Optional[Tuple] = None):
    """One decode block over layer-stacked KV caches.

    ``kv_stack`` holds every layer's KV+code cache with leading index
    dims; ``lead`` (tuple of traced/static ints) addresses this block's
    slot. KV stacks are CARRIED (appends stay in place); SSM states are
    passed per-layer (``sstate``, scan xs->ys — they are fully
    rewritten every step, so ys threading is exactly one state r/w).
    Returns (x, kv_stack, new_sstate).
    """
    if kind == "cross":
        y, _ = block_decode(cfg, p, w_h, x, None, kind, pos, use_hata,
                            cross_kv=cross_kv)
        return y, kv_stack, None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "ssm":
        y, new_state = ssm_mod.ssm_decode(cfg, p["ssm"], h, sstate)
        return x + y, kv_stack, new_state

    if _is_mla(cfg):
        q_lat, ckv, krope, codes = attn.mla_decode_project(
            cfg, p["attn"], w_h, h, pos)
        if kv_stack.codes is None:
            codes = None
        kv_stack = MLACache(
            ckv=_stack_append(kv_stack.ckv, ckv, lead, pos),
            krope=_stack_append(kv_stack.krope, krope, lead, pos),
            codes=None if codes is None else _stack_append(
                kv_stack.codes, codes, lead, pos))
        view = _layer_view(kv_stack, lead)
        a = attn.mla_decode_attend(cfg, p["attn"], w_h, q_lat, view,
                                   pos, use_hata, x.dtype)
    else:
        q1, k1, v1, codes = attn.gqa_decode_project(cfg, p["attn"],
                                                    w_h, h, pos)
        if kv_stack.codes is None:
            codes = None
        kv_stack = LayerKVCache(
            k=_stack_append(kv_stack.k, k1, lead, pos),
            v=_stack_append(kv_stack.v, v1, lead, pos),
            codes=None if codes is None else _stack_append(
                kv_stack.codes, codes, lead, pos))
        view = _layer_view(kv_stack, lead)
        a = attn.gqa_decode_attend(cfg, p["attn"], w_h, q1, view, pos,
                                   use_hata)

    new_state = None
    if kind == "hybrid":
        s, new_state = ssm_mod.ssm_decode(cfg, p["ssm"], h, sstate)
        mix = 0.5 * (p["beta_attn"] * rms_norm(a, jnp.ones_like(
            p["beta_attn"]), cfg.norm_eps) + p["beta_ssm"] * rms_norm(
            s, jnp.ones_like(p["beta_ssm"]), cfg.norm_eps))
        x = x + mix
    else:
        x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h, group_size=x.shape[0])
        x = x + y
    else:
        x = x + ffn(p["ffn"], h)
    return x, kv_stack, new_state


# ---------------------------------------------------------------------------
# stacked-cache prefill (carry-based)
# ---------------------------------------------------------------------------
def block_prefill_stacked(cfg: ModelConfig, p, w_h, x: jax.Array,
                          kv_stack, lead, kind: str, pos, *,
                          img: Optional[jax.Array] = None):
    """Prefill analogue of block_decode_stacked: the freshly computed
    K/V/code rows are written straight into the stacked cache (one
    in-place slice write per layer); attention runs on the fresh
    projections, never re-reading the cache. SSM final states are
    returned per layer (scan ys); cross layers return their (static)
    image KV. Returns (x, kv_stack, aux) where aux is the SSM state or
    the cross KV."""
    if kind == "cross":
        y, ckv = block_prefill(cfg, p, w_h, x, None, kind, pos, img=img)
        return y, kv_stack, ckv
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "ssm":
        y, state = ssm_mod.ssm_forward(cfg, p["ssm"], h,
                                       return_state=True)
        return x + y, kv_stack, state

    from repro.kernels import ops as kops

    if _is_mla(cfg):
        q, k, v, ckv, krope, codes = attn.mla_prefill_parts(
            cfg, p["attn"], w_h, h, pos)
        if kv_stack.codes is None:
            codes = None
        kv_stack = MLACache(
            ckv=_stack_append(kv_stack.ckv, ckv, lead, pos),
            krope=_stack_append(kv_stack.krope, krope, lead, pos),
            codes=None if codes is None else _stack_append(
                kv_stack.codes, codes, lead, pos))
        out = kops.flash_attention(q, k, v, causal=True)
        a = out.reshape(*x.shape[:2], -1) @ p["attn"]["wo"]
    else:
        q, k, v, codes = attn.gqa_prefill_parts(cfg, p["attn"], w_h, h,
                                                pos)
        if kv_stack.codes is None:
            codes = None
        kv_stack = LayerKVCache(
            k=_stack_append(kv_stack.k, k, lead, pos),
            v=_stack_append(kv_stack.v, v, lead, pos),
            codes=None if codes is None else _stack_append(
                kv_stack.codes, codes, lead, pos))
        out = kops.flash_attention(q, k, v, causal=True,
                                   window=cfg.sliding_window)
        a = out.reshape(*x.shape[:2], -1) @ p["attn"]["wo"]

    state = None
    if kind == "hybrid":
        s, state = ssm_mod.ssm_forward(cfg, p["ssm"], h,
                                       return_state=True)
        mix = 0.5 * (p["beta_attn"] * rms_norm(a, jnp.ones_like(
            p["beta_attn"]), cfg.norm_eps) + p["beta_ssm"] * rms_norm(
            s, jnp.ones_like(p["beta_ssm"]), cfg.norm_eps))
        x = x + mix
    else:
        x = x + a
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h2)
        x = x + y
    else:
        x = x + ffn(p["ffn"], h2)
    return x, kv_stack, state


# ---------------------------------------------------------------------------
# view-typed serving paths (cache views; dense/moe attention families)
# ---------------------------------------------------------------------------
def init_block_pool(cfg: ModelConfig, num_pages: int, page_size: int):
    """One layer's shared page pool (KV+codes paged together)."""
    from repro.core.paged_cache import (init_paged_kv_pool,
                                        init_paged_mla_pool)
    dtype = jnp.dtype(cfg.dtype)
    rbit = cfg.hata.rbit if cfg.hata.enabled else 0
    if _is_mla(cfg):
        return init_paged_mla_pool(num_pages, page_size,
                                   cfg.mla.kv_lora_rank,
                                   cfg.mla.qk_rope_dim, rbit=rbit,
                                   dtype=dtype)
    return init_paged_kv_pool(num_pages, page_size, cfg.n_kv_heads,
                              cfg.head_dim, rbit=rbit, dtype=dtype)


def init_offload_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                      pipeline=None):
    """One layer's *tiered* pool: hash codes HBM-resident, K/V (or
    latent) rows in host memory. Requires HATA — without codes to score
    on-device, every decode would stream the whole cache over PCIe."""
    from repro.core.offload import (init_offloaded_kv_pool,
                                    init_offloaded_mla_pool)
    assert cfg.hata.enabled, \
        f"{cfg.name}: the offload tier needs HATA hash codes to score " \
        "on-device (hata.enabled=False would make every decode stream " \
        "the full cache over PCIe)"
    dtype = jnp.dtype(cfg.dtype)
    if _is_mla(cfg):
        return init_offloaded_mla_pool(num_pages, page_size,
                                       cfg.mla.kv_lora_rank,
                                       cfg.mla.qk_rope_dim,
                                       rbit=cfg.hata.rbit, dtype=dtype,
                                       pipeline=pipeline)
    return init_offloaded_kv_pool(num_pages, page_size, cfg.n_kv_heads,
                                  cfg.head_dim, rbit=cfg.hata.rbit,
                                  dtype=dtype, pipeline=pipeline)


def block_prefill_chunk(cfg: ModelConfig, p, w_h, x: jax.Array, view,
                        ctx: jax.Array):
    """One chunk of a chunked prefill through one block, over any cache
    view (``PagedView``/``PagedMLAView`` in the paged engine — the
    block-table flash-prefill kernel attends over the page pool in
    place; ``Contiguous*View`` works identically for chunked prefill on
    dense caches). x: (1, C, D) at absolute positions [ctx, ctx + C);
    traced ``ctx``: one compiled chunk shape. Attention families only
    (dense/moe, GQA or MLA)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if _is_mla(cfg):
        a, view = attn.mla_prefill_chunk(cfg, p["attn"], w_h, h, view,
                                         ctx)
    else:
        a, view = attn.gqa_prefill_chunk(cfg, p["attn"], w_h, h, view,
                                         ctx)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + ffn(p["ffn"], h)
    return x, view


def block_verify_chunk(cfg: ModelConfig, p, w_h, x: jax.Array, view,
                       ctx: jax.Array, use_hata, *,
                       layer: Optional[int] = None):
    """Speculative verify through one block: chunk-shaped projections +
    per-row appends (as :func:`block_prefill_chunk`'s per-row branch),
    but DECODE-path attention per position — dense or hash top-k per
    the layer's HATA flag — so verify logits are bit-identical to the
    sequential decode the wave replaces. x: (B, C, D) at per-row
    absolute positions [ctx_b, ctx_b + C)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if _is_mla(cfg):
        a, view = attn.mla_verify_chunk(cfg, p["attn"], w_h, h, view,
                                        ctx, use_hata, layer)
    else:
        a, view = attn.gqa_verify_chunk(cfg, p["attn"], w_h, h, view,
                                        ctx, use_hata, layer)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + ffn(p["ffn"], h)
    return x, view


# ---------------------------------------------------------------------------
# decode (one token; Alg. 3)
# ---------------------------------------------------------------------------
def block_decode(cfg: ModelConfig, p, w_h, x: jax.Array, cache,
                 kind: str, pos, use_hata, *,
                 cross_kv: Optional[Tuple] = None,
                 layer: Optional[int] = None):
    if kind == "ssm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, state = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache)
        return x + y, state
    if kind == "cross":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        k, v = cross_kv
        x = x + attn.cross_attend(cfg, p["attn"], h, k, v)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + jnp.tanh(p["attn"]["gate_ffn"]) * ffn(p["ffn"], h)
        return x, cache
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "hybrid":
        kv, sstate = cache
        a, kv = _attn_decode(cfg, p["attn"], w_h, h, kv, pos, use_hata,
                             layer)
        s, sstate = ssm_mod.ssm_decode(cfg, p["ssm"], h, sstate)
        mix = 0.5 * (p["beta_attn"] * rms_norm(a, jnp.ones_like(
            p["beta_attn"]), cfg.norm_eps) + p["beta_ssm"] * rms_norm(
            s, jnp.ones_like(p["beta_ssm"]), cfg.norm_eps))
        x = x + mix
        cache = (kv, sstate)
    else:
        a, cache = _attn_decode(cfg, p["attn"], w_h, h, cache, pos,
                                use_hata, layer)
        x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], h, group_size=x.shape[0])
        x = x + y
    else:
        x = x + ffn(p["ffn"], h)
    return x, cache
