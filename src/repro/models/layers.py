"""Shared building blocks: norms, RoPE, SwiGLU, embeddings, chunked CE."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def init_linear(key, d_in: int, d_out: int, dtype, *,
                scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (partial-rotary capable)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float,
               partial: float = 1.0) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * partial)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               partial: float = 1.0) -> jax.Array:
    """x: (..., S, H, d) — the sequence axis must be third-from-last.
    positions: (S,) absolute positions. Shared/rope-only streams (MLA
    k_rope) pass a singleton head axis."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta, partial)
    rot = inv.shape[0] * 2
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]  # (S, r/2)
    bshape = [1] * x.ndim
    bshape[-3] = positions.shape[0]
    bshape[-1] = rot // 2
    cos = jnp.cos(ang).reshape(bshape)
    sin = jnp.sin(ang).reshape(bshape)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(*x.shape[:-1], rot)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------
def init_ffn(key, d_model: int, d_ff: int, dtype) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": init_linear(k1, d_model, d_ff, dtype),
            "wu": init_linear(k2, d_model, d_ff, dtype),
            "wd": init_linear(k3, d_ff, d_model, dtype)}


def ffn(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wi"]) * (x @ p["wu"])
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes full (B, S, V) logits)
# ---------------------------------------------------------------------------
def chunked_ce_loss(x: jax.Array, w_head: jax.Array, labels: jax.Array,
                    mask: Optional[jax.Array] = None, chunk: int = 512,
                    n_vocab: Optional[int] = None) -> jax.Array:
    """x: (B, S, D) final hidden, w_head: (D, Vp), labels: (B, S) int32.

    Scans over S chunks so peak logits memory is (B, chunk, Vp) — the
    405B train shape has Vp=128k where full logits would be GiBs/device.
    ``n_vocab``: real vocab size; columns >= n_vocab (shard padding) are
    excluded from the softmax.
    """
    b, s, d = x.shape
    vp = w_head.shape[1]
    chunk = min(chunk, s)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s += pad
    n = s // chunk
    xc = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.astype(jnp.float32).reshape(b, n, chunk), 1, 0)

    def step(carry, xs):
        tot, cnt = carry
        xi, li, mi = xs
        logits = (xi @ w_head).astype(jnp.float32)           # (B, c, Vp)
        if n_vocab is not None and n_vocab < vp:
            dead = jnp.arange(vp) >= n_vocab
            logits = jnp.where(dead[None, None], -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
