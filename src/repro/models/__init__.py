"""Model zoo: one :class:`~repro.models.transformer.Model` serves all
12 configs (10 assigned architectures + the paper's two eval models)."""
from repro.models.transformer import Model

__all__ = ["Model"]
