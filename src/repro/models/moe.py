"""Mixture-of-Experts FFN (Mixtral 8x22B, DeepSeek-V2-Lite).

GShard-style grouped-capacity dispatch: tokens are split into groups of
``group_size``; inside each group, one-hot dispatch/combine einsums route
tokens into per-expert capacity buffers. With group_size ~512 the
dispatch matmul costs 2·group·E·C·d ≈ 0.04% of expert FLOPs (napkin math
in DESIGN.md) while staying a pure-einsum graph that GSPMD partitions
cleanly: expert buffers (E, C, d) shard E over the ``model`` axis
(expert parallelism -> XLA all-to-all) or C/d_ff over ``model``
(intra-expert TP for Mixtral's 8 < 16 experts).

Router conventions:
  * Mixtral: softmax over the top-k logits (renormalized).
  * DeepSeek-V2: softmax over all experts, weights NOT renormalized,
    plus 2 always-on shared experts and a dense first layer.
Aux loss: Switch-style load-balance loss, returned for the trainer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import ffn, init_ffn, init_linear


def moe_init(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    e = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": init_linear(ks[0], d, e.n_experts, jnp.float32),
        # experts stacked: (E, d, d_ff) / (E, d_ff, d)
        "wi": jax.vmap(lambda k_: init_linear(k_, d, e.d_ff_expert, dtype)
                       )(jax.random.split(ks[1], e.n_experts)),
        "wu": jax.vmap(lambda k_: init_linear(k_, d, e.d_ff_expert, dtype)
                       )(jax.random.split(ks[2], e.n_experts)),
        "wd": jax.vmap(lambda k_: init_linear(k_, e.d_ff_expert, d, dtype)
                       )(jax.random.split(ks[3], e.n_experts)),
    }
    if e.n_shared_experts:
        p["shared"] = init_ffn(ks[4], d,
                               e.d_ff_expert * e.n_shared_experts, dtype)
    return p


def _router(e: MoEConfig, logits: jax.Array):
    """logits: (T, E) f32 -> (weights (T, k), experts (T, k), probs)."""
    probs = jax.nn.softmax(logits, axis=-1)
    if e.parallelism == "tp" or e.n_shared_experts == 0:
        # Mixtral: softmax over selected logits
        top_logits, experts = jax.lax.top_k(logits, e.top_k)
        weights = jax.nn.softmax(top_logits, axis=-1)
    else:
        # DeepSeek: global softmax, no renorm
        weights, experts = jax.lax.top_k(probs, e.top_k)
    return weights, experts, probs


def load_balance_loss(probs: jax.Array, experts: jax.Array,
                      n_experts: int) -> jax.Array:
    """Switch aux loss: E * Σ_e f_e · P_e."""
    onehot = jax.nn.one_hot(experts, n_experts)         # (T, k, E)
    frac = onehot.sum((0, 1)) / (experts.shape[0] * experts.shape[1])
    mean_p = probs.mean(0)
    return n_experts * jnp.sum(frac * mean_p)


def moe_ffn(cfg: ModelConfig, p, x: jax.Array, *, group_size: int = 512,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Grouped-capacity routing."""
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gs = min(group_size, t)
    assert t % gs == 0, (t, gs)
    n_groups = t // gs
    cap = max(1, int(gs * e.top_k / e.n_experts * e.capacity_factor))

    logits = (xf.astype(jnp.float32) @ p["router"])     # (T, E)
    weights, experts, probs = _router(e, logits)
    aux = load_balance_loss(probs, experts, e.n_experts)

    xg = xf.reshape(n_groups, gs, d)
    wg = weights.reshape(n_groups, gs, e.top_k)
    eg = experts.reshape(n_groups, gs, e.top_k)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(eg, e.n_experts, dtype=jnp.int32)  # (g,t,k,E)
    # flatten the k choices into the token axis for a single cumsum:
    oh_flat = onehot.reshape(n_groups, gs * e.top_k, e.n_experts)
    pos_in_e = jnp.cumsum(oh_flat, axis=1) - 1               # (g, t*k, E)
    pos = jnp.sum(pos_in_e * oh_flat, axis=-1)               # (g, t*k)
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)
    eg_flat = eg.reshape(n_groups, gs * e.top_k)
    wg_flat = jnp.where(keep, wg.reshape(n_groups, gs * e.top_k), 0.0)

    # dispatch one-hot: (g, t*k, E, C)
    disp = (jax.nn.one_hot(eg_flat, e.n_experts, dtype=xf.dtype)
            [..., None] * jax.nn.one_hot(pos, cap, dtype=xf.dtype)
            [..., None, :]) * keep[..., None, None].astype(xf.dtype)
    # token features repeated over the k choices:
    xrep = jnp.repeat(xg, e.top_k, axis=1)                   # (g, t*k, d)
    expert_in = jnp.einsum("gtec,gtd->gecd", disp, xrep)     # (g,E,C,d)

    # batched expert SwiGLU over all groups at once: (E, g*C, d)
    ein = jnp.moveaxis(expert_in, 1, 0).reshape(e.n_experts,
                                                n_groups * cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, p["wi"])) \
        * jnp.einsum("ecd,edf->ecf", ein, p["wu"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    eout = jnp.moveaxis(eout.reshape(e.n_experts, n_groups, cap, d), 0, 1)

    combine = disp * wg_flat[..., None, None].astype(xf.dtype)
    yrep = jnp.einsum("gtec,gecd->gtd", combine, eout)       # (g, t*k, d)
    y = yrep.reshape(n_groups, gs, e.top_k, d).sum(2)
    y = y.reshape(b, s, d)

    if e.n_shared_experts:
        y = y + ffn(p["shared"], x)
    return y.astype(x.dtype), aux
