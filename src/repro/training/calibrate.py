"""Recall-vs-budget calibration on held-out harvests.

Sweeps hash-selection recall per (layer, kv-head) over a ladder of
candidate budgets, then emits the persisted per-layer budget table
(:mod:`repro.core.budgets` schema, version 1) plus the recall baseline
JSON the weekly CI gate compares against.

Budget choice is a JOINT allocation across layers, not a per-layer
threshold: minimize the total budget subject to the summed recall
staying >= the all-layers-at-global-k baseline (greedy marginal-recall
ascent from the ladder floor, then down-step / pairwise-exchange
mop-up). By construction the emitted table's mean recall is >= the
global-k mean recall at a mean budget <= the global k — strictly lower
whenever the layers' recall-vs-budget slopes differ enough for an
improving exchange. With ``target_recall`` given, the old independent
per-layer semantics apply instead (smallest budget reaching the bar).
This module is — with ``core/budgets.py`` — one of the two sanctioned
``hcfg.budget(...)`` call sites (CI grep-guards the rest of the tree).
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import hash_weights as hwt
from repro.core.topk import selection_recall
from repro.kernels import ops
from repro.models.transformer import Model
from repro.training import harvest, trainer


def _head_recall_curve(qh, kh, w_h, hi: int, budgets: Sequence[int],
                       rbit: int) -> list:
    """Mean recall at each budget for one kv head, all batch rows.

    Codes and exact scores are computed once; only the top-k cutoff
    varies across the sweep.
    """
    b, s, h, d = qh.shape
    g = h // kh.shape[2]
    w = hwt.head_slice(w_h, hi)
    per_budget = [[] for _ in budgets]
    for bi in range(b):
        qs = jnp.asarray(qh[bi, s // 2:, hi * g:(hi + 1) * g])
        qs = qs.reshape(-1, d).astype(jnp.float32)
        ks = jnp.asarray(kh[bi, :, hi]).astype(jnp.float32)
        true = qs @ ks.T
        qc = ops.hash_encode(qs, w)
        kc = ops.hash_encode(ks, w)
        x = jnp.bitwise_xor(qc[:, None, :], kc[None, :, :])
        est = rbit - jnp.sum(
            jax.lax.population_count(x).astype(jnp.int32), axis=-1)
        est = est.astype(jnp.float32)
        for j, k in enumerate(budgets):
            per_budget[j].append(
                float(selection_recall(est, true, k).mean()))
    return [sum(v) / len(v) for v in per_budget]


def recall_vs_budget(model: Model, params, batch: Dict,
                     budgets: Sequence[int], *,
                     layers: Optional[Sequence[int]] = None,
                     weights: Optional[Dict[int, object]] = None,
                     ) -> Dict[int, Dict]:
    """{layer: {"budgets", "mean", "min_head", "head": {hi: [...]}}}.

    ``weights`` overrides the params tree's hash weights per layer
    (e.g. freshly trained, not yet installed).
    """
    cfg = model.cfg
    if layers is None:
        layers = [l for l in harvest.self_attention_layers(model)
                  if l >= cfg.hata.dense_layers]
    held = harvest.harvest_all_layers(model, params, batch, layers=layers)
    out: Dict[int, Dict] = {}
    for l in layers:
        qh, kh = held[l]
        w = (weights or {}).get(l)
        if w is None:
            w = trainer.layer_hash_weights(model, params, l)
        if w is None:
            continue
        rbit = hwt.rbit_of(w)
        h_kv = kh.shape[2]
        head = {hi: _head_recall_curve(qh, kh, w, hi, budgets, rbit)
                for hi in range(h_kv)}
        mean = [sum(head[hi][j] for hi in head) / len(head)
                for j in range(len(budgets))]
        min_head = [min(head[hi][j] for hi in head)
                    for j in range(len(budgets))]
        out[l] = {"budgets": list(budgets), "mean": mean,
                  "min_head": min_head, "head": head}
    return out


def _candidate_budgets(global_k: int, ctx: int) -> list:
    ks = {max(2, global_k // 8), max(2, global_k // 4),
          max(2, global_k // 2), max(2, (3 * global_k) // 4),
          global_k, min(ctx, 2 * global_k)}
    # unit steps around the global k: that's where exchanges happen
    ks |= {k for k in range(max(2, global_k - 8), global_k + 9)
           if k <= ctx}
    return sorted(k for k in ks if 0 < k <= ctx)


def _allocate(layer_recs: Dict[int, list], budgets: Sequence[int],
              gi: int) -> Dict[int, int]:
    """Joint allocation: per-layer ladder indices minimizing total
    budget s.t. sum of recalls >= sum of recalls at ``budgets[gi]``.

    Greedy marginal-(recall gain / budget cost) ascent from the ladder
    floor, then mop-up down-steps and pairwise up/down exchanges that
    shed budget without dropping the summed recall below the baseline.
    Falls back to all-global (always feasible) if ascent stalls short.
    """
    layers = sorted(layer_recs)
    target = sum(layer_recs[l][gi] for l in layers)
    idx = {l: 0 for l in layers}

    def total_recall():
        return sum(layer_recs[l][idx[l]] for l in layers)

    def total_budget():
        return sum(budgets[idx[l]] for l in layers)

    while total_recall() < target - 1e-12:
        best, best_ratio = None, 0.0
        for l in layers:
            i = idx[l]
            for j in range(i + 1, len(budgets)):
                gain = layer_recs[l][j] - layer_recs[l][i]
                cost = budgets[j] - budgets[i]
                if gain > 0 and gain / cost > best_ratio:
                    best, best_ratio = (l, j), gain / cost
        if best is None:
            idx = {l: gi for l in layers}     # always feasible
            break
        idx[best[0]] = best[1]
    # mop-up: single down-steps, then budget-shedding exchanges
    improved = True
    while improved:
        improved = False
        for l in layers:
            while idx[l] > 0:
                trial = {**idx, l: idx[l] - 1}
                if sum(layer_recs[m][trial[m]] for m in layers) \
                        >= target - 1e-12:
                    idx = trial
                    improved = True
                else:
                    break
        for lu in layers:
            for ld in layers:
                if lu == ld or idx[lu] + 1 >= len(budgets) or idx[ld] == 0:
                    continue
                trial = {**idx, lu: idx[lu] + 1, ld: idx[ld] - 1}
                tb = sum(budgets[trial[m]] for m in layers)
                tr = sum(layer_recs[m][trial[m]] for m in layers)
                if tr < target - 1e-12:
                    continue
                # accept budget-shedding moves, or equal-budget moves
                # that bank recall for a later down-step
                if tb < total_budget() or (tb == total_budget()
                                           and tr > total_recall() + 1e-12):
                    idx = trial
                    improved = True
    if total_budget() > len(layers) * budgets[gi]:
        idx = {l: gi for l in layers}         # never exceed global
    return idx


def calibrate_budget_table(model: Model, params, batch: Dict, *,
                           layers: Optional[Sequence[int]] = None,
                           budgets: Optional[Sequence[int]] = None,
                           weights: Optional[Dict[int, object]] = None,
                           target_recall: Optional[float] = None,
                           ) -> tuple:
    """Sweep -> choose per-layer budgets -> (table_obj, baseline_obj).

    ``table_obj`` is a version-1 ``core.budgets`` table: each entry
    carries ``budget_min = k`` (the chosen budget — the floor pins it
    at the calibration context) and ``budget_frac = k/ctx`` so it
    scales to longer contexts. Dense layers are never emitted.
    ``baseline_obj`` records the mean recall/budget the weekly CI gate
    checks regressions against.
    """
    cfg = model.cfg
    hcfg = cfg.hata
    ctx = int(batch["tokens"].shape[1])
    global_k = hcfg.budget(ctx)      # sanctioned: this IS the calibrator
    if budgets is None:
        budgets = _candidate_budgets(global_k, ctx)
    budgets = sorted(set(int(k) for k in budgets) | {min(global_k, ctx)})
    curves = recall_vs_budget(model, params, batch, budgets,
                              layers=layers, weights=weights)
    gi = budgets.index(min(global_k, ctx))
    if target_recall is None:
        alloc = _allocate({l: curves[l]["mean"] for l in curves},
                          budgets, gi)
    else:
        alloc = {}
        for l, c in curves.items():
            chosen = gi
            for j in range(len(budgets)):
                if c["mean"][j] >= target_recall - 1e-9:
                    chosen = j
                    break
            alloc[l] = chosen
    entries = []
    baseline_layers = {}
    for l in sorted(curves):
        c = curves[l]
        chosen = alloc[l]
        k = budgets[chosen]
        hr = {str(hi): round(min(1.0, max(0.0, c["head"][hi][chosen])), 6)
              for hi in c["head"]}
        entries.append({
            "layer": l,
            "budget_frac": round(min(1.0, max(k / ctx, 1e-6)), 6),
            "budget_min": k,
            "budget_max": max(k, hcfg.budget_max),
            "head_recall": hr,
        })
        baseline_layers[str(l)] = {"budget": k,
                                   "recall": round(c["mean"][chosen], 6)}
    n_kv_heads = 1 if cfg.mla is not None else cfg.n_kv_heads
    table = {
        "version": 1,
        "model": cfg.name,
        "n_layers": cfg.n_layers,
        "n_kv_heads": n_kv_heads,
        "layers": entries,
    }
    n = max(1, len(baseline_layers))
    baseline = {
        "context_len": ctx,
        "global_budget": global_k,
        "mean_budget": round(sum(v["budget"]
                                 for v in baseline_layers.values()) / n, 3),
        "mean_recall": round(sum(v["recall"]
                                 for v in baseline_layers.values()) / n, 6),
        "layers": baseline_layers,
    }
    return table, baseline


def write_json(path: str, obj) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=False)
        f.write("\n")
