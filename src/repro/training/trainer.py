"""Per-head-vmapped hash trainers + held-out recall + params install.

Both hash forms train against the same exact-top-k teacher triplets
(:mod:`repro.training.harvest`):

- linear (paper Eq. 9): ``core.hashing.train_hash_weights_per_head`` —
  a jitted scan of SGD steps, vmapped over kv heads.
- non-linear (Spotlight-style 2-layer MLP before sign):
  ``core.hashing.train_mlp_hash_weights_per_head`` — same harness over
  the dict pytree of core/hash_weights.py.

Held-out recall averages over ALL G query heads of every kv group and
every batch row (the old driver scored only head ``hi*g`` of batch 0).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HataConfig
from repro.core import hash_weights as hwt
from repro.core import hashing
from repro.models.transformer import Model
from repro.training import harvest


@dataclasses.dataclass
class LayerMetrics:
    layer: int
    recall_trained: float
    recall_seed: float
    recall_lsh: float
    budget: int
    rbit: int


def heldout_recall(qh: np.ndarray, kh: np.ndarray, w_h, budget: int, *,
                   rbit: int) -> float:
    """Mean hash-top-k recall over ALL heads/rows of a held-out batch.

    qh: (B, S, H, d), kh: (B, S, H_kv, d); w_h: stacked per-head
    weights — (H_kv, d, rbit) or the MLP dict with leading H_kv axis.
    Queries are the second-half positions of each row, scored against
    that row's own causal key set, for every query head in the kv
    group (not just the group's first head).
    """
    per_head = heldout_recall_per_head(qh, kh, w_h, budget, rbit=rbit)
    return float(np.mean(per_head))


def heldout_recall_per_head(qh: np.ndarray, kh: np.ndarray, w_h,
                            budget: int, *, rbit: int) -> List[float]:
    """Per-kv-head mean recall; see :func:`heldout_recall`."""
    b, s, h, d = qh.shape
    h_kv = kh.shape[2]
    g = h // h_kv
    out = []
    for hi in range(h_kv):
        w = hwt.head_slice(w_h, hi)
        recs = []
        for bi in range(b):
            qs = jnp.asarray(qh[bi, s // 2:, hi * g:(hi + 1) * g])
            qs = qs.reshape(-1, d)                   # all G heads
            ks = jnp.asarray(kh[bi, :, hi])
            recs.append(hashing.hash_topk_recall(qs, ks, w, budget,
                                                 rbit=rbit).mean())
        out.append(float(jnp.mean(jnp.stack(recs))))
    return out


def layer_hash_weights(model: Model, params, layer: int):
    """The params tree's (seed or trained) hash weights of one layer."""
    if layer < model.n_pre:
        return params["hash_pre"][layer]
    j = layer - model.n_pre
    hs = params.get("hash_stack")
    if hs is None:
        return None
    return jax.tree.map(lambda t: t[j], hs)


def install_hash_weights(model: Model, params,
                         trained: Dict[int, object]):
    """Write trained per-layer weights into hash_stack / hash_pre.

    Works for both weight forms: ``jax.tree.map`` pairs the stacked
    leaves with the per-layer leaves (a plain array is a single leaf).
    Returns the updated params dict (hash_stack replaced functionally;
    hash_pre entries replaced in a copied list).
    """
    params = dict(params)
    if "hash_pre" in params:
        params["hash_pre"] = list(params["hash_pre"])
    hs = params.get("hash_stack")
    for layer, w in trained.items():
        if layer < model.n_pre:
            params["hash_pre"][layer] = w
            continue
        j = layer - model.n_pre
        if hs is None or not 0 <= j < model.n_stack:
            continue
        hs = jax.tree.map(lambda stk, wl: stk.at[j].set(wl), hs, w)
    params["hash_stack"] = hs
    return params


def _triplet_recall(w, q: jax.Array, k: jax.Array, rbit: int) -> float:
    """Selection recall on triplets: hash-top-k of each query's key set
    vs exact-top-k. q: (N, d), k: (N, M, d)."""
    from repro.core.topk import selection_recall
    from repro.kernels import ops
    n, m, d = k.shape
    qc = ops.hash_encode(q, w)
    kc = ops.hash_encode(k.reshape(n * m, d), w).reshape(n, m, -1)
    x = jax.lax.population_count(jnp.bitwise_xor(qc[:, None, :], kc))
    est = (rbit - jnp.sum(x.astype(jnp.int32), -1)).astype(jnp.float32)
    true = jnp.einsum("nd,nmd->nm", q.astype(jnp.float32),
                      k.astype(jnp.float32))
    budget = max(1, m // 4)
    return float(selection_recall(est, true, budget).mean())


def train_layer(dataset: Tuple[np.ndarray, np.ndarray, np.ndarray], *,
                rbit: int, hcfg: HataConfig, hidden: int = 0,
                epochs: int = 15, iters: int = 20, seed: int = 0,
                heldout: Optional[Tuple[np.ndarray, np.ndarray]] = None):
    """Train one layer's per-head hash weights on harvested triplets.

    dataset: (q (H_kv,N,d), k (H_kv,N,M,d), s (H_kv,N,M)).
    hidden=0 -> linear Eq. 9 weights (H_kv, d, rbit); hidden>0 -> the
    MLP dict form. With ``hidden == 2*rbit`` the MLP warm-starts as an
    exact embedding of the linear hash trained with the SAME key (so
    it starts bit-identical to what the linear run would produce —
    :func:`repro.core.hashing.mlp_warm_start`), fine-tunes at a low
    lr, and keeps — per head — whichever of {warm start, fine-tuned}
    selects better. Selection uses ``heldout`` (the calibration
    harvest ``(q (B,S,H,d), k (B,S,H_kv,d))``) when given, else a 1/4
    validation split of the triplets; ties keep the warm start, so the
    MLP never regresses below the linear hash it embeds.
    """
    q, k, s = (jnp.asarray(a) for a in dataset)
    key = jax.random.PRNGKey(seed)
    if not hidden:
        return hashing.train_hash_weights_per_head(
            key, q, k, s, rbit=rbit, hcfg=hcfg, epochs=epochs,
            iters=iters)
    if hidden != 2 * rbit:
        return hashing.train_mlp_hash_weights_per_head(
            key, q, k, s, rbit=rbit, hidden=hidden, hcfg=hcfg,
            epochs=epochs, iters=iters)
    # same key as the linear path: warm == the linear run, bit-exact
    w_lin = hashing.train_hash_weights_per_head(
        key, q, k, s, rbit=rbit, hcfg=hcfg, epochs=epochs, iters=iters)
    warm = jax.vmap(hashing.mlp_warm_start)(w_lin)
    ft_key = jax.random.fold_in(key, 1)
    n = q.shape[1]
    n_fit = n if heldout is not None else max(1, (3 * n) // 4)
    tuned = hashing.train_mlp_hash_weights_per_head(
        ft_key, q[:, :n_fit], k[:, :n_fit], s[:, :n_fit], rbit=rbit,
        hidden=hidden, hcfg=hcfg, init=warm, epochs=epochs,
        iters=iters, lr=0.01)

    if heldout is not None:
        qh, kh = heldout
        budget = max(4, qh.shape[1] // 10)
        rec_w = heldout_recall_per_head(qh, kh, warm, budget, rbit=rbit)
        rec_t = heldout_recall_per_head(qh, kh, tuned, budget, rbit=rbit)
        better = [t > w for w, t in zip(rec_w, rec_t)]
    else:
        better = []
        for hi in range(q.shape[0]):
            qv, kv = q[hi, n_fit:], k[hi, n_fit:]
            w_w = hwt.head_slice(warm, hi)
            w_t = hwt.head_slice(tuned, hi)
            better.append(_triplet_recall(w_t, qv, kv, rbit)
                          > _triplet_recall(w_w, qv, kv, rbit))
    picked = [hwt.head_slice(tuned if b else warm, hi)
              for hi, b in enumerate(better)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *picked)


def train_model_hashes(model: Model, params, batches: Sequence[Dict], *,
                       layers: Optional[Sequence[int]] = None,
                       rbit: Optional[int] = None, hidden: int = 0,
                       epochs: int = 15, iters: int = 20,
                       n_queries: int = 64, m_keys: int = 64,
                       budget_frac: float = 0.1, seed: int = 0,
                       install: Optional[bool] = None,
                       ) -> Tuple[Dict, Dict[int, object],
                                  List[LayerMetrics]]:
    """End-to-end harvest -> train -> evaluate for a set of layers.

    ``batches[:-1]`` are the training prompts, ``batches[-1]`` is held
    out for recall. Returns (params (with trained weights installed
    when the trained rbit matches the config), {layer: weights},
    [LayerMetrics]). The selecting layers (>= hcfg.dense_layers) are
    trained by default.
    """
    cfg = model.cfg
    rbit = cfg.hata.rbit if rbit is None else rbit
    hcfg = dataclasses.replace(cfg.hata, rbit=rbit)
    if layers is None:
        layers = [l for l in harvest.self_attention_layers(model)
                  if l >= cfg.hata.dense_layers]
    assert len(batches) >= 2, "need >= 2 batches (last one is held out"
    datasets = harvest.build_datasets(
        model, params, batches[:-1], layers, hcfg,
        n_queries=n_queries, m_keys=m_keys, seed=seed)
    held = harvest.harvest_all_layers(model, params, batches[-1],
                                      layers=layers)
    trained: Dict[int, object] = {}
    metrics: List[LayerMetrics] = []
    lsh_key = jax.random.PRNGKey(seed + 1)
    for l in layers:
        # the held-out harvest doubles as the calibration set for the
        # MLP's per-head keep-warm-or-tuned selection
        w = train_layer(datasets[l], rbit=rbit, hcfg=hcfg,
                        hidden=hidden, epochs=epochs, iters=iters,
                        seed=seed + l, heldout=held[l])
        trained[l] = w
        qh, kh = held[l]
        s_len = qh.shape[1]
        budget = max(4, int(budget_frac * s_len))
        rec = heldout_recall(qh, kh, w, budget, rbit=rbit)
        w_seed = layer_hash_weights(model, params, l)
        rec_seed = (heldout_recall(qh, kh, w_seed, budget, rbit=rbit)
                    if w_seed is not None
                    and hwt.rbit_of(w_seed) == rbit else float("nan"))
        d = qh.shape[-1]
        w_lsh = jnp.broadcast_to(
            hashing.random_projection_lsh(lsh_key, d, rbit),
            (kh.shape[2], d, rbit))
        rec_lsh = heldout_recall(qh, kh, w_lsh, budget, rbit=rbit)
        metrics.append(LayerMetrics(layer=l, recall_trained=rec,
                                    recall_seed=rec_seed,
                                    recall_lsh=rec_lsh, budget=budget,
                                    rbit=rbit))
    do_install = install
    if do_install is None:
        do_install = (rbit == cfg.hata.rbit
                      and bool(hidden) == bool(cfg.hata.hash_hidden))
    if do_install:
        params = install_hash_weights(model, params, trained)
    return params, trained, metrics
