"""Hash quality subsystem: harvest -> train -> calibrate -> gate.

Closes the quality loop the paper's "T" (trainable) stands for:

- :mod:`repro.training.harvest` — streams per-layer/per-head
  (q, k, exact-top-k) teacher triplets from prefill runs, ONE forward
  pass per batch for all layers (the old ``data.hash_dataset.harvest_qk``
  re-ran the stack per layer: O(L^2) blocks per batch).
- :mod:`repro.training.trainer` — jit-compiled, per-head-vmapped
  training of the linear Eq. 9 hash and the non-linear MLP variant,
  held-out recall over ALL query heads, and installation of trained
  weights into the params tree.
- :mod:`repro.training.calibrate` — recall-vs-budget sweeps per
  layer/head on held-out data, emitting the persisted budget table
  (``core/budgets.py``) and the committed recall baseline the weekly CI
  gate checks against.

``launch/hash_train.py`` is a thin CLI driver over this package;
``benchmarks/recall_budget_curve.py`` renders the frontier and gates.
"""
from repro.training.harvest import (build_datasets, harvest_all_layers,
                                    self_attention_layers)
from repro.training.trainer import (LayerMetrics, heldout_recall,
                                    install_hash_weights,
                                    layer_hash_weights, train_layer,
                                    train_model_hashes)
from repro.training.calibrate import (calibrate_budget_table,
                                      recall_vs_budget, write_json)

__all__ = [
    "build_datasets", "harvest_all_layers", "self_attention_layers",
    "LayerMetrics", "heldout_recall", "install_hash_weights",
    "layer_hash_weights", "train_layer", "train_model_hashes",
    "calibrate_budget_table", "recall_vs_budget", "write_json",
]
