"""Scalable teacher-label harvester (paper App. B.1).

One forward pass per batch captures the (q, k) pairs of EVERY
self-attention layer — the capture happens right before each block
consumes its pre-norm input, so advancing the residual stream and
harvesting share the same block evaluations. The old
``data.hash_dataset.harvest_qk`` re-ran blocks ``0..layer-1`` for each
layer, i.e. O(L^2) block evaluations per batch; this module does O(L)
and is bit-exact with it per layer (tests/test_hash_training.py).

Teacher labels (exact-top-k structure) come from
``data.hash_dataset.build_triplets``: for each sampled query the causal
keys are scored exactly, the top-10% become linearly decayed positives.
For MLA the captured pair is the *latent-space* (absorbed q, [c_kv ;
k_rope]) — exactly what HashEncode sees at inference.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HataConfig
from repro.data.hash_dataset import build_triplets
from repro.models import attention as attn_mod
from repro.models import blocks as blocks_mod
from repro.models.layers import rms_norm
from repro.models.transformer import Model


def _layer_params(model: Model, params, i: int):
    """(block params, kind) of layer ``i`` — the unrolled order."""
    cfg = model.cfg
    if i < model.n_pre:
        return params["pre"][i], "main"
    j = i - model.n_pre
    if cfg.family == "vlm":
        ce = cfg.vlm.cross_every
        g, r = divmod(j, ce)
        if r == ce - 1:
            return jax.tree.map(lambda t: t[g],
                                params["cross_stack"]), "cross"
        return jax.tree.map(lambda t: t[g][r], params["stack"]), "main"
    return jax.tree.map(lambda t: t[j], params["stack"]), "main"


def self_attention_layers(model: Model) -> List[int]:
    """Indices of the layers that hash-select (the harvest targets)."""
    if model.cfg.attention_free:
        return []
    return [i for i in range(model.cfg.n_layers)
            if _layer_kind(model, i) == "main"]


def _layer_kind(model: Model, i: int) -> str:
    cfg = model.cfg
    if i < model.n_pre:
        return "main"
    if cfg.family == "vlm":
        ce = cfg.vlm.cross_every
        if (i - model.n_pre) % ce == ce - 1:
            return "cross"
    return "main"


def _capture_qk(model: Model, bp, x: jax.Array
                ) -> Tuple[np.ndarray, np.ndarray]:
    """The projection capture at one layer's pre-norm input."""
    cfg = model.cfg
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    positions = jnp.arange(h.shape[1])
    if cfg.mla is not None:
        q_nope, q_rope, ckv, krope = attn_mod._mla_qkv(
            cfg, bp["attn"], h, positions)
        q_lat = jax.vmap(lambda qn, qr: attn_mod._mla_latent_q(
            cfg, bp["attn"], qn, qr), in_axes=1, out_axes=1)(
            q_nope, q_rope)                          # (B, S, H, r+rd)
        k_lat = jnp.concatenate([ckv, krope], -1)[:, :, None, :]
        return (np.asarray(q_lat, np.float32),
                np.asarray(k_lat, np.float32))
    q, k, _ = attn_mod._project_qkv(cfg, bp["attn"], h, positions)
    return np.asarray(q, np.float32), np.asarray(k, np.float32)


def harvest_all_layers(model: Model, params, batch: Dict, *,
                       layers: Optional[Sequence[int]] = None,
                       ) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """One forward pass -> {layer: (q (B,S,H,d), k (B,S,H_kv,d))}.

    ``layers`` restricts the capture set (default: every
    self-attention layer). Bit-exact per layer with the per-layer
    ``harvest_qk`` because the residual stream is advanced by the same
    ``block_train`` evaluations in the same order.
    """
    cfg = model.cfg
    want = set(self_attention_layers(model) if layers is None else layers)
    x = model.embed(params, batch["tokens"])
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(x.dtype) @ params["img_proj"]
    out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    last = max(want) if want else -1
    for i in range(cfg.n_layers):
        if i > last:
            break
        bp, kind = _layer_params(model, params, i)
        if kind == "main" and i in want:
            out[i] = _capture_qk(model, bp, x)
        kind_name = "cross" if kind == "cross" else model.kind
        x, _ = blocks_mod.block_train(cfg, bp, None, x, kind_name,
                                      img=img)
    missing = want - set(out)
    if missing:
        raise ValueError(f"layers {sorted(missing)} are not "
                         "self-attention layers")
    return out


def build_datasets(model: Model, params, batches: Iterable[Dict],
                   layers: Sequence[int], hcfg: HataConfig, *,
                   n_queries: int = 64, m_keys: int = 64, seed: int = 0,
                   ) -> Dict[int, Tuple[np.ndarray, np.ndarray,
                                        np.ndarray]]:
    """Streaming dataset build: per batch, ONE forward pass harvests
    every requested layer, then per-head exact-top-k triplets
    accumulate. Returns {layer: (q (H_kv,N,d), k (H_kv,N,M,d),
    s (H_kv,N,M))} — the shape the per-head-vmapped trainer consumes.
    """
    acc: Dict[int, Dict[int, list]] = {l: {} for l in layers}
    for bi, batch in enumerate(batches):
        caps = harvest_all_layers(model, params, batch, layers=layers)
        for l in layers:
            q, k = caps[l]
            b, s, h, d = q.shape
            h_kv = k.shape[2]
            g = h // h_kv
            qg = q.reshape(b, s, h_kv, g, d)
            for hi in range(h_kv):
                acc[l].setdefault(hi, []).append(
                    build_triplets(qg[:, :, hi], k[:, :, hi], hcfg,
                                   n_queries=n_queries, m_keys=m_keys,
                                   seed=seed + 7919 * bi + hi))
    out = {}
    for l in layers:
        heads = sorted(acc[l])
        qs = np.stack([np.concatenate([t[0] for t in acc[l][hi]])
                       for hi in heads])
        ks = np.stack([np.concatenate([t[1] for t in acc[l][hi]])
                       for hi in heads])
        ls = np.stack([np.concatenate([t[2] for t in acc[l][hi]])
                       for hi in heads])
        out[l] = (qs, ks, ls)
    return out
