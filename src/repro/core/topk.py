"""Top-k selection utilities (single-device semantics).

The distributed two-stage top-k (sequence-sharded caches) lives in
``repro/distributed/collectives.py``; these are the local building
blocks plus reference implementations for its tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """lax.top_k over the last axis -> (values, indices).

    Deterministic: ties resolve to the lowest index (lax.top_k contract),
    so the kernel/oracle/distributed paths agree exactly on integer hash
    scores as long as they see identical score vectors.
    """
    return jax.lax.top_k(scores, k)


def chunked_topk(scores: jax.Array, k: int, *,
                 chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Exact two-stage top-k over the last axis: local top-k per chunk,
    then top-k of the gathered (value, index) candidates.

    The on-device mirror of ``collectives.distributed_topk``: XLA's
    TopK over a long minor axis is the select stage's bottleneck (it
    dominates the whole HATA decode pipeline at S >= 4k), while two
    stages of short top-ks are both cheap. Exact for k <= chunk by the
    usual subset argument, *including* lax.top_k's tie-break contract:
    within a chunk, equal-value candidates keep ascending-index order
    (local tie-break), and the chunk-major candidate layout keeps that
    order globally, so stage 2's stable selection picks the same
    lowest-index winners as a flat lax.top_k. Falls back to flat
    lax.top_k when the axis doesn't chunk evenly or k > chunk.
    """
    n = scores.shape[-1]
    if n % chunk or k > chunk or n <= chunk:
        return jax.lax.top_k(scores, k)
    lead = scores.shape[:-1]
    n_chunks = n // chunk
    local = scores.reshape(*lead, n_chunks, chunk)
    lv, li = jax.lax.top_k(local, k)
    gi = li + (jnp.arange(n_chunks) * chunk)[:, None]
    v, sel = jax.lax.top_k(lv.reshape(*lead, n_chunks * k), k)
    return v, jnp.take_along_axis(gi.reshape(*lead, n_chunks * k), sel,
                                  axis=-1)


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the top-k entries along the last axis."""
    _, idx = topk(scores, k)
    mask = jnp.zeros(scores.shape, jnp.bool_)
    return jnp.put_along_axis(mask, idx, True, axis=-1, inplace=False)


def selection_recall(est_scores: jax.Array, true_scores: jax.Array,
                     k: int) -> jax.Array:
    """|top-k(est) ∩ top-k(true)| / k along the last axis.

    The paper's accuracy results (Tables 1-2) are downstream of exactly
    this quantity: a selector with recall 1.0 reproduces exact top-k
    attention bit-for-bit.
    """
    em = topk_mask(est_scores, k)
    tm = topk_mask(true_scores, k)
    return jnp.sum(em & tm, axis=-1) / k


def two_stage_topk_ref(scores: jax.Array, k: int,
                       n_shards: int) -> jax.Array:
    """Single-device reference of the distributed two-stage top-k.

    scores: (S,) with S divisible by n_shards. Stage 1 takes the local
    top-k of each shard, stage 2 the global top-k of the gathered
    (n_shards * k) candidates. Exact whenever k <= local shard length:
    every global top-k element is in its own shard's local top-k.
    Returns global indices, ascending-sorted for set comparison.
    """
    s = scores.shape[-1]
    local = scores.reshape(n_shards, s // n_shards)
    lv, li = jax.lax.top_k(local, min(k, s // n_shards))
    offs = (jnp.arange(n_shards) * (s // n_shards))[:, None]
    gidx = (li + offs).reshape(-1)
    gval = lv.reshape(-1)
    _, sel = jax.lax.top_k(gval, k)
    return jnp.sort(gidx[sel])
