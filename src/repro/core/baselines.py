"""Baseline top-k attention selectors the paper compares against (§5.1,
Table 5). Each baseline answers the same question as HATA's Hamming
scorer — "which cache rows should this decode step attend to?" — so they
share one interface: estimator scores (or a selection mask) per kv head,
evaluated in benchmarks/recall_accuracy.py and priced by the HBM byte
model in benchmarks/decode_efficiency.py.

Per-kv-head shapes: q (G, d) the query heads sharing the kv head,
keys (S, d) the cache. All scorers return (S,) "bigger = keep".

  exact_scores      exact top-k attention (the upper bound, Table 5 row 2)
  loki_*            low-rank PCA channels (Singhania et al.)
  quest_*           block min/max upper bounds (Tang et al.)
  lsh_scores        random-hyperplane SimHash (MagicPIG's L·K sampling is
                    modeled by its byte cost; selection quality at equal
                    bits is what Fig. 1/8 compare)
  streaming_mask    StreamingLLM sinks+recent (selection is position-only)
  h2o_select        heavy-hitter cumulative attention mass
  snapkv_select     observation-window pooled attention (prefill-time)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Exact top-k (score oracle)
# ---------------------------------------------------------------------------
def exact_scores(q: jax.Array, keys: jax.Array) -> jax.Array:
    """Sum of exact qk scores over the group — what HATA's aggregated
    Hamming score estimates ordinally."""
    return jnp.sum(q.astype(jnp.float32) @ keys.astype(jnp.float32).T,
                   axis=0)


# ---------------------------------------------------------------------------
# Loki (low-rank PCA channels)
# ---------------------------------------------------------------------------
class LokiState(NamedTuple):
    components: jax.Array    # (d, d) PCA basis, decreasing variance
    keys_proj: jax.Array     # (S, r) cached projected keys


def loki_fit(keys: jax.Array, r: int = 32) -> LokiState:
    """Offline PCA of key vectors (Loki uses calibration-set PCA)."""
    kf = keys.astype(jnp.float32)
    mu = kf.mean(0)
    cov = (kf - mu).T @ (kf - mu) / kf.shape[0]
    _, vecs = jnp.linalg.eigh(cov)          # ascending
    comps = vecs[:, ::-1]                   # (d, d) descending variance
    return LokiState(components=comps, keys_proj=kf @ comps[:, :r])


def loki_scores(q: jax.Array, state: LokiState, r: int = 32) -> jax.Array:
    """Approximate group-aggregated scores from the first r channels."""
    qp = q.astype(jnp.float32) @ state.components[:, :r]   # (G, r)
    return jnp.sum(qp @ state.keys_proj[:, :r].T, axis=0)


# ---------------------------------------------------------------------------
# Quest (block-level min/max upper bound)
# ---------------------------------------------------------------------------
class QuestState(NamedTuple):
    kmin: jax.Array          # (n_blocks, d)
    kmax: jax.Array          # (n_blocks, d)


def quest_fit(keys: jax.Array, block: int = 32) -> QuestState:
    s, d = keys.shape
    nb = s // block
    kb = keys[: nb * block].reshape(nb, block, d).astype(jnp.float32)
    return QuestState(kmin=kb.min(1), kmax=kb.max(1))


def quest_scores(q: jax.Array, state: QuestState, block: int = 32,
                 s: int = 0) -> jax.Array:
    """Per-token scores = the containing block's upper bound (so block
    selection == token top-k at block granularity). q: (G, d)."""
    qf = q.astype(jnp.float32)
    ub = jnp.maximum(qf[:, None, :] * state.kmin[None],
                     qf[:, None, :] * state.kmax[None])    # (G, nb, d)
    block_scores = jnp.sum(ub, axis=(0, 2))                # (nb,)
    tok = jnp.repeat(block_scores, block)
    if s and tok.shape[0] < s:   # ragged tail: always keep (recent tokens)
        pad = jnp.full((s - tok.shape[0],), jnp.inf, tok.dtype)
        tok = jnp.concatenate([tok, pad])
    return tok


# ---------------------------------------------------------------------------
# LSH (MagicPIG-style random hyperplanes)
# ---------------------------------------------------------------------------
def lsh_scores(q: jax.Array, key_codes: jax.Array, w_lsh: jax.Array,
               rbit: int) -> jax.Array:
    """Hash match scores with *random* (untrained) projections — same
    scoring path as HATA; the delta to HATA isolates learning-to-hash."""
    from repro.kernels import ops, ref
    qc = ops.hash_encode(q, w_lsh)
    x = jax.lax.population_count(
        jnp.bitwise_xor(qc[:, None, :], key_codes[None, :, :]))
    ham = jnp.sum(x.astype(jnp.int32), axis=(0, 2))
    return q.shape[0] * rbit - ham


# ---------------------------------------------------------------------------
# StreamingLLM (sinks + recency; selection independent of content)
# ---------------------------------------------------------------------------
def streaming_mask(s: int, n_valid, budget: int,
                   sinks: int = 4) -> jax.Array:
    pos = jnp.arange(s)
    recent = budget - sinks
    return (pos < sinks) | ((pos >= n_valid - recent) & (pos < n_valid))


# ---------------------------------------------------------------------------
# H2O (heavy hitters by cumulative attention mass)
# ---------------------------------------------------------------------------
def h2o_select(cum_attn: jax.Array, n_valid, budget: int,
               recent_frac: float = 0.5) -> jax.Array:
    """cum_attn: (S,) accumulated attention prob mass per position.
    Budget split half heavy-hitters / half recent (paper Table 5)."""
    s = cum_attn.shape[0]
    pos = jnp.arange(s)
    n_recent = int(budget * recent_frac)
    recent = (pos >= n_valid - n_recent) & (pos < n_valid)
    hh_scores = jnp.where(recent | (pos >= n_valid), -jnp.inf, cum_attn)
    _, hh_idx = jax.lax.top_k(hh_scores, budget - n_recent)
    mask = jnp.zeros(s, jnp.bool_).at[hh_idx].set(True)
    return mask | recent


# ---------------------------------------------------------------------------
# SnapKV (observation-window pooled attention, prefill-time compression)
# ---------------------------------------------------------------------------
def snapkv_select(q_window: jax.Array, keys: jax.Array, budget: int,
                  kernel: int = 7) -> jax.Array:
    """q_window: (w, d) last-w prefill queries (w=16 in Table 5);
    keys: (S, d). Returns a (S,) keep mask of size<=budget+w."""
    s, d = keys.shape
    w = q_window.shape[0]
    logits = (q_window.astype(jnp.float32) @ keys.astype(jnp.float32).T
              ) * (d ** -0.5)
    qpos = s - w + jnp.arange(w)
    causal = jnp.arange(s)[None, :] <= qpos[:, None]
    probs = jax.nn.softmax(jnp.where(causal, logits, -jnp.inf), axis=-1)
    votes = probs.sum(0)                              # (S,)
    # 1D average pooling (SnapKV's clustering smoothing)
    pad = kernel // 2
    pooled = jnp.convolve(votes, jnp.ones(kernel) / kernel, mode="same")
    pooled = pooled.at[-w:].set(jnp.inf)              # window always kept
    _, idx = jax.lax.top_k(pooled, min(budget, s))
    return jnp.zeros(s, jnp.bool_).at[idx].set(True)


# ---------------------------------------------------------------------------
# Per-step HBM byte model (the efficiency comparison of Fig. 4/5)
# ---------------------------------------------------------------------------
def decode_bytes_per_kv_head(method: str, s: int, d: int, *, budget: int,
                             rbit: int = 128, loki_r: int = 32,
                             quest_block: int = 32, kv_bytes: int = 2,
                             lsh_bits: int = 1500) -> int:
    """HBM bytes one decode step must move per kv head (score + attend).

    This is the quantity HATA's design minimizes; on the memory-bound
    decode roofline, speedup == byte ratio. Dense moves the full K and V;
    estimators move their score operands plus the selected K/V rows.
    """
    kv_row = 2 * d * kv_bytes                     # one K row + one V row
    if method == "dense":
        return s * kv_row
    if method == "exact-topk":
        return s * d * kv_bytes + budget * kv_row  # all K + top-k K/V
    if method == "loki":
        return s * loki_r * kv_bytes + budget * kv_row
    if method == "quest":
        blocks = s // quest_block
        return blocks * 2 * d * kv_bytes + budget * kv_row
    if method == "hata":
        return s * rbit // 8 + budget * kv_row
    if method == "lsh":
        return s * lsh_bits // 8 + budget * kv_row
    if method in ("streaming", "h2o", "snapkv"):
        return budget * kv_row                    # selection is metadata
    raise ValueError(method)
