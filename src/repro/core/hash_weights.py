"""Dual-form hash weights: linear array vs non-linear MLP dict.

The hash projection is either the paper's linear ``W_H`` — a plain
``(H_kv, d, rbit)`` array — or the trained non-linear variant (a small
2-layer MLP before sign, Spotlight-style): a dict pytree

    {"w1": (H_kv, d, hidden), "b1": (H_kv, hidden),
     "w2": (H_kv, hidden, rbit)}

Both forms flow through every entry point (dense, paged, offloaded,
MLA, sequence-parallel) because everything that touches them — stacking
into ``params["hash_stack"]``, per-layer slicing, vmapping over heads,
checkpointing — is pytree-generic. The helpers here replace the two raw
accesses that were not: ``w_h.shape[-1]`` (rbit) and ``w_h[0]`` (the
MLA single-head slice).
"""
from __future__ import annotations

from typing import Any, Union

import jax

HashWeights = Union[jax.Array, dict]


def is_mlp(w_h: HashWeights) -> bool:
    """True for the MLP dict form, False for the linear array."""
    return isinstance(w_h, dict)


def rbit_of(w_h: HashWeights) -> int:
    """Number of hash bits produced by either weight form."""
    if isinstance(w_h, dict):
        return w_h["w2"].shape[-1]
    return w_h.shape[-1]


def head_slice(w_h: HashWeights, i: int) -> HashWeights:
    """Per-head weights: drops the leading H_kv axis of every leaf."""
    return jax.tree.map(lambda t: t[i], w_h)


def head0(w_h: HashWeights) -> HashWeights:
    """The MLA single-stream slice (``w_h[0]`` for the linear form)."""
    return head_slice(w_h, 0)


def tree_equal(a: Any, b: Any) -> bool:
    """Structural + bit-exact value equality of two hash-weight trees."""
    import numpy as np
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    return all(x.shape == y.shape and x.dtype == y.dtype
               and bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(la, lb))
