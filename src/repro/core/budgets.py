"""Recall-calibrated per-layer top-k budgets behind ONE resolver.

The paper uses a single global budget (``HataConfig.budget``: a clamped
fraction of the context). The native-top-k literature — and our own
calibration sweeps (`repro.training.calibrate`) — show sparsity
tolerance varies sharply per layer, so this module adds a persisted
per-layer budget table with the same schema discipline as the kernel
tuning tables (``kernels/runtime.py``): JSON with an explicit version,
exact-key-set validation, and *hard errors* on anything malformed — a
bad table must never silently fall back to the global budget.

Resolution order for the budget of (layer, context):

    installed table entry for the layer  >  ``HataConfig.budget``

``resolve_budget`` is the ONE sanctioned ``hcfg.budget(...)`` call site
outside the calibrator (CI grep-guards this). Paths without a concrete
layer index — scanned layer stacks and the sequence-parallel strategy
hooks, where the budget must be shape-static across layers — pass
``layer=None`` and get the global budget.

Tables install either explicitly (``set_budget_table`` /
``use_budget_table`` — the serving engines take a ``budget_table=``
argument) or via the ``REPRO_BUDGET_TABLE`` env path. Budgets stay
static under jit: the table is read at trace time with python-int
layers.
"""
from __future__ import annotations

import contextlib
import functools
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import HataConfig

ENV_TABLE = "REPRO_BUDGET_TABLE"

_REQUIRED_ENTRY_KEYS = {"layer", "budget_frac", "budget_min", "budget_max"}
_OPTIONAL_ENTRY_KEYS = {"head_recall"}


class BudgetTableError(ValueError):
    """A budget table failed validation.

    Raised for schema violations (missing/unknown keys, bad version),
    unknown layer or head indices, and malformed values. This is a hard
    error by design — a malformed table must never silently fall back
    to the global budget.
    """


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _validate_entry(entry, n_layers: int, n_kv_heads: Optional[int],
                    seen: set, where: str) -> None:
    if not isinstance(entry, dict):
        raise BudgetTableError(f"{where}: entry must be an object, "
                               f"got {type(entry).__name__}")
    keys = set(entry)
    missing = _REQUIRED_ENTRY_KEYS - keys
    unknown = keys - _REQUIRED_ENTRY_KEYS - _OPTIONAL_ENTRY_KEYS
    if missing:
        raise BudgetTableError(f"{where}: missing keys {sorted(missing)}")
    if unknown:
        raise BudgetTableError(f"{where}: unknown keys {sorted(unknown)}")
    layer = entry["layer"]
    if not _is_int(layer) or not 0 <= layer < n_layers:
        raise BudgetTableError(
            f"{where}: unknown layer {layer!r} (table declares "
            f"n_layers={n_layers})")
    if layer in seen:
        raise BudgetTableError(f"{where}: duplicate entry for layer {layer}")
    seen.add(layer)
    frac = entry["budget_frac"]
    if not isinstance(frac, (int, float)) or isinstance(frac, bool) \
            or not 0.0 < float(frac) <= 1.0:
        raise BudgetTableError(
            f"{where}: budget_frac must be in (0, 1], got {frac!r}")
    bmin, bmax = entry["budget_min"], entry["budget_max"]
    for name, v in (("budget_min", bmin), ("budget_max", bmax)):
        if not _is_int(v) or v <= 0:
            raise BudgetTableError(
                f"{where}: {name} must be a positive int, got {v!r}")
    if bmin > bmax:
        raise BudgetTableError(
            f"{where}: budget_min={bmin} > budget_max={bmax}")
    hr = entry.get("head_recall")
    if hr is None:
        return
    if not isinstance(hr, dict):
        raise BudgetTableError(f"{where}: head_recall must be an object")
    for hk, hv in hr.items():
        if not (isinstance(hk, str) and hk.isdigit()):
            raise BudgetTableError(
                f"{where}: head_recall key {hk!r} is not a head index")
        head = int(hk)
        if n_kv_heads is not None and head >= n_kv_heads:
            raise BudgetTableError(
                f"{where}: unknown head {head} (table declares "
                f"n_kv_heads={n_kv_heads})")
        if not isinstance(hv, (int, float)) or isinstance(hv, bool) \
                or not 0.0 <= float(hv) <= 1.0:
            raise BudgetTableError(
                f"{where}: head_recall[{hk}]={hv!r} not a recall in [0, 1]")


@dataclass(frozen=True)
class BudgetTable:
    """Validated per-layer budget overrides.

    ``entries`` maps layer index -> (budget_frac, budget_min,
    budget_max). Layers without an entry fall back to the global
    ``HataConfig.budget``.
    """
    n_layers: int
    entries: tuple                  # ((layer, frac, bmin, bmax), ...)
    model: Optional[str] = None

    @functools.cached_property
    def _by_layer(self) -> Dict[int, tuple]:
        return {e[0]: e for e in self.entries}

    def layers(self):
        return sorted(self._by_layer)

    def budget(self, layer: int, hcfg: HataConfig, context_len: int) -> int:
        """The clamped budget for ``layer`` at ``context_len`` — same
        clamp semantics as ``HataConfig.budget`` with per-layer
        parameters."""
        e = self._by_layer.get(layer)
        if e is None:
            return hcfg.budget(context_len)
        _, frac, bmin, bmax = e
        k = int(context_len * frac)
        k = max(bmin, min(k, bmax))
        return min(k, context_len)


def parse_budget_table(obj, *, source: str = "<table>") -> BudgetTable:
    """Validate a decoded budget-table JSON object. Hard-errors on any
    schema violation (``BudgetTableError``)."""
    if not isinstance(obj, dict):
        raise BudgetTableError(f"{source}: table must be an object")
    if obj.get("version") != 1:
        raise BudgetTableError(
            f"{source}: unsupported version {obj.get('version')!r} "
            "(expected 1)")
    known = {"version", "model", "n_layers", "n_kv_heads", "layers"}
    unknown = set(obj) - known
    if unknown:
        raise BudgetTableError(f"{source}: unknown keys {sorted(unknown)}")
    n_layers = obj.get("n_layers")
    if not _is_int(n_layers) or n_layers <= 0:
        raise BudgetTableError(
            f"{source}: n_layers must be a positive int, got {n_layers!r}")
    n_kv_heads = obj.get("n_kv_heads")
    if n_kv_heads is not None and (not _is_int(n_kv_heads)
                                   or n_kv_heads <= 0):
        raise BudgetTableError(
            f"{source}: n_kv_heads must be a positive int, "
            f"got {n_kv_heads!r}")
    layers = obj.get("layers")
    if not isinstance(layers, list):
        raise BudgetTableError(f"{source}: layers must be a list")
    seen: set = set()
    entries = []
    for i, entry in enumerate(layers):
        _validate_entry(entry, n_layers, n_kv_heads, seen,
                        f"{source}: layers[{i}]")
        entries.append((entry["layer"], float(entry["budget_frac"]),
                        entry["budget_min"], entry["budget_max"]))
    return BudgetTable(n_layers=n_layers, entries=tuple(entries),
                       model=obj.get("model"))


@functools.lru_cache(maxsize=None)
def load_budget_table(path: str) -> BudgetTable:
    try:
        with open(path) as f:
            obj = json.load(f)
    except FileNotFoundError as e:
        raise BudgetTableError(f"budget table not found: {path}") from e
    except json.JSONDecodeError as e:
        raise BudgetTableError(f"{path}: invalid JSON: {e}") from e
    return parse_budget_table(obj, source=path)


def clear_table_cache() -> None:
    load_budget_table.cache_clear()


# ---------------------------------------------------------------------------
# Installation + the one resolver
# ---------------------------------------------------------------------------
_ACTIVE: Optional[BudgetTable] = None


def set_budget_table(table: Optional[BudgetTable]) -> None:
    global _ACTIVE
    assert table is None or isinstance(table, BudgetTable), table
    _ACTIVE = table


def get_budget_table() -> Optional[BudgetTable]:
    """The active table: explicit install wins over the env path."""
    if _ACTIVE is not None:
        return _ACTIVE
    path = os.environ.get(ENV_TABLE)
    if path:
        return load_budget_table(path)
    return None


@contextlib.contextmanager
def use_budget_table(table: Optional[BudgetTable]):
    prev = _ACTIVE
    set_budget_table(table)
    try:
        yield
    finally:
        set_budget_table(prev)


def resolve_budget(hcfg: HataConfig, s_max: int, *,
                   layer: Optional[int] = None,
                   window: Optional[int] = None) -> int:
    """The ONE budget resolution chain: table[layer] > hcfg.budget.

    ``layer=None`` (scanned stacks, SP strategies, analytic estimators)
    always resolves the global budget. A sliding window caps the number
    of attendable rows, and the budget can never exceed the cache.
    """
    table = get_budget_table()
    if table is not None and layer is not None:
        k = table.budget(layer, hcfg, s_max)
    else:
        k = hcfg.budget(s_max)
    if window is not None:
        k = min(k, window)
    return min(k, s_max)
