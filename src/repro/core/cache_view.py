"""Cache views: one addressing API for dense, paged, and sequence-
sharded attention.

HATA's score -> select -> gather discipline is layout-independent: the
Hamming scores are per-row, the fused gather is per-row DMA, and the
chunked-prefill context read is causal at absolute positions. What
*differs* between a contiguous ``(B, S, ...)`` cache, a block-table page
pool and a sequence-sharded slice is purely how rows are addressed — so
the addressing lives here, behind a small pytree protocol, and the model
stack (``models/attention.py`` down to the serving engines and the SP
decode strategy) carries exactly one attend/decode/prefill entry point
per attention family.

Two protocols, three concrete shapes each:

``KVView``  (GQA/MHA)                 ``MLAView`` (latent stream)
  :class:`ContiguousView`               :class:`ContiguousMLAView`
  :class:`PagedView`                    :class:`PagedMLAView`
  :class:`ShardedView`  (wraps either family's local slice)

Every view exposes the same verbs, each bottoming out in the existing
Pallas kernels (``hamming_score_batched/_paged``,
``flash_decode_gathered_batched/_paged``, ``flash_prefill_batched/
_paged`` and the MLA twins) — no view introduces new kernel code:

  ``append(…, pos)``          decode-row write (scalar or (B,) ``pos``)
  ``append_chunk(…, ctx)``    chunked-prefill write at offset ``ctx``
  ``hamming_scores(…)``       masked logical match scores
  ``gather_decode(…)``        fused sparse attend over selected rows
  ``gather_stats(…)``         unnormalized (m, l, o~) flash partials
  ``prefill_attend(…)``       chunk queries over the context in place

Logical/physical convention: selection math (budgets, top-k, validity
masks) always runs in *logical* row space; :class:`PagedView` translates
through its block table only at the append/gather boundary (see
``core/paged_cache.physical_rows``). :class:`ShardedView` adds the
shard's absolute offset on top of its inner view's local rows, so the
sequence-parallel two_stage/local_split modes run over paged pools with
the same ownership-mask stats kernels they use over contiguous shards.

All views are ``register_dataclass`` pytrees: they cross jit/shard_map
boundaries, can be donated (donation reaches the wrapped buffers), and
wrapping is free — no leaf is copied.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import register_dataclass

from repro.core import offload
from repro.core import paged_cache as paged
from repro.core.kvcache import (LayerKVCache, MLACache, append_kv,
                                append_mla)
from repro.kernels import ops

_static = dataclasses.field(metadata=dict(static=True))


def _mask_rows(scores: jax.Array, n_valid, window: Optional[int],
               positions: Optional[jax.Array]) -> jax.Array:
    """Validity + sliding-window mask at (absolute) positions -> -1."""
    from repro.core.hash_attention import mask_scores
    return mask_scores(scores, n_valid, window=window,
                       positions=positions)


# ===========================================================================
# GQA / MHA views
# ===========================================================================
@register_dataclass
@dataclasses.dataclass
class ContiguousView:
    """A plain ``(B, S_max, H_kv, d)`` cache seen through the view API."""
    cache: LayerKVCache

    # -- protocol ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Static logical row capacity (drives the HATA budget)."""
        return self.cache.max_len

    @property
    def has_codes(self) -> bool:
        return self.cache.codes is not None

    def append(self, k: jax.Array, v: jax.Array,
               codes: Optional[jax.Array], pos) -> "ContiguousView":
        return ContiguousView(append_kv(self.cache, k, v, codes, pos))

    # contiguous writes don't distinguish a decode row from a chunk —
    # append_kv handles any (B, S_new, ...) at any offset
    append_chunk = append

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None,
                       positions: Optional[jax.Array] = None) -> jax.Array:
        """(B, H_kv, G, W) q codes -> (B, H_kv, S_log) masked scores
        (invalid / out-of-window rows at -1, the selection floor)."""
        scores = ops.hamming_scores(q_codes, self.cache.codes, rbit=rbit)
        return _mask_rows(scores, n_valid, window, positions)

    def gather_decode(self, q: jax.Array, idx: jax.Array,
                      sel_valid: jax.Array) -> jax.Array:
        """Fused sparse attend over selected *logical* rows.
        q: (B, H, d); idx: (B, H_kv, k); sel_valid: prefix mask."""
        return ops.gather_decode_attention(q, self.cache.k, self.cache.v,
                                           idx, sel_valid=sel_valid,
                                           fused=True)

    def gather_stats(self, q: jax.Array, idx: jax.Array,
                     sel_mask: Optional[jax.Array]):
        """Unnormalized (m, l, o~) partials over selected rows —
        arbitrary ``sel_mask`` (the SP ownership filter)."""
        return ops.gather_decode_stats(q, self.cache.k, self.cache.v,
                                       idx, sel_mask)

    def kv_logical(self) -> Tuple[jax.Array, jax.Array]:
        """The (B, S_log, H_kv, d) logical K/V read (dense fallback /
        XLA reference paths). Free for contiguous caches."""
        return self.cache.k, self.cache.v

    def tile_rows(self, n: int) -> "ContiguousView":
        """READ-ONLY batch tiling for the speculative verify wave:
        request row b becomes rows [b*n, (b+1)*n), one per verify
        position, so all C positions of every slot run as ONE batched
        decode attend — same kernels and dispatch count as a plain
        wave instead of C sequential attends. Appends through a tiled
        view are undefined (each copy would scatter); contiguous
        caches pay a real O(n) copy, the paged views tile only the
        block table."""
        r = lambda a: jnp.repeat(a, n, axis=0)
        return ContiguousView(dataclasses.replace(
            self.cache, k=r(self.cache.k), v=r(self.cache.v),
            codes=(None if self.cache.codes is None
                   else r(self.cache.codes))))

    def prefill_attend(self, q: jax.Array, ctx, *,
                       window: Optional[int] = None) -> jax.Array:
        """Chunk queries (B, C, H, d) at absolute positions
        [ctx, ctx+C) attend causally over the cached context."""
        return ops.chunk_attention(q, self.cache.k, self.cache.v,
                                   q_offset=ctx, window=window)

    def unwrap(self):
        return self.cache


@register_dataclass
@dataclasses.dataclass
class PagedView:
    """A shared page pool + per-request block table, same verbs.

    ``pool``: one layer's ``(P, page, H_kv, ...)`` pool (K/V and hash
    codes paged together); ``block_table``: (B, T) int32 page ids.
    Logical capacity is the table width ``T * page`` — the pool size
    never leaks into selection shapes.
    """
    pool: paged.PagedKVPool
    block_table: jax.Array

    @property
    def capacity(self) -> int:
        return self.block_table.shape[1] * self.pool.page_size

    @property
    def has_codes(self) -> bool:
        return self.pool.codes is not None

    def _phys(self, logical: jax.Array) -> jax.Array:
        return paged.physical_rows(self.block_table, logical,
                                   self.pool.page_size)

    def append(self, k: jax.Array, v: jax.Array,
               codes: Optional[jax.Array], pos) -> "PagedView":
        b = k.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        pool = paged.append_rows_kv(self.pool, k, v, codes,
                                    self._phys(pos))
        return PagedView(pool, self.block_table)

    def append_chunk(self, k: jax.Array, v: jax.Array,
                     codes: Optional[jax.Array], ctx) -> "PagedView":
        pool = paged.append_chunk_kv(self.pool, k, v, codes,
                                     self.block_table, ctx)
        return PagedView(pool, self.block_table)

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None,
                       positions: Optional[jax.Array] = None) -> jax.Array:
        scores = ops.hamming_scores_paged(q_codes, self.pool.codes,
                                          self.block_table, n_valid,
                                          rbit=rbit)
        if window is None and positions is None:
            return scores          # validity already masked in-kernel
        return _mask_rows(scores, n_valid, window, positions)

    def gather_decode(self, q: jax.Array, idx: jax.Array,
                      sel_valid: jax.Array) -> jax.Array:
        return ops.gather_decode_attention_paged(
            q, self.pool.k, self.pool.v, self._phys(idx),
            sel_valid=sel_valid)

    def gather_stats(self, q: jax.Array, idx: jax.Array,
                     sel_mask: Optional[jax.Array]):
        return ops.gather_decode_stats_paged(
            q, self.pool.k, self.pool.v, self._phys(idx), sel_mask)

    def kv_logical(self) -> Tuple[jax.Array, jax.Array]:
        return (paged.logical_view(self.pool.k, self.block_table),
                paged.logical_view(self.pool.v, self.block_table))

    def tile_rows(self, n: int) -> "PagedView":
        """Read-only batch tiling (see ``ContiguousView.tile_rows``):
        the shared pool is untouched, only the block table repeats."""
        return PagedView(self.pool,
                         jnp.repeat(self.block_table, n, axis=0))

    def prefill_attend(self, q: jax.Array, ctx, *,
                       window: Optional[int] = None) -> jax.Array:
        return ops.chunk_attention_paged(q, self.pool.k, self.pool.v,
                                         self.block_table, ctx,
                                         window=window)

    def unwrap(self):
        return self.pool


# ===========================================================================
# MLA latent views
# ===========================================================================
@register_dataclass
@dataclasses.dataclass
class ContiguousMLAView:
    cache: MLACache

    @property
    def capacity(self) -> int:
        return self.cache.max_len

    @property
    def has_codes(self) -> bool:
        return self.cache.codes is not None

    def append(self, ckv: jax.Array, krope: jax.Array,
               codes: Optional[jax.Array], pos) -> "ContiguousMLAView":
        return ContiguousMLAView(append_mla(self.cache, ckv, krope,
                                            codes, pos))

    append_chunk = append

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None,
                       positions: Optional[jax.Array] = None) -> jax.Array:
        """(B, H, W) q codes -> (B, S_log) masked latent match scores."""
        scores = ops.hamming_scores_latent(q_codes, self.cache.codes,
                                           rbit=rbit)
        return _mask_rows(scores[:, None], n_valid, window,
                          positions)[:, 0]

    def gather_latent(self, q_lat: jax.Array, idx: jax.Array, *,
                      lora_rank: int, scale: float,
                      n_valid: Optional[jax.Array] = None,
                      sel_mask: Optional[jax.Array] = None,
                      return_stats: bool = False):
        """Split-latent fused gather over selected rows; returns o_lat
        (B, H, r) f32 (caller applies W_uv) or (m, l, o~) partials."""
        return ops.mla_gather_decode(
            q_lat, self.cache.ckv, self.cache.krope, idx,
            lora_rank=lora_rank, scale=scale, n_valid=n_valid,
            sel_mask=sel_mask, return_stats=return_stats)

    def latents_logical(self) -> Tuple[jax.Array, jax.Array]:
        return self.cache.ckv, self.cache.krope

    def tile_rows(self, n: int) -> "ContiguousMLAView":
        """Read-only batch tiling (see ``ContiguousView.tile_rows``)."""
        r = lambda a: jnp.repeat(a, n, axis=0)
        return ContiguousMLAView(dataclasses.replace(
            self.cache, ckv=r(self.cache.ckv),
            krope=r(self.cache.krope),
            codes=(None if self.cache.codes is None
                   else r(self.cache.codes))))

    def prefill_attend(self, q_lat: jax.Array, ctx, *, lora_rank: int,
                       scale: float) -> jax.Array:
        return ops.mla_chunk_attention(q_lat, self.cache.ckv,
                                       self.cache.krope, ctx,
                                       lora_rank=lora_rank, scale=scale)

    def unwrap(self):
        return self.cache


@register_dataclass
@dataclasses.dataclass
class PagedMLAView:
    pool: paged.PagedMLAPool
    block_table: jax.Array

    @property
    def capacity(self) -> int:
        return self.block_table.shape[1] * self.pool.page_size

    @property
    def has_codes(self) -> bool:
        return self.pool.codes is not None

    def _phys(self, logical: jax.Array) -> jax.Array:
        return paged.physical_rows(self.block_table, logical,
                                   self.pool.page_size)

    def append(self, ckv: jax.Array, krope: jax.Array,
               codes: Optional[jax.Array], pos) -> "PagedMLAView":
        b = ckv.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        pool = paged.append_rows_mla(self.pool, ckv, krope, codes,
                                     self._phys(pos))
        return PagedMLAView(pool, self.block_table)

    def append_chunk(self, ckv: jax.Array, krope: jax.Array,
                     codes: Optional[jax.Array], ctx) -> "PagedMLAView":
        pool = paged.append_chunk_mla(self.pool, ckv, krope, codes,
                                      self.block_table, ctx)
        return PagedMLAView(pool, self.block_table)

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None,
                       positions: Optional[jax.Array] = None) -> jax.Array:
        scores = ops.hamming_scores_latent_paged(
            q_codes, self.pool.codes, self.block_table, n_valid,
            rbit=rbit)
        if window is None and positions is None:
            return scores
        return _mask_rows(scores[:, None], n_valid, window,
                          positions)[:, 0]

    def gather_latent(self, q_lat: jax.Array, idx: jax.Array, *,
                      lora_rank: int, scale: float,
                      n_valid: Optional[jax.Array] = None,
                      sel_mask: Optional[jax.Array] = None,
                      return_stats: bool = False):
        return ops.mla_gather_decode_paged(
            q_lat, self.pool.ckv, self.pool.krope, self._phys(idx),
            lora_rank=lora_rank, scale=scale, n_valid=n_valid,
            sel_mask=sel_mask, return_stats=return_stats)

    def latents_logical(self) -> Tuple[jax.Array, jax.Array]:
        return (paged.logical_view(self.pool.ckv, self.block_table),
                paged.logical_view(self.pool.krope, self.block_table))

    def tile_rows(self, n: int) -> "PagedMLAView":
        """Read-only batch tiling (see ``ContiguousView.tile_rows``)."""
        return PagedMLAView(self.pool,
                            jnp.repeat(self.block_table, n, axis=0))

    def prefill_attend(self, q_lat: jax.Array, ctx, *, lora_rank: int,
                       scale: float) -> jax.Array:
        return ops.mla_chunk_attention_paged(
            q_lat, self.pool.ckv, self.pool.krope, self.block_table,
            ctx, lora_rank=lora_rank, scale=scale)

    def unwrap(self):
        return self.pool


# ===========================================================================
# Offloaded views (device codes + host rows) — the tiered layer
# ===========================================================================
def _concrete(x, what: str) -> np.ndarray:
    """Offloaded waves cross the host boundary, so they run *eagerly*:
    the selected indices must be concrete before the host gather. A
    tracer here means someone jitted the offloaded path — fail with
    direction instead of silently baking host state into the trace."""
    if isinstance(x, jax.core.Tracer):
        raise TypeError(
            f"OffloadedView needs a concrete {what} — the top-k winners "
            "are resolved to HOST pages outside the XLA program. Drive "
            "offloaded decode eagerly (PagedServingEngine(offload=True) "
            "skips jit); only the resident layers belong under jit.")
    return np.asarray(x)


@dataclasses.dataclass
class OffloadedView:
    """Tiered GQA/MHA pool: hash codes HBM-resident, K/V rows on host.

    Same verbs, same *logical* selection math as :class:`PagedView` —
    ``hamming_scores`` runs the identical paged score kernel over the
    device codes pool, so view and all-resident pool pick bit-identical
    rows. Only the gather boundary differs: the winners are translated
    to host pages (``offload.physical_rows_np``), gathered compactly on
    the host per kv head, DMA'd up through the engine's
    :class:`~repro.core.offload.PrefetchPipeline` (A/B slots — wave
    t+1's upload overlaps wave t's attention), and attended with the
    same fused contiguous kernel via the identity index map
    (``ops.gather_decode_attention_staged``).

    NOT a pytree: the host half is numpy and the pipeline is a mutable
    ledger. The view never crosses a jit boundary — see
    :func:`_concrete`.
    """
    pool: offload.OffloadedKVPool
    block_table: jax.Array
    stream: str = "kv"                # staging-slot namespace

    @property
    def capacity(self) -> int:
        return self.block_table.shape[1] * self.pool.page_size

    @property
    def has_codes(self) -> bool:
        return self.pool.codes is not None

    def _phys(self, logical: jax.Array) -> jax.Array:
        return paged.physical_rows(self.block_table, logical,
                                   self.pool.page_size)

    def _bt_np(self) -> np.ndarray:
        return np.asarray(self.block_table)

    def _spill(self, k_rows: np.ndarray, v_rows: np.ndarray,
               phys: np.ndarray) -> None:
        """Fresh rows stream down to the host tier (metered)."""
        self.pool.host.scatter_rows(k_rows, v_rows, phys)
        n = k_rows.nbytes + v_rows.nbytes
        ops.account_pcie(n, "down")
        self.pool.pipeline.account_down(n)

    def append(self, k: jax.Array, v: jax.Array,
               codes: Optional[jax.Array], pos) -> "OffloadedView":
        b = k.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        phys = self._phys(pos)
        pool = dataclasses.replace(
            self.pool,
            codes=paged._scatter_rows(self.pool.codes, codes[:, 0],
                                      phys))
        self._spill(_concrete(k, "append")[:, 0],
                    np.asarray(v)[:, 0], np.asarray(phys))
        return OffloadedView(pool, self.block_table, self.stream)

    def append_chunk(self, k: jax.Array, v: jax.Array,
                     codes: Optional[jax.Array], ctx) -> "OffloadedView":
        if jnp.ndim(ctx) == 1:
            # speculative verify: one chunk per slot at per-row starts
            b, c = k.shape[:2]
            phys = paged._chunk_phys_rows(
                self.block_table, ctx, c, self.pool.page_size,
                self.pool.num_pages).reshape(b * c)
            pool = dataclasses.replace(
                self.pool,
                codes=paged._scatter_rows(
                    self.pool.codes,
                    codes.reshape((b * c,) + codes.shape[2:]), phys))
            k_np = _concrete(k, "append_chunk")
            self._spill(k_np.reshape((b * c,) + k_np.shape[2:]),
                        np.asarray(v).reshape((b * c,) + v.shape[2:]),
                        np.asarray(phys))
            return OffloadedView(pool, self.block_table, self.stream)
        phys = paged._chunk_phys(self.block_table, ctx, k.shape[1],
                                 self.pool.page_size,
                                 self.pool.num_pages)
        pool = dataclasses.replace(
            self.pool,
            codes=paged._scatter_rows(self.pool.codes, codes[0], phys))
        # host scatter_rows drops the one-past-the-pool ids, matching
        # the device scatter's OOB-drop convention for padded tails
        self._spill(_concrete(k, "append_chunk")[0],
                    np.asarray(v)[0], np.asarray(phys))
        return OffloadedView(pool, self.block_table, self.stream)

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None,
                       positions: Optional[jax.Array] = None) -> jax.Array:
        scores = ops.hamming_scores_paged(q_codes, self.pool.codes,
                                          self.block_table, n_valid,
                                          rbit=rbit)
        if window is None and positions is None:
            return scores
        return _mask_rows(scores, n_valid, window, positions)

    def _stage_rows(self, idx: jax.Array):
        """idx (B, H_kv, k) logical winners -> staged device rows
        (B, k, H_kv, d): host page translate, per-head compact gather,
        double-buffered PCIe upload."""
        idx_np = _concrete(idx, "selection idx")
        ops.account_pcie(idx_np.nbytes, "down")
        self.pool.pipeline.account_down(idx_np.nbytes)
        phys = offload.physical_rows_np(self._bt_np(), idx_np,
                                        self.pool.page_size)
        kg, vg = self.pool.host.gather_heads(phys)   # (B, H_kv, k, d)
        return self.pool.pipeline.stage(
            self.stream,
            np.ascontiguousarray(np.moveaxis(kg, 1, 2)),
            np.ascontiguousarray(np.moveaxis(vg, 1, 2)))

    def gather_decode(self, q: jax.Array, idx: jax.Array,
                      sel_valid: jax.Array) -> jax.Array:
        k_st, v_st = self._stage_rows(idx)
        return ops.gather_decode_attention_staged(q, k_st, v_st,
                                                  sel_valid=sel_valid)

    def gather_stats(self, q: jax.Array, idx: jax.Array,
                     sel_mask: Optional[jax.Array]):
        k_st, v_st = self._stage_rows(idx)
        return ops.gather_decode_stats_staged(q, k_st, v_st, sel_mask)

    def _upload_logical(self):
        """Whole-context host read (dense fallback / prefill): honest —
        every logical row crosses PCIe, which is exactly why offloaded
        layers should be HATA layers (codes score on-device; only the
        budget crosses)."""
        k_log, v_log = self.pool.host.logical(self._bt_np())
        self.pool.pipeline.account_up(k_log.nbytes + v_log.nbytes)
        return (ops.device_put_accounted(k_log),
                ops.device_put_accounted(v_log))

    def kv_logical(self) -> Tuple[jax.Array, jax.Array]:
        return self._upload_logical()

    def prefill_attend(self, q: jax.Array, ctx, *,
                       window: Optional[int] = None) -> jax.Array:
        k_dev, v_dev = self._upload_logical()
        return ops.chunk_attention(q, k_dev, v_dev, q_offset=ctx,
                                   window=window)

    def tile_rows(self, n: int) -> "OffloadedView":
        """Read-only batch tiling (see ``ContiguousView.tile_rows``):
        pool + host tier shared, block table repeats — one batched
        score/stage/gather serves all verify positions."""
        return OffloadedView(self.pool,
                             jnp.repeat(self.block_table, n, axis=0),
                             self.stream)

    def unwrap(self):
        return self.pool


@dataclasses.dataclass
class OffloadedMLAView:
    """MLA twin: latent codes (P, page, W) on device, (ckv, krope)
    rows on host; fused split-latent attend over staged rows."""
    pool: offload.OffloadedMLAPool
    block_table: jax.Array
    stream: str = "mla"
    # wave-batched chunked-prefill state (see stage_mla_ctx_uploads):
    # staged_ctx — this layer's slice of the one multi-layer logical
    # upload made at wave start; chunk_dev — the current chunk's own
    # (ckv, krope) device rows, kept at append_chunk time so
    # prefill_attend never re-uploads rows the device just computed
    staged_ctx: Optional[Tuple[jax.Array, jax.Array]] = None
    chunk_dev: Optional[Tuple[jax.Array, jax.Array]] = None

    @property
    def capacity(self) -> int:
        return self.block_table.shape[1] * self.pool.page_size

    @property
    def has_codes(self) -> bool:
        return self.pool.codes is not None

    def _phys(self, logical: jax.Array) -> jax.Array:
        return paged.physical_rows(self.block_table, logical,
                                   self.pool.page_size)

    def _bt_np(self) -> np.ndarray:
        return np.asarray(self.block_table)

    def _spill(self, ckv_rows: np.ndarray, krope_rows: np.ndarray,
               phys: np.ndarray) -> None:
        self.pool.host.scatter_rows(ckv_rows, krope_rows, phys)
        n = ckv_rows.nbytes + krope_rows.nbytes
        ops.account_pcie(n, "down")
        self.pool.pipeline.account_down(n)

    def append(self, ckv: jax.Array, krope: jax.Array,
               codes: Optional[jax.Array], pos) -> "OffloadedMLAView":
        b = ckv.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        phys = self._phys(pos)
        pool = dataclasses.replace(
            self.pool,
            codes=paged._scatter_rows(self.pool.codes, codes[:, 0],
                                      phys))
        self._spill(_concrete(ckv, "append")[:, 0],
                    np.asarray(krope)[:, 0], np.asarray(phys))
        return OffloadedMLAView(pool, self.block_table, self.stream)

    def append_chunk(self, ckv: jax.Array, krope: jax.Array,
                     codes: Optional[jax.Array], ctx
                     ) -> "OffloadedMLAView":
        if jnp.ndim(ctx) == 1:
            # speculative verify: per-row starts; no chunk_dev splice
            # (the per-row attend takes the logical-upload path — the
            # staged_ctx DUS splice is scalar-ctx only)
            b, c = ckv.shape[:2]
            phys = paged._chunk_phys_rows(
                self.block_table, ctx, c, self.pool.page_size,
                self.pool.num_pages).reshape(b * c)
            pool = dataclasses.replace(
                self.pool,
                codes=paged._scatter_rows(
                    self.pool.codes,
                    codes.reshape((b * c,) + codes.shape[2:]), phys))
            ckv_np = _concrete(ckv, "append_chunk")
            self._spill(ckv_np.reshape((b * c,) + ckv_np.shape[2:]),
                        np.asarray(krope).reshape(
                            (b * c,) + krope.shape[2:]),
                        np.asarray(phys))
            return OffloadedMLAView(pool, self.block_table, self.stream,
                                    staged_ctx=self.staged_ctx,
                                    chunk_dev=None)
        phys = paged._chunk_phys(self.block_table, ctx, ckv.shape[1],
                                 self.pool.page_size,
                                 self.pool.num_pages)
        pool = dataclasses.replace(
            self.pool,
            codes=paged._scatter_rows(self.pool.codes, codes[0], phys))
        self._spill(_concrete(ckv, "append_chunk")[0],
                    np.asarray(krope)[0], np.asarray(phys))
        return OffloadedMLAView(pool, self.block_table, self.stream,
                                staged_ctx=self.staged_ctx,
                                chunk_dev=(ckv, krope))

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None,
                       positions: Optional[jax.Array] = None) -> jax.Array:
        scores = ops.hamming_scores_latent_paged(
            q_codes, self.pool.codes, self.block_table, n_valid,
            rbit=rbit)
        if window is None and positions is None:
            return scores
        return _mask_rows(scores[:, None], n_valid, window,
                          positions)[:, 0]

    def gather_latent(self, q_lat: jax.Array, idx: jax.Array, *,
                      lora_rank: int, scale: float,
                      n_valid: Optional[jax.Array] = None,
                      sel_mask: Optional[jax.Array] = None,
                      return_stats: bool = False):
        idx_np = _concrete(idx, "selection idx")
        ops.account_pcie(idx_np.nbytes, "down")
        self.pool.pipeline.account_down(idx_np.nbytes)
        phys = offload.physical_rows_np(self._bt_np(), idx_np,
                                        self.pool.page_size)
        cg, rg = self.pool.host.gather_rows(phys)  # (B,k,r), (B,k,rd)
        ckv_st, krope_st = self.pool.pipeline.stage(
            self.stream, np.ascontiguousarray(cg),
            np.ascontiguousarray(rg))
        return ops.mla_gather_decode_staged(
            q_lat, ckv_st, krope_st, lora_rank=lora_rank, scale=scale,
            n_valid=n_valid, sel_mask=sel_mask,
            return_stats=return_stats)

    def _upload_logical(self):
        c_log, r_log = self.pool.host.logical(self._bt_np())
        self.pool.pipeline.account_up(c_log.nbytes + r_log.nbytes)
        return (ops.device_put_accounted(c_log),
                ops.device_put_accounted(r_log))

    def latents_logical(self) -> Tuple[jax.Array, jax.Array]:
        return self._upload_logical()

    def prefill_attend(self, q_lat: jax.Array, ctx, *, lora_rank: int,
                       scale: float) -> jax.Array:
        if self.staged_ctx is not None and self.chunk_dev is not None:
            # wave-batched path: the context rows (< ctx) rode the one
            # multi-layer upload at wave start; the chunk's own rows
            # never left the device. Splicing them at [ctx, ctx+C)
            # reproduces the per-layer logical upload bit-for-bit —
            # the host pools are f32, so the spill round-trip the old
            # path read back was lossless, and rows >= ctx+C are
            # identical pre/post spill (and masked by causality).
            ckv_dev, krope_dev = self.staged_ctx
            ckv_c, krope_c = self.chunk_dev
            start = jnp.asarray(ctx, jnp.int32)
            zero = jnp.int32(0)
            ckv_dev = jax.lax.dynamic_update_slice(
                ckv_dev, ckv_c.astype(ckv_dev.dtype),
                (zero, start, zero))
            krope_dev = jax.lax.dynamic_update_slice(
                krope_dev, krope_c.astype(krope_dev.dtype),
                (zero, start, zero))
        else:
            ckv_dev, krope_dev = self._upload_logical()
        return ops.mla_chunk_attention(q_lat, ckv_dev, krope_dev, ctx,
                                       lora_rank=lora_rank, scale=scale)

    def tile_rows(self, n: int) -> "OffloadedMLAView":
        """Read-only batch tiling (see ``ContiguousView.tile_rows``).
        The prefill staging state is dropped — a tiled view only ever
        serves decode-path attends."""
        return OffloadedMLAView(self.pool,
                                jnp.repeat(self.block_table, n, axis=0),
                                self.stream)

    def unwrap(self):
        return self.pool


def stage_mla_ctx_uploads(views: Sequence) -> List:
    """Batch the offloaded MLA layers' chunked-prefill context uploads
    into ONE stacked host gather + one accounted PCIe transfer per
    latent stream (the PR-2 leftover: per-layer MLA latent gathers ->
    one multi-layer dispatch).

    Call once per prefill wave, *before* the layer loop. Every
    :class:`OffloadedMLAView` in ``views`` used to upload its full
    logical latent window inside ``prefill_attend`` — L layers x one
    ``device_put`` pair per chunk. The context part (rows < ctx) is
    selection-independent and already on the host when the wave
    starts, so one (L, B, T·page, r) stacked gather moves the same
    bytes in 2 transfers instead of 2L; each layer's chunk rows stay
    device-side (``chunk_dev``) and are spliced in at attend time.
    Layers that are not offloaded MLA pass through untouched, so the
    call is a no-op for dense/paged/GQA stacks.
    """
    off = [(i, v) for i, v in enumerate(views)
           if isinstance(v, OffloadedMLAView)]
    if not off:
        return list(views)
    c_logs, r_logs = [], []
    for _, v in off:
        c_log, r_log = v.pool.host.logical(v._bt_np())
        c_logs.append(c_log)
        r_logs.append(r_log)
    c_st = np.ascontiguousarray(np.stack(c_logs))   # (L, B, cap, r)
    r_st = np.ascontiguousarray(np.stack(r_logs))
    off[0][1].pool.pipeline.account_up(c_st.nbytes + r_st.nbytes)
    ckv_dev = ops.device_put_accounted(c_st)
    krope_dev = ops.device_put_accounted(r_st)
    out = list(views)
    for j, (i, v) in enumerate(off):
        out[i] = dataclasses.replace(
            v, staged_ctx=(ckv_dev[j], krope_dev[j]))
    return out


# ===========================================================================
# Sequence-sharded view (SP decode shards)
# ===========================================================================
@register_dataclass
@dataclasses.dataclass
class ShardedView:
    """One SP shard's slice of the logical sequence, either family.

    ``inner`` is the shard's *local* view (a :class:`ContiguousView`
    over the local cache slice, or a :class:`PagedView` /
    :class:`PagedMLAView` over the local pool + local block table —
    table entries name local pages); ``offset`` is the absolute logical
    position of local row 0. Built *inside* shard_map by
    ``distributed/decode.SPDecode``, so the two_stage/local_split local
    math is written once against this class and runs unchanged over
    contiguous and paged layouts — physical-row translation (inner
    ``PagedView``) composes with the ownership-mask stats kernels.
    """
    inner: Union[ContiguousView, PagedView, ContiguousMLAView,
                 PagedMLAView]
    offset: jax.Array                 # scalar int32, absolute row 0
    n_shards: int = _static

    @property
    def s_local(self) -> int:
        return self.inner.capacity

    @property
    def has_codes(self) -> bool:
        return self.inner.has_codes

    def positions(self) -> jax.Array:
        """Absolute logical positions of the local rows."""
        return self.offset + jnp.arange(self.s_local)

    def hamming_scores(self, q_codes: jax.Array, n_valid, *, rbit: int,
                       window: Optional[int] = None) -> jax.Array:
        """Local match scores masked at *absolute* positions: validity
        and window are both computed against the global ``n_valid`` at
        ``offset + local_row``. (A paged inner's in-kernel local-row
        mask is a superset of the valid set; the absolute-position
        remask makes shards agree with the unsharded scores exactly.)"""
        return self.inner.hamming_scores(
            q_codes, n_valid, rbit=rbit, window=window,
            positions=self.positions())

    def gather_decode(self, q, idx, sel_valid):
        return self.inner.gather_decode(q, idx, sel_valid)

    def gather_stats(self, q: jax.Array, idx: jax.Array,
                     sel_mask: Optional[jax.Array]):
        """Local-row partials: idx are in-range *local* rows, sel_mask
        the ownership filter (two_stage keeps only global winners this
        shard holds)."""
        return self.inner.gather_stats(q, idx, sel_mask)

    def gather_latent(self, q_lat, idx, **kw):
        return self.inner.gather_latent(q_lat, idx, **kw)

    def kv_logical(self):
        return self.inner.kv_logical()

    def latents_logical(self):
        return self.inner.latents_logical()

    def unwrap(self):
        return self.inner


# ===========================================================================
# Coercion helpers — the one place raw caches meet the view API
# ===========================================================================
KVView = Union[ContiguousView, PagedView, OffloadedView, ShardedView]
MLAView = Union[ContiguousMLAView, PagedMLAView, OffloadedMLAView,
                ShardedView]
AnyView = Union[KVView, MLAView]

_VIEW_TYPES = (ContiguousView, PagedView, OffloadedView,
               ContiguousMLAView, PagedMLAView, OffloadedMLAView,
               ShardedView)


def is_view(x) -> bool:
    return isinstance(x, _VIEW_TYPES)


def as_gqa_view(x) -> KVView:
    """LayerKVCache -> ContiguousView; views pass through."""
    if isinstance(x, LayerKVCache):
        return ContiguousView(x)
    assert isinstance(x, (ContiguousView, PagedView, OffloadedView,
                          ShardedView)), type(x)
    return x


def as_mla_view(x) -> MLAView:
    """MLACache -> ContiguousMLAView; views pass through."""
    if isinstance(x, MLACache):
        return ContiguousMLAView(x)
    assert isinstance(x, (ContiguousMLAView, PagedMLAView,
                          OffloadedMLAView, ShardedView)), type(x)
    return x


def paged_view(pool, block_table: jax.Array):
    """Wrap one layer's pool + table in the right view family — the
    offloaded pools dispatch here too, so the serving engine's decode/
    chunk bodies are mode-agnostic (offload just drops the jit)."""
    if isinstance(pool, offload.OffloadedKVPool):
        return OffloadedView(pool, block_table)
    if isinstance(pool, offload.OffloadedMLAPool):
        return OffloadedMLAView(pool, block_table)
    if isinstance(pool, paged.PagedMLAPool):
        return PagedMLAView(pool, block_table)
    assert isinstance(pool, paged.PagedKVPool), type(pool)
    return PagedView(pool, block_table)


def paged_views(pools, block_table: jax.Array) -> List:
    """One per-layer view around a shared block table — the serving
    plane's workers (serving/plane.py) build their decode/chunk bodies
    on this, so dense-resident, paged and offloaded layers all flow
    through :func:`paged_view`'s per-pool dispatch in one place."""
    return [paged_view(pool, block_table) for pool in pools]


def unwrap(view_or_cache):
    """Return the wrapped storage (cache or pool); raw caches pass
    through — the inverse of the ``as_*``/``paged_view`` coercions."""
    if is_view(view_or_cache):
        return view_or_cache.unwrap()
    return view_or_cache
