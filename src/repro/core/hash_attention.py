"""HATA top-k attention (paper §3.2, Algorithms 1-3) — single-device
semantics. The sequence-sharded SPMD decode lives in
``repro/distributed/decode.py`` and must agree with this module exactly
(tested in tests/test_distributed.py); the per-row building blocks here
(:func:`aggregate_q_codes`, :func:`clamped_budget`,
:func:`mask_scores`) are shared with it.

Prefill (Alg. 1): full flash attention + fill KV cache + hash-encode and
cache the key codes. The attention bottoms out in
``kernels/flash_attention.flash_prefill_batched`` (one batched dispatch,
GQA folded into the tile, traced ``q_offset``); the paged serving
engine's chunked prefill runs the block-table variant over the page
pools in place.

Decode (Alg. 3): hash-encode q and the new k; update caches; Hamming
match scores against the whole code cache (GQA: summed over the q heads
sharing each kv head); top-k; fused gather + sparse flash attention.
The whole score -> select -> gather pipeline is batched over (B, H_kv):
two Pallas dispatches per decode wave (batched Hamming kernel, batched
fused-gather kernel), no per-head vmap.

Static-shape policy: ``k`` (the token budget) must be static under jit.
We take ``k = resolve_budget(hcfg, max_len, layer=...)`` (per-layer
budget tables apply — core/budgets.py) and make selection exact for short
caches by (a) masking invalid rows' scores to -1 — below the score floor
of 0 ≤ valid match scores — and (b) masking selections with score < 0
out of the softmax *inside the fused kernel* (they contribute zero
probability mass — the paged DMA still lands, the logit is -inf). While
cache_len <= k this reproduces *dense* decode bit-for-bit (every valid
row selected), which is also what the paper's budget_min floor does.
The fused path needs no clamp-and-recompute correction: the kernel's
masking is the exact semantics, verified bit-exact against the XLA
reference in tests/test_decode_parity.py.

Batching across request depths: every entry point accepts ``pos`` as a
scalar (aligned batch) or a (B,) vector (continuous-batching slots at
different depths — the serving engine's decode wave). Per-row validity
masks fall out of broadcasting; the budget stays static.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HataConfig
from repro.core import budgets as _budgets
from repro.core import hash_weights as hw
from repro.core import paged_cache as paged
from repro.core.kvcache import LayerKVCache, append_kv
from repro.core.topk import chunked_topk
from repro.kernels import ops, ref


class HataDecodeOut(NamedTuple):
    out: jax.Array                    # (B, H, d)
    cache: LayerKVCache
    idx: jax.Array                    # (B, H_kv, k) selected rows
    scores: jax.Array                 # (B, H_kv, S) match scores


def hata_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                 w_h: jax.Array, cache: LayerKVCache, *,
                 hcfg: HataConfig, pos: jax.Array,
                 window: Optional[int] = None,
                 ) -> Tuple[jax.Array, LayerKVCache]:
    """Alg. 1. q: (B, S, H, d), k/v: (B, S, H_kv, d), w_h: (H_kv, d, rbit).

    Encoding cost is O(S·d·rbit) vs attention's O(S²·d): <1% of prefill
    (paper §3.2) — measured in benchmarks/opt_ablation.py.
    """
    codes = None
    if cache.codes is not None:
        codes = ops.hash_encode_heads(k, w_h)       # (B, S, H_kv, W)
    cache = append_kv(cache, k, v, codes, pos)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              q_offset=0)
    return out, cache


def aggregate_q_codes(q: jax.Array, w_h,
                      n_kv_heads: int) -> jax.Array:
    """Encode q per-head with its kv-group's hash weights.

    q: (B, H, d), w_h: (H_kv, d, rbit) linear — or the MLP dict form
    (core/hash_weights.py); vmap maps over the leading head axis of
    every leaf either way -> (B, H_kv, G, W) uint32.
    """
    b, h, d = q.shape
    g = h // n_kv_heads
    qg = q.reshape(b, n_kv_heads, g, d)
    # heads share their group's W_H so q codes and k codes are comparable
    fn = lambda x, w: ops.hash_encode(x, w)          # (B, G, d),(d,r)->(B,G,W)
    return jax.vmap(fn, in_axes=(1, 0), out_axes=1)(qg, w_h)


def clamped_budget(hcfg: HataConfig, s_max: int,
                   window: Optional[int] = None, *,
                   layer: Optional[int] = None) -> int:
    """Static top-k budget for a cache of capacity ``s_max``.

    A sliding window caps the number of attendable rows, and the budget
    can never exceed the cache itself. Shared by the single-device,
    model-stack and sequence-parallel decode paths so their selection
    shapes agree. Resolution goes through ``core.budgets.resolve_budget``
    — when a calibrated per-layer budget table is installed and the
    caller passes a concrete ``layer`` (the unrolled decode paths do),
    that layer's calibrated budget replaces the global one; scanned
    stacks and SP strategies pass ``layer=None`` and keep the global
    budget (their selection shape must be layer-invariant).
    """
    return _budgets.resolve_budget(hcfg, s_max, layer=layer, window=window)


def mask_scores(scores: jax.Array, n_valid: jax.Array, *,
                window: Optional[int] = None,
                positions: Optional[jax.Array] = None) -> jax.Array:
    """Mask match scores outside the valid (and windowed) range to -1.

    scores: (B, H_kv, S); n_valid: scalar or (B,) valid row count
    (slots at different depths get per-row masks); positions: optional
    (S,) absolute row positions (sequence-sharded callers pass their
    shard offsets; default arange(S)). -1 sits below the score floor of
    0 for valid rows, so top-k + ``score >= 0`` recovers exactness.
    """
    s = scores.shape[-1]
    if positions is None:
        positions = jnp.arange(s)
    nv = jnp.reshape(jnp.asarray(n_valid), (-1, 1, 1))   # (1|B, 1, 1)
    valid = positions[None, None, :] < nv
    if window is not None:
        valid = valid & (positions[None, None, :] > nv - 1 - window)
    return jnp.where(valid, scores, -1)


def hata_score_select(q: jax.Array, w_h: jax.Array, codes: jax.Array, *,
                      rbit: int, budget: int, n_valid: jax.Array,
                      window: Optional[int] = None,
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Alg. 3 lines 6, 10-15: encode q, batched Hamming scores, top-k.

    q: (B, H, d), w_h: (H_kv, d, rbit), codes: (B, S, H_kv, W).
    Returns (top_scores (B, H_kv, k), idx (B, H_kv, k),
    scores (B, H_kv, S)). ``budget`` must be static (see
    :func:`clamped_budget`); ``n_valid`` may be scalar or (B,).
    """
    h_kv = codes.shape[2]
    q_codes = aggregate_q_codes(q, w_h, h_kv)        # (B, H_kv, G, W)
    scores = ops.hamming_scores(q_codes, codes, rbit=rbit)
    scores = mask_scores(scores, n_valid, window=window)
    # two-stage on-device top-k: bit-identical to lax.top_k (ties
    # included) but without its long-minor-axis cost — see core/topk.py
    top_scores, idx = chunked_topk(scores, budget)   # (B, H_kv, k)
    return top_scores, idx, scores


def hata_attend(q: jax.Array, cache: LayerKVCache, idx: jax.Array,
                sel_valid: jax.Array, *, fused: bool = True) -> jax.Array:
    """Sparse attention over selected rows with a validity mask.

    Fused path (pallas impl): the batched gather kernel masks invalid
    selections inside the kernel — no clamping, no side computation of
    the exact answer. The xla impl evaluates the same math as
    ``ref.masked_gather_decode_ref`` (the kernel's differential oracle).
    """
    return ops.gather_decode_attention(q, cache.k, cache.v, idx,
                                       sel_valid=sel_valid, fused=fused)


def hata_decode_batched(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                        w_h, cache: LayerKVCache, *,
                        hcfg: HataConfig, pos: jax.Array,
                        window: Optional[int] = None,
                        layer: Optional[int] = None,
                        fused_gather: bool = True) -> HataDecodeOut:
    """Alg. 3, batched over requests at arbitrary depths.

    q: (B, H, d), k_new/v_new: (B, 1, H_kv, d), w_h: (H_kv, d, rbit),
    pos: scalar int32 *or* (B,) int32 per-row cache fill before this
    token (continuous-batching slots sit at different depths).

    One decode wave = encode + cache append, then the batched
    score -> select -> gather pipeline: a (B, H_kv, S-blocks) Hamming
    dispatch and a (B, H_kv, k) fused-gather dispatch. This is the
    entry point the serving engine's decode step and the naive-mode
    distributed decode both bottom out in.
    """
    h_kv = k_new.shape[2]
    s_max = cache.max_len
    rbit = hw.rbit_of(w_h)

    # --- Encode & cache update (Alg. 3 lines 3-9) ---
    k_codes = ops.hash_encode_heads(k_new, w_h)      # (B, 1, H_kv, W)
    cache = append_kv(cache, k_new, v_new, k_codes, pos)

    # --- Score + select (lines 10-15), per-row validity ---
    n_valid = jnp.asarray(pos) + 1                   # scalar or (B,)
    budget = clamped_budget(hcfg, s_max, window, layer=layer)
    top_scores, idx, scores = hata_score_select(
        q, w_h, cache.codes, rbit=rbit, budget=budget, n_valid=n_valid,
        window=window)

    # --- Fused gather + sparse attention (lines 16-17) ---
    out = hata_attend(q, cache, idx, top_scores >= 0, fused=fused_gather)
    return HataDecodeOut(out=out, cache=cache, idx=idx, scores=scores)


def hata_decode(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                w_h: jax.Array, cache: LayerKVCache, *,
                hcfg: HataConfig, pos: jax.Array,
                window: Optional[int] = None,
                fused_gather: bool = False) -> HataDecodeOut:
    """Alg. 3 with a single aligned depth — thin wrapper over
    :func:`hata_decode_batched` with scalar ``pos`` (cache fill before
    this token). Kept as the reference-shaped entry point the
    differential tests loop per-row against the batched path.
    """
    return hata_decode_batched(q, k_new, v_new, w_h, cache, hcfg=hcfg,
                               pos=jnp.asarray(pos, jnp.int32),
                               window=window, fused_gather=fused_gather)


def hata_score_select_paged(q: jax.Array, w_h: jax.Array,
                            codes_pool: jax.Array,
                            block_table: jax.Array, *, rbit: int,
                            budget: int, n_valid: jax.Array,
                            window: Optional[int] = None,
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Paged analogue of :func:`hata_score_select`.

    codes_pool: (P, page, H_kv, W) shared per-layer code pool;
    block_table: (B, T) int32. Scores are *logical* (B, H_kv, T*page)
    with garbage rows at -1 (masked inside the paged Hamming kernel),
    so selection — including the window clamp and the score>=0 validity
    convention — is byte-for-byte the contiguous selection math; only
    the score kernel's page fetch differs.
    """
    h_kv = codes_pool.shape[2]
    q_codes = aggregate_q_codes(q, w_h, h_kv)
    scores = ops.hamming_scores_paged(q_codes, codes_pool, block_table,
                                      n_valid, rbit=rbit)
    if window is not None:
        scores = mask_scores(scores, n_valid, window=window)
    top_scores, idx = chunked_topk(scores, budget)
    return top_scores, idx, scores


def hata_decode_paged(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                      w_h, pool: paged.PagedKVPool,
                      block_table: jax.Array, *, hcfg: HataConfig,
                      pos: jax.Array, window: Optional[int] = None,
                      layer: Optional[int] = None,
                      ) -> Tuple[jax.Array, paged.PagedKVPool,
                                 jax.Array, jax.Array]:
    """Alg. 3 over a paged cache: the serving decode wave's per-layer
    HATA step.

    q: (B, H, d); k_new/v_new: (B, 1, H_kv, d); pool: the shared
    per-layer page pool; block_table: (B, T) int32; pos: (B,) int32
    per-request fill before this token (inactive slots point at the
    scratch page). Encode + scatter-append, paged score -> select, then
    logical -> physical translation feeds the shared-pool fused gather.
    Returns (out (B, H, d), pool, idx (B, H_kv, k) logical, scores).
    """
    psz = pool.page_size
    rbit = hw.rbit_of(w_h)
    s_log = block_table.shape[1] * psz

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (q.shape[0],))
    k_codes = ops.hash_encode_heads(k_new, w_h)        # (B, 1, H_kv, W)
    phys_new = paged.physical_rows(block_table, pos, psz)
    pool = paged.append_rows_kv(pool, k_new, v_new, k_codes, phys_new)

    n_valid = jnp.asarray(pos) + 1
    budget = clamped_budget(hcfg, s_log, window, layer=layer)
    top_scores, idx, scores = hata_score_select_paged(
        q, w_h, pool.codes, block_table, rbit=rbit, budget=budget,
        n_valid=n_valid, window=window)

    phys_idx = paged.physical_rows(block_table, idx, psz)
    out = ops.gather_decode_attention_paged(
        q, pool.k, pool.v, phys_idx, sel_valid=top_scores >= 0)
    return out, pool, idx, scores


def _xla_masked(q: jax.Array, cache: LayerKVCache, idx: jax.Array,
                sel_valid: jax.Array) -> jax.Array:
    """Back-compat alias for the XLA oracle (see kernels/ref.py)."""
    return ref.masked_gather_decode_ref(q, cache.k, cache.v, idx,
                                        sel_valid)

# The MLA variant (beyond-paper: hash over the compressed latent stream)
# lives with the MLA projection math in models/attention.py.
