"""HATA top-k attention (paper §3.2, Algorithms 1-3) — single-device
semantics. The sequence-sharded SPMD decode lives in
``repro/distributed/decode.py`` and must agree with this module exactly
(tested in tests/test_distributed.py).

Prefill (Alg. 1): full flash attention + fill KV cache + hash-encode and
cache the key codes.

Decode (Alg. 3): hash-encode q and the new k; update caches; Hamming
match scores against the whole code cache (GQA: summed over the q heads
sharing each kv head); top-k; gather; sparse flash attention.

Static-shape policy: ``k`` (the token budget) must be static under jit.
We take ``k = hcfg.budget(max_len)`` and make selection exact for short
caches by (a) masking invalid rows' scores to -1 — below the score floor
of 0 ≤ valid match scores — and (b) masking gathered rows with score < 0
out of the softmax. While cache_len <= k this reproduces *dense* decode
bit-for-bit (every valid row selected), which is also what the paper's
budget_min floor does.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HataConfig
from repro.core.kvcache import LayerKVCache, append_kv
from repro.kernels import ops


class HataDecodeOut(NamedTuple):
    out: jax.Array                    # (B, H, d)
    cache: LayerKVCache
    idx: jax.Array                    # (B, H_kv, k) selected rows
    scores: jax.Array                 # (B, H_kv, S) match scores


def hata_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                 w_h: jax.Array, cache: LayerKVCache, *,
                 hcfg: HataConfig, pos: jax.Array,
                 window: Optional[int] = None,
                 ) -> Tuple[jax.Array, LayerKVCache]:
    """Alg. 1. q: (B, S, H, d), k/v: (B, S, H_kv, d), w_h: (H_kv, d, rbit).

    Encoding cost is O(S·d·rbit) vs attention's O(S²·d): <1% of prefill
    (paper §3.2) — measured in benchmarks/opt_ablation.py.
    """
    codes = None
    if cache.codes is not None:
        codes = ops.hash_encode_heads(k, w_h)       # (B, S, H_kv, W)
    cache = append_kv(cache, k, v, codes, pos)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              q_offset=0)
    return out, cache


def _aggregate_q_codes(q: jax.Array, w_h: jax.Array,
                       n_kv_heads: int) -> jax.Array:
    """Encode q per-head with its kv-group's hash weights.

    q: (B, H, d), w_h: (H_kv, d, rbit) -> (B, H_kv, G, W) uint32.
    """
    b, h, d = q.shape
    g = h // n_kv_heads
    qg = q.reshape(b, n_kv_heads, g, d)
    # heads share their group's W_H so q codes and k codes are comparable
    fn = lambda x, w: ops.hash_encode(x, w)          # (B, G, d),(d,r)->(B,G,W)
    return jax.vmap(fn, in_axes=(1, 0), out_axes=1)(qg, w_h)


def hata_decode(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                w_h: jax.Array, cache: LayerKVCache, *,
                hcfg: HataConfig, pos: jax.Array,
                window: Optional[int] = None,
                fused_gather: bool = False) -> HataDecodeOut:
    """Alg. 3. q: (B, H, d), k_new/v_new: (B, 1, H_kv, d),
    w_h: (H_kv, d, rbit), pos: scalar int32 (cache fill before this token).
    """
    b, h, d = q.shape
    h_kv = k_new.shape[2]
    s_max = cache.max_len
    rbit = w_h.shape[-1]

    # --- Encode & cache update (Alg. 3 lines 3-9) ---
    k_codes = ops.hash_encode_heads(k_new, w_h)      # (B, 1, H_kv, W)
    cache = append_kv(cache, k_new, v_new, k_codes, pos)
    q_codes = _aggregate_q_codes(q, w_h, h_kv)       # (B, H_kv, G, W)

    # --- Hamming scores over the full code cache (lines 10-11) ---
    scores = ops.hamming_scores(q_codes, cache.codes, rbit=rbit)
    n_valid = pos + 1
    positions = jnp.arange(s_max)
    valid = positions[None, None, :] < n_valid       # (1, 1, S)
    if window is not None:
        valid = valid & (positions[None, None, :] > n_valid - 1 - window)
    scores = jnp.where(valid, scores, -1)

    # --- Top-k select + gather + sparse attention (lines 13-17) ---
    budget = hcfg.budget(s_max)
    if window is not None:
        budget = min(budget, window)
    budget = min(budget, s_max)
    top_scores, idx = jax.lax.top_k(scores, budget)  # (B, H_kv, k)
    sel_valid = top_scores >= 0

    out = _masked_gather_attention(q, cache, idx, sel_valid,
                                   fused=fused_gather)
    return HataDecodeOut(out=out, cache=cache, idx=idx, scores=scores)


def _masked_gather_attention(q: jax.Array, cache: LayerKVCache,
                             idx: jax.Array, sel_valid: jax.Array, *,
                             fused: bool) -> jax.Array:
    """Sparse attention over gathered rows with a validity mask."""
    b, h, d = q.shape
    h_kv = cache.k.shape[2]
    g = h // h_kv
    if fused and ops.get_impl() == "pallas":
        # Fused path: invalid selections are clamped to row 0 and their
        # probability mass removed by re-running the reference mask; on
        # real TPU the index list is exactly the valid prefix because
        # scores < 0 sort last. We keep the clamp + correction exact:
        idx_c = jnp.where(sel_valid, idx, 0)
        out = ops.gather_decode_attention(q, cache.k, cache.v, idx_c,
                                          fused=True)
        # correction only needed when any invalid present; cheap branch:
        any_invalid = jnp.any(~sel_valid)
        out_exact = _xla_masked(q, cache, idx, sel_valid)
        return jnp.where(any_invalid, out_exact, out)
    return _xla_masked(q, cache, idx, sel_valid)


def _xla_masked(q: jax.Array, cache: LayerKVCache, idx: jax.Array,
                sel_valid: jax.Array) -> jax.Array:
    b, h, d = q.shape
    h_kv = cache.k.shape[2]
    g = h // h_kv
    kg = jnp.take_along_axis(jnp.moveaxis(cache.k, 2, 1), idx[..., None],
                             axis=2)                 # (B, H_kv, k, d)
    vg = jnp.take_along_axis(jnp.moveaxis(cache.v, 2, 1), idx[..., None],
                             axis=2)
    qf = q.reshape(b, h_kv, g, d).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, kg.astype(jnp.float32))
    logits = jnp.where(sel_valid[:, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs, vg.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)

# The MLA variant (beyond-paper: hash over the compressed latent stream)
# lives with the MLA projection math in models/attention.py.
