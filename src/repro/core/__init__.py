"""HATA core: learning-to-hash + hash-aware top-k attention (paper §3),
the baselines it is compared against (§5.1), and the HATA-off offloading
extension (§5.3)."""
from repro.core import baselines, hashing, kvcache, offload, paged_cache, topk
from repro.core.hash_attention import (HataDecodeOut, hata_decode,
                                       hata_decode_batched,
                                       hata_decode_paged, hata_prefill)
from repro.core.kvcache import (LayerKVCache, MLACache, SSMState,
                                append_kv, append_mla, init_kv_cache,
                                init_mla_cache, init_ssm_state)
from repro.core.paged_cache import (PageAllocator, PagedKVPool,
                                    PagedMLAPool, PrefixCache)

__all__ = ["baselines", "hashing", "kvcache", "offload", "paged_cache",
           "topk", "HataDecodeOut", "hata_decode", "hata_decode_batched",
           "hata_decode_paged", "hata_prefill", "LayerKVCache",
           "MLACache", "SSMState", "append_kv", "append_mla",
           "init_kv_cache", "init_mla_cache", "init_ssm_state",
           "PageAllocator", "PagedKVPool", "PagedMLAPool", "PrefixCache"]
