"""HATA core: learning-to-hash + hash-aware top-k attention (paper §3),
the baselines it is compared against (§5.1), the HATA-off offloading
extension (§5.3), and the cache-view addressing layer (DESIGN.md §5)."""
from repro.core import (baselines, cache_view, hashing, kvcache, offload,
                        paged_cache, topk)
from repro.core.cache_view import (ContiguousMLAView, ContiguousView,
                                   PagedMLAView, PagedView, ShardedView)
from repro.core.hash_attention import (HataDecodeOut, hata_decode,
                                       hata_decode_batched,
                                       hata_decode_paged, hata_prefill)
from repro.core.kvcache import (LayerKVCache, MLACache, SSMState,
                                append_kv, append_mla, init_kv_cache,
                                init_mla_cache, init_ssm_state)
from repro.core.paged_cache import (PageAllocator, PagedKVPool,
                                    PagedMLAPool, PrefixCache)

__all__ = ["baselines", "cache_view", "hashing", "kvcache", "offload",
           "paged_cache", "topk", "ContiguousView", "ContiguousMLAView",
           "PagedView", "PagedMLAView", "ShardedView", "HataDecodeOut",
           "hata_decode", "hata_decode_batched", "hata_decode_paged",
           "hata_prefill", "LayerKVCache", "MLACache", "SSMState",
           "append_kv", "append_mla", "init_kv_cache", "init_mla_cache",
           "init_ssm_state", "PageAllocator", "PagedKVPool",
           "PagedMLAPool", "PrefixCache"]
