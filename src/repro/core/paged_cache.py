"""Paged KV + hash-code cache: block tables over one shared page pool.

The vLLM idea, specialized for HATA: because hash-based selection never
needs contiguous KV (scores are per-row, the fused gather is per-row
DMA), the cache can live in fixed-size *pages* of one shared pool per
layer, addressed through per-request *block tables*. The code cache is
paged together with K/V — rbit/32 words per token ride along in the same
page — so the whole score -> select -> gather pipeline runs over pages
with zero compaction (DASH-KV and HashAttention make the same
observation; see PAPERS.md).

Two halves:

Device side (this file, jit-land)
  * :class:`PagedKVPool` / :class:`PagedMLAPool` — per-layer pools of
    shape (num_pages, page_size, ...). Page 0 by convention is the
    engine's *scratch* page (inactive batch slots write their garbage
    rows there so they can never corrupt a page owned by a live
    request).
  * :func:`physical_rows` — logical row -> physical row translation
    through a block table (``bt[b, l // page] * page + l % page``).
    Selection math stays logical; only the final gather and the cache
    append see physical rows.
  * ``append_*`` (scatter new rows at physical positions) and
    ``gather_*`` (materialize the padded logical view — the dense-path
    and chunked-prefill context read).

Host side (plain Python, engine-land)
  * :class:`PageAllocator` — free list + per-page refcounts. Refcounts
    are what make prefix sharing safe: shared pages are always *full*
    and therefore immutable (writes only ever land past the shared
    prefix, in pages the writer owns alone), so sharing is
    copy-on-write that never needs the copy.
  * :class:`PrefixCache` — hash-of-token-prefix -> page lookup at full
    page granularity, LRU evicted under memory pressure. A hit lets a
    new request adopt the donor's prefix pages (refcount bump) and skip
    their prefill compute entirely.

Invariants (property-tested in tests/test_paged.py): every page is
either in the free list or has refcount >= 1, never both; releases
below zero raise; ``free + held == num_pages`` at all times.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import register_dataclass


# ---------------------------------------------------------------------------
# Device-side pools
# ---------------------------------------------------------------------------
@register_dataclass
@dataclasses.dataclass
class PagedKVPool:
    """One GQA/MHA layer's shared page pool (+ paged hash codes)."""
    k: jax.Array                      # (P, page, H_kv, d)
    v: jax.Array                      # (P, page, H_kv, d)
    codes: Optional[jax.Array]        # (P, page, H_kv, rbit//32) uint32

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


@register_dataclass
@dataclasses.dataclass
class PagedMLAPool:
    """One MLA layer's shared latent page pool (+ paged codes)."""
    ckv: jax.Array                    # (P, page, r)
    krope: jax.Array                  # (P, page, rope_dim)
    codes: Optional[jax.Array]        # (P, page, rbit//32) uint32

    @property
    def num_pages(self) -> int:
        return self.ckv.shape[0]

    @property
    def page_size(self) -> int:
        return self.ckv.shape[1]


def init_paged_kv_pool(num_pages: int, page_size: int, n_kv_heads: int,
                       head_dim: int, *, rbit: int = 0,
                       dtype=jnp.bfloat16) -> PagedKVPool:
    codes = None
    if rbit:
        codes = jnp.zeros((num_pages, page_size, n_kv_heads, rbit // 32),
                          jnp.uint32)
    return PagedKVPool(
        k=jnp.zeros((num_pages, page_size, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((num_pages, page_size, n_kv_heads, head_dim), dtype),
        codes=codes)


def init_paged_mla_pool(num_pages: int, page_size: int, kv_lora_rank: int,
                        rope_dim: int, *, rbit: int = 0,
                        dtype=jnp.bfloat16) -> PagedMLAPool:
    codes = None
    if rbit:
        codes = jnp.zeros((num_pages, page_size, rbit // 32), jnp.uint32)
    return PagedMLAPool(
        ckv=jnp.zeros((num_pages, page_size, kv_lora_rank), dtype),
        krope=jnp.zeros((num_pages, page_size, rope_dim), dtype),
        codes=codes)


# ---------------------------------------------------------------------------
# Logical -> physical translation
# ---------------------------------------------------------------------------
def physical_rows(block_table: jax.Array, logical: jax.Array,
                  page_size: int) -> jax.Array:
    """Translate logical rows to physical pool rows through a block table.

    block_table: (B, T) int32 page ids; logical: (B, ...) int32 logical
    row indices in [0, T * page_size). Returns physical row ids of the
    same shape: ``bt[b, l // page] * page + l % page``. This is the one
    place the paged subsystem maps selection output (logical) onto pool
    storage (physical) — kernels and selection math never see pages.
    """
    b, t = block_table.shape
    li = logical // page_size
    if logical.ndim == 1:
        pages = jnp.take_along_axis(block_table, li[:, None],
                                    axis=-1)[:, 0]
    else:
        bt = block_table.reshape((b,) + (1,) * (logical.ndim - 2) + (t,))
        pages = jnp.take_along_axis(
            jnp.broadcast_to(bt, logical.shape[:-1] + (t,)), li, axis=-1)
    return pages * page_size + logical % page_size


def _flat(pool_leaf: jax.Array) -> jax.Array:
    """(P, page, ...) -> (P * page, ...) physical row view."""
    return pool_leaf.reshape((-1,) + pool_leaf.shape[2:])


def _scatter_rows(pool_leaf: jax.Array, rows: jax.Array,
                  phys: jax.Array) -> jax.Array:
    """Write ``rows`` (N, ...) at physical row ids ``phys`` (N,)."""
    flat = _flat(pool_leaf)
    flat = flat.at[phys].set(rows.astype(flat.dtype))
    return flat.reshape(pool_leaf.shape)


# ---------------------------------------------------------------------------
# Appends (scatter) and logical gathers
# ---------------------------------------------------------------------------
def append_rows_kv(pool: PagedKVPool, k: jax.Array, v: jax.Array,
                   codes: Optional[jax.Array],
                   phys: jax.Array) -> PagedKVPool:
    """Decode-wave append: one new row per request.

    k/v: (B, 1, H_kv, d), codes: (B, 1, H_kv, W) | None, phys: (B,)
    physical rows (inactive slots point at the scratch page — duplicate
    scratch writes are fine, the garbage is never read).
    """
    return PagedKVPool(
        k=_scatter_rows(pool.k, k[:, 0], phys),
        v=_scatter_rows(pool.v, v[:, 0], phys),
        codes=None if pool.codes is None
        else _scatter_rows(pool.codes, codes[:, 0], phys))


def append_rows_mla(pool: PagedMLAPool, ckv: jax.Array, krope: jax.Array,
                    codes: Optional[jax.Array],
                    phys: jax.Array) -> PagedMLAPool:
    """ckv: (B, 1, r), krope: (B, 1, rd), codes: (B, 1, W), phys: (B,)."""
    return PagedMLAPool(
        ckv=_scatter_rows(pool.ckv, ckv[:, 0], phys),
        krope=_scatter_rows(pool.krope, krope[:, 0], phys),
        codes=None if pool.codes is None
        else _scatter_rows(pool.codes, codes[:, 0], phys))


def _chunk_phys(block_table: jax.Array, ctx: jax.Array, c: int,
                page_size: int, num_pages: int) -> jax.Array:
    """Physical destinations for a chunk's C rows starting at ``ctx``.

    A chunk is written at its fixed compiled width, so its zero-padded
    tail can reach past the block table's logical capacity (e.g. the
    last chunk of a prompt that ends near the table wall). Those rows
    must not be translated — an out-of-bounds table column would come
    back as take_along_axis's fill value and alias arbitrary pool rows
    after the page arithmetic. They are routed to one-past-the-pool
    instead, which JAX's scatter drops (out-of-bounds *updates* are
    dropped by default), so the padded tail lands nowhere.
    """
    capacity = block_table.shape[1] * page_size
    logical = ctx + jnp.arange(c)
    safe = jnp.minimum(logical, capacity - 1)
    phys = physical_rows(block_table, safe[None], page_size)[0]
    return jnp.where(logical < capacity, phys, num_pages * page_size)


def _chunk_phys_rows(block_table: jax.Array, ctx: jax.Array, c: int,
                     page_size: int, num_pages: int) -> jax.Array:
    """Per-row-ctx batched :func:`_chunk_phys`: ctx (B,) -> phys (B, C).

    The speculative verify wave writes a C-row chunk per *slot*, each
    starting at that slot's own committed length, so every row gets its
    own [ctx_b, ctx_b + C) window. Rows past the table's logical
    capacity route to one-past-the-pool exactly like the B=1 variant
    (scatter drops them) — a slot speculating into the capacity wall
    silently loses only the rows the engine will clamp away host-side.
    """
    capacity = block_table.shape[1] * page_size
    logical = ctx[:, None] + jnp.arange(c)[None, :]
    safe = jnp.minimum(logical, capacity - 1)
    phys = physical_rows(block_table, safe, page_size)
    return jnp.where(logical < capacity, phys, num_pages * page_size)


def append_chunk_kv(pool: PagedKVPool, k: jax.Array, v: jax.Array,
                    codes: Optional[jax.Array], block_table: jax.Array,
                    ctx: jax.Array) -> PagedKVPool:
    """Chunked-prefill append: k/v (B, C, H_kv, d) at logical rows
    [ctx, ctx + C); rows past the table capacity are dropped. ``ctx``
    is a scalar (B=1 prefill chunk) or (B,) per-row starts (the
    speculative verify wave appends one chunk per slot)."""
    if jnp.ndim(ctx) == 1:
        b, c = k.shape[:2]
        phys = _chunk_phys_rows(block_table, ctx, c, pool.page_size,
                                pool.num_pages).reshape(b * c)
        return PagedKVPool(
            k=_scatter_rows(pool.k, k.reshape((b * c,) + k.shape[2:]),
                            phys),
            v=_scatter_rows(pool.v, v.reshape((b * c,) + v.shape[2:]),
                            phys),
            codes=None if pool.codes is None
            else _scatter_rows(pool.codes,
                               codes.reshape((b * c,) + codes.shape[2:]),
                               phys))
    phys = _chunk_phys(block_table, ctx, k.shape[1], pool.page_size,
                       pool.num_pages)
    return PagedKVPool(
        k=_scatter_rows(pool.k, k[0], phys),
        v=_scatter_rows(pool.v, v[0], phys),
        codes=None if pool.codes is None
        else _scatter_rows(pool.codes, codes[0], phys))


def append_chunk_mla(pool: PagedMLAPool, ckv: jax.Array, krope: jax.Array,
                     codes: Optional[jax.Array], block_table: jax.Array,
                     ctx: jax.Array) -> PagedMLAPool:
    if jnp.ndim(ctx) == 1:
        b, c = ckv.shape[:2]
        phys = _chunk_phys_rows(block_table, ctx, c, pool.page_size,
                                pool.num_pages).reshape(b * c)
        return PagedMLAPool(
            ckv=_scatter_rows(pool.ckv,
                              ckv.reshape((b * c,) + ckv.shape[2:]), phys),
            krope=_scatter_rows(
                pool.krope, krope.reshape((b * c,) + krope.shape[2:]),
                phys),
            codes=None if pool.codes is None
            else _scatter_rows(pool.codes,
                               codes.reshape((b * c,) + codes.shape[2:]),
                               phys))
    phys = _chunk_phys(block_table, ctx, ckv.shape[1], pool.page_size,
                       pool.num_pages)
    return PagedMLAPool(
        ckv=_scatter_rows(pool.ckv, ckv[0], phys),
        krope=_scatter_rows(pool.krope, krope[0], phys),
        codes=None if pool.codes is None
        else _scatter_rows(pool.codes, codes[0], phys))


def logical_view(pool_leaf: jax.Array,
                 block_table: jax.Array) -> jax.Array:
    """Materialize the padded logical view of one pool leaf.

    pool_leaf: (P, page, ...), block_table: (B, T) ->
    (B, T * page, ...). Rows past a request's fill are garbage (drawn
    from whatever page the table names there — inactive table slots
    point at the scratch page) and must be masked by the consumer, which
    every caller already does through ``n_valid`` (or, for the chunked
    prefill, by causality at absolute positions). Only the *dense*
    decode fallback and the XLA reference paths read this: the HATA hot
    path and the pallas chunked prefill never materialize it (the paged
    score / gather / flash-prefill kernels all read pages in place
    through the block-table index_map).
    """
    page = pool_leaf.shape[1]
    flat = _flat(pool_leaf)
    b, t = block_table.shape
    logical = jnp.broadcast_to(jnp.arange(t * page)[None], (b, t * page))
    phys = physical_rows(block_table, logical, page)
    return flat[phys]


# ---------------------------------------------------------------------------
# Host-side page allocator
# ---------------------------------------------------------------------------
class PageAllocator:
    """Free list + refcounted pages (host side, no jax).

    Refcounts implement prefix sharing: an allocation starts at ref 1;
    adopting a shared page bumps it (:meth:`retain`); :meth:`release`
    drops it and returns the page to the free list at zero. Shared
    pages are immutable by construction (only *full* pages are ever
    shared), so no copy-on-write copy is needed.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        # pop() from the end -> ascending page ids first
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def free_count(self) -> int:
        return len(self._free)

    def used_count(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages at refcount 1, or None if short (the
        caller decides whether to evict, preempt, or wait)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        for p in pages:
            if self._ref.get(p, 0) <= 0:
                raise ValueError(f"retain of unallocated page {p}")
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> int:
        """Drop one ref per page; pages hitting zero return to the free
        list. Returns how many pages were actually freed."""
        freed = 0
        for p in pages:
            ref = self._ref.get(p, 0)
            if ref <= 0:
                raise ValueError(f"double free of page {p}")
            if ref == 1:
                del self._ref[p]
                self._free.append(p)
                freed += 1
            else:
                self._ref[p] = ref - 1
        return freed

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert the allocator invariants (property tests)."""
        held = set(self._ref)
        free = set(self._free)
        assert not (held & free), f"pages both held and free: {held & free}"
        assert len(self._free) == len(free), "duplicate free-list entries"
        assert held | free == set(range(self.num_pages)), "page leaked"
        assert all(r >= 1 for r in self._ref.values()), self._ref


class ShardedPageAllocator:
    """Per-shard free lists over ONE global page-id space.

    The sharded-pool paged engine shards a pool's page axis over the
    mesh's sequence axis: shard ``s`` physically holds global pages
    ``[s * pps, (s+1) * pps)`` with ``pps = num_pages // n_shards``.
    Block-table columns are sharded the same way, so the page backing
    column ``c`` must be OWNED by ``c``'s shard — allocation is
    therefore by shard (:meth:`alloc_shards`), while refcounting stays
    id-addressed (``retain``/``release`` route to the owner), which is
    exactly the :class:`PageAllocator` surface :class:`PrefixCache`
    needs: prefix pages sit at fixed column positions (column =
    logical_row // page_size), so a cached prefix page is always
    re-adopted into the same shard it lives on.

    Page-id convention (DESIGN.md §8): engine/transfer-layer tables
    carry GLOBAL ids; ``SPDecode(global_page_ids=True)`` derives each
    shard's local ids inside shard_map by subtracting the shard base.
    """

    def __init__(self, num_pages: int, n_shards: int):
        assert num_pages % n_shards == 0, (num_pages, n_shards)
        self.num_pages = num_pages
        self.n_shards = n_shards
        self.pages_per_shard = num_pages // n_shards
        pps = self.pages_per_shard
        # pop() from the end -> ascending ids first, per shard
        self._free: List[List[int]] = [
            list(range((s + 1) * pps - 1, s * pps - 1, -1))
            for s in range(n_shards)]
        self._ref: Dict[int, int] = {}

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    # ------------------------------------------------------------------
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    def shard_free_count(self, shard: int) -> int:
        return len(self._free[shard])

    def used_count(self) -> int:
        return self.num_pages - self.free_count()

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    # ------------------------------------------------------------------
    def alloc_shards(self, shards: Sequence[int]) -> Optional[List[int]]:
        """One page from each listed shard (repeats allowed), atomic:
        if ANY shard is dry nothing is allocated and None returns."""
        demand: Dict[int, int] = {}
        for s in shards:
            demand[s] = demand.get(s, 0) + 1
        if any(len(self._free[s]) < n for s, n in demand.items()):
            return None
        pages = [self._free[s].pop() for s in shards]
        for p in pages:
            self._ref[p] = 1
        return pages

    def alloc(self, n: int) -> Optional[List[int]]:
        """Shard-agnostic allocation (round-robin from the freest
        shards) — for callers that don't care about column placement,
        e.g. per-shard scratch reservation goes through
        :meth:`alloc_shards` instead."""
        if n > self.free_count():
            return None
        pages: List[int] = []
        for _ in range(n):
            s = max(range(self.n_shards),
                    key=lambda i: len(self._free[i]))
            pages.append(self._free[s].pop())
        for p in pages:
            self._ref[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        for p in pages:
            if self._ref.get(p, 0) <= 0:
                raise ValueError(f"retain of unallocated page {p}")
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> int:
        freed = 0
        for p in pages:
            ref = self._ref.get(p, 0)
            if ref <= 0:
                raise ValueError(f"double free of page {p}")
            if ref == 1:
                del self._ref[p]
                self._free[self.shard_of(p)].append(p)
                freed += 1
            else:
                self._ref[p] = ref - 1
        return freed

    # ------------------------------------------------------------------
    def check(self) -> None:
        held = set(self._ref)
        free = set(p for f in self._free for p in f)
        assert not (held & free), f"pages both held and free: {held & free}"
        assert sum(len(f) for f in self._free) == len(free), \
            "duplicate free-list entries"
        assert held | free == set(range(self.num_pages)), "page leaked"
        assert all(r >= 1 for r in self._ref.values()), self._ref
        for s in range(self.n_shards):
            lo, hi = s * self.pages_per_shard, (s + 1) * self.pages_per_shard
            assert all(lo <= p < hi for p in self._free[s]), \
                f"shard {s} free list holds foreign pages"


# ---------------------------------------------------------------------------
# Page shipping (disaggregated prefill -> decode transfer)
# ---------------------------------------------------------------------------
@jax.jit
def _gather_pages(leaf: jax.Array, ids: jax.Array) -> jax.Array:
    return leaf[ids]


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_pages(dst_leaf: jax.Array, ids: jax.Array,
                   rows: jax.Array) -> jax.Array:
    return dst_leaf.at[ids].set(rows.astype(dst_leaf.dtype))


def copy_pages(src_pool, dst_pool, src_ids, dst_ids, device=None):
    """Copy whole pages between two pools of the same layout.

    The disaggregated serving plane's ``Transfer`` boundary: gather the
    shipped pages from the prefill pool, (optionally) move them to the
    decode pool's device, scatter them at the remapped ids. Src pages
    are read in place (no donation); dst leaves are donated so the
    scatter stays a true in-place write. Returns the new dst pool.
    """
    src_leaves, treedef = jax.tree_util.tree_flatten(src_pool)
    dst_leaves = jax.tree_util.tree_leaves(dst_pool)
    si = jnp.asarray(src_ids, jnp.int32)
    di = jnp.asarray(dst_ids, jnp.int32)
    out = []
    for s, d in zip(src_leaves, dst_leaves):
        rows = _gather_pages(s, si)
        if device is not None:
            rows = jax.device_put(rows, device)
        out.append(_scatter_pages(d, di, rows))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Prefix cache (hash-of-prefix -> page), LRU
# ---------------------------------------------------------------------------
def _prefix_key(tokens: np.ndarray, n: int) -> bytes:
    return np.ascontiguousarray(tokens[:n], dtype=np.int32).tobytes()


class PrefixCache:
    """Full-page prefix reuse: token-prefix hash -> pool page.

    Each entry holds one allocator reference on its page, so cached
    prefixes outlive the request that produced them; :meth:`evict`
    drops LRU entries when the engine needs pages back. Lookups are
    clamped to ``prompt_len - 1`` tokens so a fully-cached prompt still
    runs its last token through prefill (the logits must come from
    somewhere), then rounded *down* to whole pages so adopters only
    ever write into pages they own alone.
    """

    def __init__(self, alloc: PageAllocator, page_size: int):
        self._alloc = alloc
        self.page_size = page_size
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------
    def register(self, tokens: np.ndarray, pages: Sequence[int]) -> None:
        """Offer a finished prefill's full pages to the cache."""
        psz = self.page_size
        n_full = min(len(pages), len(tokens) // psz)
        for i in range(n_full):
            key = _prefix_key(tokens, (i + 1) * psz)
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            self._alloc.retain([pages[i]])
            self._entries[key] = pages[i]

    def peek(self, tokens: np.ndarray) -> int:
        """Number of full prefix pages a :meth:`lookup` would return —
        WITHOUT touching refcounts, LRU order or hit/miss counters.
        Admission uses this for its watermark check so a request stuck
        waiting below the watermark doesn't churn the cache every
        engine step."""
        psz = self.page_size
        max_pages = max(0, (len(tokens) - 1) // psz)
        n = 0
        for i in range(max_pages):
            if _prefix_key(tokens, (i + 1) * psz) not in self._entries:
                break
            n += 1
        return n

    def lookup(self, tokens: np.ndarray) -> List[int]:
        """Longest cached full-page prefix of ``tokens``; the returned
        pages are retained for the caller (one ref each)."""
        psz = self.page_size
        max_pages = max(0, (len(tokens) - 1) // psz)
        pages: List[int] = []
        for i in range(max_pages):
            key = _prefix_key(tokens, (i + 1) * psz)
            page = self._entries.get(key)
            if page is None:
                break
            self._entries.move_to_end(key)
            pages.append(page)
        if pages:
            self._alloc.retain(pages)
            self.hits += 1
        else:
            self.misses += 1
        return pages

    def evict(self, n_pages: int) -> int:
        """Drop LRU entries until ~``n_pages`` pages were actually freed
        (an entry whose page is still referenced elsewhere frees
        nothing but its cache ref). Returns pages freed."""
        freed = 0
        while self._entries and freed < n_pages:
            _, page = self._entries.popitem(last=False)
            freed += self._alloc.release([page])
        return freed

    def clear(self) -> int:
        return self.evict(len(self._entries) + self._alloc.num_pages)
