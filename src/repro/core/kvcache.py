"""KV + hash-code cache structures (paper Alg. 1/3 state).

Fixed-capacity ring-free caches: arrays are allocated at ``max_len`` and
a scalar ``pos`` tracks fill. All append ops are ``dynamic_update_slice``
so the structures are jit/pjit friendly; sharding specs for the S axis
come from ``repro/distributed/sharding.py``.

Three cache families:
  * :class:`LayerKVCache`   — GQA/MHA: K/V per kv head + packed key codes.
  * :class:`MLACache`       — DeepSeek MLA: compressed latent c_kv + rope
                              key + one shared code stream (the
                              beyond-paper HATA+MLA extension).
  * :class:`SSMState`       — Mamba2: conv window + SSD recurrent state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.tree_util import register_dataclass


@register_dataclass
@dataclasses.dataclass
class LayerKVCache:
    k: jax.Array                      # (B, S_max, H_kv, d)
    v: jax.Array                      # (B, S_max, H_kv, d)
    codes: Optional[jax.Array]        # (B, S_max, H_kv, rbit//32) uint32

    @property
    def max_len(self) -> int:
        return self.k.shape[1]


@register_dataclass
@dataclasses.dataclass
class MLACache:
    ckv: jax.Array                    # (B, S_max, r)
    krope: jax.Array                  # (B, S_max, rope_dim)
    codes: Optional[jax.Array]        # (B, S_max, rbit//32) uint32

    @property
    def max_len(self) -> int:
        return self.ckv.shape[1]


@register_dataclass
@dataclasses.dataclass
class SSMState:
    conv: jax.Array                   # (B, d_conv - 1, conv_dim)
    ssm: jax.Array                    # (B, n_heads, head_dim, d_state)


def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  *, rbit: int = 0, dtype=jnp.bfloat16) -> LayerKVCache:
    codes = None
    if rbit:
        codes = jnp.zeros((batch, max_len, n_kv_heads, rbit // 32),
                          jnp.uint32)
    # k and v must be distinct buffers (donation aliases per leaf)
    return LayerKVCache(
        k=jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        codes=codes)


def init_mla_cache(batch: int, max_len: int, kv_lora_rank: int,
                   rope_dim: int, *, rbit: int = 0,
                   dtype=jnp.bfloat16) -> MLACache:
    codes = None
    if rbit:
        codes = jnp.zeros((batch, max_len, rbit // 32), jnp.uint32)
    return MLACache(
        ckv=jnp.zeros((batch, max_len, kv_lora_rank), dtype),
        krope=jnp.zeros((batch, max_len, rope_dim), dtype),
        codes=codes)


def init_ssm_state(batch: int, conv_dim: int, d_conv: int, n_heads: int,
                   head_dim: int, d_state: int, *,
                   dtype=jnp.float32) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, n_heads, head_dim, d_state), dtype))


def _upd(buf: jax.Array, val: jax.Array, pos: jax.Array) -> jax.Array:
    """Write ``val`` at sequence offset ``pos`` (axis 1).

    ``pos`` may be a scalar (aligned batch) or per-row (B,) — the
    continuous-batching engine decodes slots at different depths.
    With a sequence-parallel decode strategy installed, scalar writes
    run inside shard_map (masked local row writes) — GSPMD's own
    lowering of a DUS on a sharded dim is a whole-buffer ownership
    select.
    """
    if jnp.ndim(pos) == 1:
        if val.shape[1] > 1:
            # per-slot CHUNK write (speculative verify): DUS would
            # *clamp* a start near the wall and shift the window onto
            # committed rows, so multi-row per-slot writes go through a
            # scatter whose out-of-capacity rows are routed one past
            # the buffer and dropped — the contiguous twin of
            # paged_cache._chunk_phys_rows' drop convention.
            s_max = buf.shape[1]
            rows = pos[:, None] + jnp.arange(val.shape[1])[None]
            rows = jnp.where(rows < s_max, rows, s_max)

            def scatter_one(b_row, v_rows, r):
                return b_row.at[r].set(v_rows.astype(b_row.dtype))
            return jax.vmap(scatter_one)(buf, val, rows)

        # per-slot row write: vmap the DUS over the batch dim
        def one(b_row, v_row, p):
            idx = (p,) + (0,) * (b_row.ndim - 1)
            return jax.lax.dynamic_update_slice(
                b_row, v_row.astype(b_row.dtype), idx)
        return jax.vmap(one)(buf, val, pos)
    from repro.distributed.strategy import get_decode_strategy
    strat = get_decode_strategy()
    if strat is not None and hasattr(strat, "append_leaf"):
        return strat.append_leaf(buf, val, (), pos)
    idx = (0, pos) + (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype), idx)


def append_kv(cache: LayerKVCache, k: jax.Array, v: jax.Array,
              codes: Optional[jax.Array], pos: jax.Array) -> LayerKVCache:
    """Append S_new tokens at offset pos. k/v: (B, S_new, H_kv, d)."""
    return LayerKVCache(
        k=_upd(cache.k, k, pos),
        v=_upd(cache.v, v, pos),
        codes=None if cache.codes is None else _upd(cache.codes, codes, pos))


def append_mla(cache: MLACache, ckv: jax.Array, krope: jax.Array,
               codes: Optional[jax.Array], pos: jax.Array) -> MLACache:
    return MLACache(
        ckv=_upd(cache.ckv, ckv, pos),
        krope=_upd(cache.krope, krope, pos),
        codes=None if cache.codes is None else _upd(cache.codes, codes, pos))
