"""Learning-to-hash for top-k attention (paper §3.1, Eq. 3-9, App. B).

Trains per-(layer, kv-head) hash weights ``W_H ∈ R^{d×rbit}`` so that
``sign(x W_H)`` preserves the *relative order* of qk scores — the paper's
central reframing: selection needs ordinal comparison, not score
regression.

Loss (Eq. 9), with ``h(x) = 2·sigmoid(σ·xW_H) − 1`` relaxing the sign:

    ε · Σ_j Σ_i s_ji ‖h(q_j) − h(k_ji)‖²      (similarity preservation)
  + η · Σ_j ‖Σ_i h(k_ji)‖²                    (bit balance, relaxed Eq. 5)
  + λ · ‖W_HᵀW_H − I_r‖_F                     (bit uncorrelation, Eq. 6)

Labels s_ji come from :mod:`repro.data.hash_dataset` (App. B.1): top-10%
qk pairs get linearly decayed positives in [1, 20], the rest −1.
Optimizer: SGD, lr 0.1, momentum 0.9, weight decay 1e-6 (Table 11).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HataConfig
from repro.kernels import ops
from repro.optim.sgd import SGDState, sgd_init, sgd_update


def relaxed_hash(x: jax.Array, w_h: jax.Array, sigma: float) -> jax.Array:
    """Differentiable surrogate of sign(xW_H): 2·sigmoid(σ·xW_H) − 1."""
    return 2.0 * jax.nn.sigmoid(sigma * (x @ w_h)) - 1.0


def hash_loss(w_h: jax.Array, q: jax.Array, k: jax.Array, s: jax.Array,
              hcfg: HataConfig) -> jax.Array:
    """Eq. 9 on a batch of grouped triplets.

    w_h: (d, rbit);  q: (B, d) queries;  k: (B, M, d) the M keys paired
    with each query;  s: (B, M) similarity labels.
    """
    rbit = w_h.shape[1]
    hq = relaxed_hash(q.astype(jnp.float32), w_h, hcfg.sigma)   # (B, r)
    hk = relaxed_hash(k.astype(jnp.float32), w_h, hcfg.sigma)   # (B, M, r)
    # similarity preservation
    d2 = jnp.sum((hq[:, None, :] - hk) ** 2, axis=-1)           # (B, M)
    sim_term = jnp.sum(s * d2)
    # bit balance over each query's key set
    bal_term = jnp.sum(jnp.sum(hk, axis=1) ** 2)
    # bit uncorrelation
    gram = w_h.T @ w_h - jnp.eye(rbit, dtype=w_h.dtype)
    unc_term = jnp.linalg.norm(gram)
    n = q.shape[0] * k.shape[1]
    return (hcfg.epsilon * sim_term + hcfg.eta * bal_term) / n \
        + hcfg.lam * unc_term


class HashTrainState(NamedTuple):
    w_h: jax.Array
    opt: SGDState
    step: jax.Array


def hash_train_init(key: jax.Array, d: int, rbit: int) -> HashTrainState:
    w = jax.random.normal(key, (d, rbit), jnp.float32) / jnp.sqrt(d)
    return HashTrainState(w_h=w, opt=sgd_init(w), step=jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("hcfg", "lr", "momentum",
                                              "weight_decay"))
def hash_train_step(state: HashTrainState, q: jax.Array, k: jax.Array,
                    s: jax.Array, *, hcfg: HataConfig, lr: float = 0.1,
                    momentum: float = 0.9, weight_decay: float = 1e-6,
                    ) -> Tuple[HashTrainState, jax.Array]:
    loss, grad = jax.value_and_grad(hash_loss)(state.w_h, q, k, s, hcfg)
    w, opt = sgd_update(state.w_h, grad, state.opt, lr=lr,
                        momentum=momentum, weight_decay=weight_decay)
    return HashTrainState(w, opt, state.step + 1), loss


def train_hash_weights(key: jax.Array, q: jax.Array, k: jax.Array,
                       s: jax.Array, *, rbit: int, hcfg: HataConfig,
                       epochs: int = 15, iters: int = 20,
                       batch: int = 256, lr: float = 0.1) -> jax.Array:
    """Train one head's hash weights on grouped triplets (App. B.2 loop).

    q: (N, d), k: (N, M, d), s: (N, M). Paper: 15 epochs x 20 iterations
    per layer. Returns trained W_H (d, rbit) float32.
    """
    n, d = q.shape
    state = hash_train_init(key, d, rbit)
    steps = epochs * iters
    batch = min(batch, n)

    def body(carry, i):
        state, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, n)
        state, loss = hash_train_step(state, q[idx], k[idx], s[idx],
                                      hcfg=hcfg, lr=lr)
        return (state, key), loss

    (state, _), losses = jax.lax.scan(body, (state, key), jnp.arange(steps))
    return state.w_h


def train_hash_weights_per_head(key: jax.Array, q: jax.Array, k: jax.Array,
                                s: jax.Array, *, rbit: int,
                                hcfg: HataConfig, **kw) -> jax.Array:
    """vmapped multi-head training. q: (H, N, d), k: (H, N, M, d),
    s: (H, N, M) -> (H, d, rbit)."""
    keys = jax.random.split(key, q.shape[0])
    fn = functools.partial(train_hash_weights, rbit=rbit, hcfg=hcfg, **kw)
    return jax.vmap(fn)(keys, q, k, s)


# ---------------------------------------------------------------------------
# Non-linear (MLP) hash training — Spotlight-style 2-layer MLP before
# sign. Same Eq. 9 loss with the relaxed sign applied to the MLP output;
# the uncorrelation term regularizes the output projection w2 (the layer
# that determines bit correlation). Weight form: the dict pytree of
# core/hash_weights.py without the leading head axis.
# ---------------------------------------------------------------------------
def mlp_hash_init(key: jax.Array, d: int, hidden: int, rbit: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, hidden), jnp.float32)
        / jnp.sqrt(d),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, rbit), jnp.float32)
        / jnp.sqrt(hidden),
    }


def mlp_warm_start(w_lin: jax.Array) -> dict:
    """Embed a linear hash (d, rbit) exactly into the MLP form.

    With hidden = 2·rbit, ``relu(x[W, −W]) @ [I; −I] = xW`` — the MLP
    starts bit-identical to the linear hash, so fine-tuning can only
    move off a known-good point (the trainer keeps the better of the
    two on a validation split).
    """
    rbit = w_lin.shape[-1]
    eye = jnp.eye(rbit, dtype=jnp.float32)
    return {
        "w1": jnp.concatenate([w_lin, -w_lin], axis=-1),
        "b1": jnp.zeros((2 * rbit,), jnp.float32),
        "w2": jnp.concatenate([eye, -eye], axis=0),
    }


def relaxed_hash_mlp(x: jax.Array, w: dict, sigma: float) -> jax.Array:
    """Differentiable surrogate of sign(relu(xW1 + b1) W2)."""
    hid = jax.nn.relu(x @ w["w1"] + w["b1"])
    return 2.0 * jax.nn.sigmoid(sigma * (hid @ w["w2"])) - 1.0


def mlp_hash_loss(w: dict, q: jax.Array, k: jax.Array, s: jax.Array,
                  hcfg: HataConfig) -> jax.Array:
    """Eq. 9 with the MLP relaxation. Shapes as :func:`hash_loss`."""
    rbit = w["w2"].shape[-1]
    hq = relaxed_hash_mlp(q.astype(jnp.float32), w, hcfg.sigma)
    hk = relaxed_hash_mlp(k.astype(jnp.float32), w, hcfg.sigma)
    d2 = jnp.sum((hq[:, None, :] - hk) ** 2, axis=-1)
    sim_term = jnp.sum(s * d2)
    bal_term = jnp.sum(jnp.sum(hk, axis=1) ** 2)
    gram = w["w2"].T @ w["w2"] - jnp.eye(rbit, dtype=w["w2"].dtype)
    unc_term = jnp.linalg.norm(gram)
    n = q.shape[0] * k.shape[1]
    return (hcfg.epsilon * sim_term + hcfg.eta * bal_term) / n \
        + hcfg.lam * unc_term


class MLPHashTrainState(NamedTuple):
    w: dict
    opt: SGDState
    step: jax.Array


def mlp_hash_train_init(key: jax.Array, d: int, hidden: int,
                        rbit: int) -> MLPHashTrainState:
    w = mlp_hash_init(key, d, hidden, rbit)
    return MLPHashTrainState(w=w, opt=sgd_init(w),
                             step=jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("hcfg", "lr", "momentum",
                                              "weight_decay"))
def mlp_hash_train_step(state: MLPHashTrainState, q: jax.Array,
                        k: jax.Array, s: jax.Array, *, hcfg: HataConfig,
                        lr: float = 0.1, momentum: float = 0.9,
                        weight_decay: float = 1e-6,
                        ) -> Tuple[MLPHashTrainState, jax.Array]:
    loss, grad = jax.value_and_grad(mlp_hash_loss)(state.w, q, k, s, hcfg)
    w, opt = sgd_update(state.w, grad, state.opt, lr=lr,
                        momentum=momentum, weight_decay=weight_decay)
    return MLPHashTrainState(w, opt, state.step + 1), loss


def train_mlp_hash_weights(key: jax.Array, q: jax.Array, k: jax.Array,
                           s: jax.Array, *, rbit: int, hidden: int,
                           hcfg: HataConfig, epochs: int = 15,
                           iters: int = 20, batch: int = 256,
                           lr: float = 0.1,
                           init: Optional[dict] = None) -> dict:
    """MLP analogue of :func:`train_hash_weights`. Returns the trained
    weight dict {"w1", "b1", "w2"} (no leading head axis). ``init``
    (e.g. :func:`mlp_warm_start` of a trained linear hash) replaces the
    random initialization."""
    n, d = q.shape
    key, init_key = jax.random.split(key)
    if init is not None:
        state = MLPHashTrainState(w=init, opt=sgd_init(init),
                                  step=jnp.zeros((), jnp.int32))
    else:
        state = mlp_hash_train_init(init_key, d, hidden, rbit)
    steps = epochs * iters
    batch = min(batch, n)

    def body(carry, i):
        state, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, n)
        state, loss = mlp_hash_train_step(state, q[idx], k[idx], s[idx],
                                          hcfg=hcfg, lr=lr)
        return (state, key), loss

    (state, _), _ = jax.lax.scan(body, (state, key), jnp.arange(steps))
    return state.w


def train_mlp_hash_weights_per_head(key: jax.Array, q: jax.Array,
                                    k: jax.Array, s: jax.Array, *,
                                    rbit: int, hidden: int,
                                    hcfg: HataConfig,
                                    init: Optional[dict] = None,
                                    **kw) -> dict:
    """vmapped multi-head MLP training. q: (H, N, d), k: (H, N, M, d),
    s: (H, N, M) -> dict with leading H axis on every leaf. ``init``
    carries a leading H axis too."""
    keys = jax.random.split(key, q.shape[0])
    fn = functools.partial(train_mlp_hash_weights, rbit=rbit,
                           hidden=hidden, hcfg=hcfg, **kw)
    if init is None:
        return jax.vmap(fn)(keys, q, k, s)
    return jax.vmap(lambda ky, qh, kh, sh, w0:
                    fn(ky, qh, kh, sh, init=w0))(keys, q, k, s, init)


# ---------------------------------------------------------------------------
# Quality metrics + LSH baseline
# ---------------------------------------------------------------------------
def random_projection_lsh(key: jax.Array, d: int, rbit: int) -> jax.Array:
    """SimHash/MagicPIG-style random hyperplanes — the untrained baseline
    the paper beats (needs ~1500 bits where HATA needs 128)."""
    return jax.random.normal(key, (d, rbit), jnp.float32)


def hash_topk_recall(q: jax.Array, keys: jax.Array, w_h,
                     budget: int, *, rbit: int) -> jax.Array:
    """Recall of hash-selected top-k vs exact qk top-k.

    q: (Nq, d) held-out queries, keys: (S, d); w_h: (d, rbit) linear
    weights or the per-head MLP dict (core/hash_weights.py, no leading
    head axis). Returns (Nq,) recall.
    """
    true_scores = q.astype(jnp.float32) @ keys.astype(jnp.float32).T
    qc = ops.hash_encode(q, w_h)                      # (Nq, W)
    kc = ops.hash_encode(keys, w_h)                   # (S, W)
    x = jax.lax.population_count(
        jnp.bitwise_xor(qc[:, None, :], kc[None, :, :]))
    est = rbit - jnp.sum(x.astype(jnp.int32), axis=-1)
    from repro.core.topk import selection_recall
    return selection_recall(est.astype(jnp.float32), true_scores, budget)
