"""HATA-off: KV-cache offloading with hash-guided prefetch (paper §5.3,
Table 3; inspired by InfiniGen).

Layout: the *code cache* (rbit/8 bytes/token/kv-head) stays in HBM; the
K/V rows (2·d·kv_bytes bytes/token) live in host DRAM. A decode step:

  1. score on-device over the resident codes (tiny),
  2. top-k indices -> host,
  3. host gathers the k rows and DMAs them up over PCIe,
  4. sparse attention on device.

MagicPIG inverts this: hashing is cheap/random but needs ~1500 bits, and
its attention runs *on the CPU* — the paper's Table 3 speedups come from
(a) 128 trained bits vs 1500 random bits and (b) GPU attention + PCIe
prefetch vs CPU attention. Both effects fall out of the cost model here,
and the functional simulator executes the same data movement with host
numpy buffers so tests can verify exactness end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HataConfig
from repro.core.topk import chunked_topk
from repro.kernels import ops


# ---------------------------------------------------------------------------
# Cost model (Table 3 analogue; constants overridable per platform)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OffloadPlatform:
    pcie_gbs: float = 32.0        # PCIe 4.0 x16 effective
    hbm_gbs: float = 819.0        # v5e HBM
    host_gbs: float = 80.0        # host DRAM streaming (48 threads)
    host_flops: float = 2e12      # CPU attention throughput (fused f32)
    dev_flops: float = 197e12     # bf16 chip peak


def hata_off_decode_time(s: int, d: int, n_kv: int, g: int, *,
                         budget: int, rbit: int,
                         plat: OffloadPlatform) -> float:
    """Seconds per layer per decode step, HATA-off."""
    score_bytes = s * n_kv * rbit / 8                 # codes from HBM
    pcie_bytes = budget * n_kv * 2 * d * 2            # top-k K/V rows up
    attn_flops = 2 * 2 * g * n_kv * budget * d        # qk + pv
    return (score_bytes / (plat.hbm_gbs * 1e9)
            + pcie_bytes / (plat.pcie_gbs * 1e9)
            + attn_flops / plat.dev_flops)


def magicpig_decode_time(s: int, d: int, n_kv: int, g: int, *,
                         sample_frac: float = 0.025, lsh_bits: int = 1500,
                         plat: OffloadPlatform) -> float:
    """MagicPIG: LSH tables + sampled attention on the CPU."""
    probe_bytes = s * n_kv * lsh_bits / 8             # host hash tables
    sampled = int(s * sample_frac)
    attn_flops = 2 * 2 * g * n_kv * sampled * d
    attn_bytes = sampled * n_kv * 2 * d * 4           # f32 rows from DRAM
    cpu_time = max(attn_flops / plat.host_flops,
                   (probe_bytes + attn_bytes) / (plat.host_gbs * 1e9))
    out_bytes = g * n_kv * d * 4                      # result down+up PCIe
    return cpu_time + out_bytes / (plat.pcie_gbs * 1e9)


# ---------------------------------------------------------------------------
# Functional simulator (host KV + device codes), exact w.r.t. hata_decode
# ---------------------------------------------------------------------------
class OffloadedKV:
    """One layer's offloaded cache: codes on device, K/V on host."""

    def __init__(self, batch: int, max_len: int, n_kv: int, d: int,
                 rbit: int, dtype=np.float32):
        self.k_host = np.zeros((batch, max_len, n_kv, d), dtype)
        self.v_host = np.zeros((batch, max_len, n_kv, d), dtype)
        self.codes = jnp.zeros((batch, max_len, n_kv, rbit // 32),
                               jnp.uint32)
        self.pos = 0
        self.rbit = rbit
        self.bytes_pcie = 0       # accounting for benchmarks

    def append(self, k: np.ndarray, v: np.ndarray, w_h: jax.Array):
        s_new = k.shape[1]
        self.k_host[:, self.pos:self.pos + s_new] = k
        self.v_host[:, self.pos:self.pos + s_new] = v
        codes = ops.hash_encode_heads(jnp.asarray(k), w_h)
        self.codes = jax.lax.dynamic_update_slice(
            self.codes, codes, (0, self.pos, 0, 0))
        self.pos += s_new
        # prefill streams K/V down to host once:
        self.bytes_pcie += k.nbytes + v.nbytes

    def decode_step(self, q: jax.Array, k_new: np.ndarray,
                    v_new: np.ndarray, w_h: jax.Array,
                    hcfg: HataConfig) -> jax.Array:
        """q: (B, H, d) device; k/v_new: (B, 1, n_kv, d) host."""
        self.append(k_new, v_new, w_h)
        b, h, d = q.shape
        n_kv = self.k_host.shape[2]
        g = h // n_kv
        qg = q.reshape(b, n_kv, g, d)
        q_codes = jax.vmap(
            lambda x, w: ops.hash_encode(x, w),
            in_axes=(1, 0), out_axes=1)(qg, w_h)
        scores = ops.hamming_scores(q_codes, self.codes, rbit=self.rbit)
        pos_mask = jnp.arange(self.codes.shape[1]) < self.pos
        scores = jnp.where(pos_mask[None, None], scores, -1)
        budget = min(hcfg.budget(self.pos), self.pos)
        # same two-stage on-device top-k as the serving decode path
        # (core/topk.chunked_topk, bit-identical to lax.top_k): the
        # offload simulator's prefetch selection and the on-device
        # pipeline share one implementation.
        _, idx = chunked_topk(scores, budget)         # (B, n_kv, k)
        idx_np = np.asarray(idx)
        # host gather + PCIe up (the prefetch step)
        bi = np.arange(b)[:, None, None]
        hi = np.arange(n_kv)[None, :, None]
        kg = self.k_host[bi, idx_np, hi]              # (B, n_kv, k, d)
        vg = self.v_host[bi, idx_np, hi]
        self.bytes_pcie += kg.nbytes + vg.nbytes
        kj, vj = jnp.asarray(kg), jnp.asarray(vg)
        qf = qg.astype(jnp.float32) * (d ** -0.5)
        logits = jnp.einsum("bhgd,bhkd->bhgk", qf, kj.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgk,bhkd->bhgd", probs, vj.astype(jnp.float32))
        return out.reshape(b, h, d).astype(q.dtype)
