"""HATA-off: tiered KV offload with hash-guided prefetch (paper §5.3,
Table 3; inspired by InfiniGen).

Layout: the *code cache* (rbit/8 bytes/token/kv-head) stays in HBM; the
K/V rows (2·d·kv_bytes bytes/token) live in host DRAM. A decode step:

  1. score on-device over the resident codes (tiny),
  2. top-k indices -> host,
  3. host gathers the k rows and DMAs them up over PCIe,
  4. sparse attention on device over the staged rows.

Three tiers of machinery live here:

  * the **cost model** (Table 3 analogue): :func:`hata_off_decode_time`
    / :func:`hata_resident_decode_time` / :func:`magicpig_decode_time`.
    ``overlap=True`` models the double-buffered schedule where the PCIe
    upload of one wave's selection overlaps the previous wave's device
    work (attention + that layer's weight streaming): the wave interval
    becomes ``t_score + max(t_pcie, t_device)`` instead of their sum.
  * the **host tier** used by ``core.cache_view.OffloadedView``:
    :class:`HostPool` / :class:`HostMLAPool` (numpy page pools under
    the same page-id space and page/refcount discipline as the device
    pools — one ``PageAllocator`` governs both tiers), the
    :class:`OffloadedKVPool` / :class:`OffloadedMLAPool` containers
    (device codes pool + host row pool), and the
    :class:`PrefetchPipeline` (A/B staging slots + PCIe accounting).
  * the seed **functional simulator** :class:`OffloadedKV` — kept as
    the oracle the view is differential-tested against. Its selection
    path (batched q encode, masked scores, static clamped budget,
    ``chunked_topk``) is the same shared pipeline the model stack uses.

MagicPIG inverts the layout: hashing is cheap/random but needs ~1500
bits, and its attention runs *on the CPU* — the paper's Table 3
speedups come from (a) 128 trained bits vs 1500 random bits and (b) GPU
attention + PCIe prefetch vs CPU attention. Both effects fall out of
the cost model here, and the functional tier executes the same data
movement with host numpy buffers so tests can verify exactness
end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HataConfig
from repro.core import hash_attention as ha
from repro.core.topk import chunked_topk
from repro.kernels import ops


# ---------------------------------------------------------------------------
# Cost model (Table 3 analogue; constants overridable per platform)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OffloadPlatform:
    pcie_gbs: float = 32.0        # PCIe 4.0 x16 effective
    hbm_gbs: float = 819.0        # v5e HBM
    host_gbs: float = 80.0        # host DRAM streaming (48 threads)
    host_flops: float = 2e12      # CPU attention throughput (fused f32)
    dev_flops: float = 197e12     # bf16 chip peak


def hata_off_decode_time(s: int, d: int, n_kv: int, g: int, *,
                         budget: int, rbit: int, plat: OffloadPlatform,
                         kv_bytes: int = 2, layer_bytes: float = 0.0,
                         overlap: bool = False) -> float:
    """Seconds per layer per decode step, HATA-off.

    ``layer_bytes`` is the layer's own HBM weight traffic per decode
    step (projections + FFN — decode is weight-streaming-bound);
    ``overlap=False`` is the serial schedule (score -> PCIe -> attend),
    ``overlap=True`` the double-buffered one: while wave *t*'s staged
    rows are attended (and the layer's weights stream), wave *t+1*'s
    selection is already crossing PCIe into the other staging buffer,
    so the steady-state wave interval hides min(t_pcie, t_device).
    """
    score_bytes = s * n_kv * rbit / 8                 # codes from HBM
    pcie_bytes = budget * n_kv * 2 * d * kv_bytes     # top-k K/V rows up
    attn_flops = 2 * 2 * g * n_kv * budget * d        # qk + pv
    t_score = score_bytes / (plat.hbm_gbs * 1e9)
    t_pcie = pcie_bytes / (plat.pcie_gbs * 1e9)
    t_dev = (attn_flops / plat.dev_flops
             + layer_bytes / (plat.hbm_gbs * 1e9))
    if overlap:
        return t_score + max(t_pcie, t_dev)
    return t_score + t_pcie + t_dev


def hata_resident_decode_time(s: int, d: int, n_kv: int, g: int, *,
                              budget: int, rbit: int,
                              plat: OffloadPlatform, kv_bytes: int = 2,
                              layer_bytes: float = 0.0) -> float:
    """All-resident baseline (``PagedView``): same score + selection,
    but the budget rows are gathered from HBM instead of over PCIe."""
    score_bytes = s * n_kv * rbit / 8
    gather_bytes = budget * n_kv * 2 * d * kv_bytes
    attn_flops = 2 * 2 * g * n_kv * budget * d
    return ((score_bytes + gather_bytes + layer_bytes)
            / (plat.hbm_gbs * 1e9) + attn_flops / plat.dev_flops)


def magicpig_decode_time(s: int, d: int, n_kv: int, g: int, *,
                         sample_frac: float = 0.025, lsh_bits: int = 1500,
                         plat: OffloadPlatform) -> float:
    """MagicPIG: LSH tables + sampled attention on the CPU."""
    probe_bytes = s * n_kv * lsh_bits / 8             # host hash tables
    sampled = int(s * sample_frac)
    attn_flops = 2 * 2 * g * n_kv * sampled * d
    attn_bytes = sampled * n_kv * 2 * d * 4           # f32 rows from DRAM
    cpu_time = max(attn_flops / plat.host_flops,
                   (probe_bytes + attn_bytes) / (plat.host_gbs * 1e9))
    out_bytes = g * n_kv * d * 4                      # result down+up PCIe
    return cpu_time + out_bytes / (plat.pcie_gbs * 1e9)


def _require_packable(rbit: int) -> None:
    if rbit <= 0 or rbit % 32:
        raise ValueError(
            f"rbit={rbit} must be a positive multiple of 32: hash codes "
            "are bit-packed into uint32 words, so a non-multiple would "
            f"silently drop {rbit % 32} hash bits per code")


# ---------------------------------------------------------------------------
# Host-tier page pools (numpy; same page-id space as the device pools)
# ---------------------------------------------------------------------------
def physical_rows_np(block_table: np.ndarray, logical: np.ndarray,
                     page_size: int) -> np.ndarray:
    """Host-side twin of ``paged_cache.physical_rows``: translate
    logical rows (B, ...) to physical pool rows through a (B, T) block
    table — ``bt[b, l // page] * page + l % page``. Used at the
    host-gather boundary, where the selected logical indices have
    already been synced off-device."""
    b, t = block_table.shape
    li = logical // page_size
    bt = block_table.reshape((b,) + (1,) * (logical.ndim - 2) + (t,))
    pages = np.take_along_axis(
        np.broadcast_to(bt, logical.shape[:-1] + (t,)), li, axis=-1)
    return pages * page_size + logical % page_size


class HostPool:
    """One GQA/MHA layer's K/V rows in host memory, paged exactly like
    the device pools: ``(P, page, H_kv, d)`` numpy buffers addressed by
    *physical row id* (``page_id * page_size + slot``). Page ids are
    shared with the layer's device codes pool — one
    :class:`~repro.core.paged_cache.PageAllocator` (free list +
    refcounts) governs both tiers, so prefix sharing, preemption and
    the scratch-page convention apply to host rows unchanged."""

    def __init__(self, num_pages: int, page_size: int, n_kv_heads: int,
                 head_dim: int, dtype=np.float32):
        self.k = np.zeros((num_pages, page_size, n_kv_heads, head_dim),
                          dtype)
        self.v = np.zeros_like(self.k)

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes

    def _flat(self) -> Tuple[np.ndarray, np.ndarray]:
        n = self.num_pages * self.page_size
        return (self.k.reshape((n,) + self.k.shape[2:]),
                self.v.reshape((n,) + self.v.shape[2:]))

    def scatter_rows(self, k_rows: np.ndarray, v_rows: np.ndarray,
                     phys: np.ndarray) -> None:
        """Write rows (N, H_kv, d) at physical ids (N,); ids at or past
        the pool (the chunk-append drop convention) are skipped."""
        fk, fv = self._flat()
        ok = phys < fk.shape[0]
        fk[phys[ok]] = k_rows[ok].astype(fk.dtype)
        fv[phys[ok]] = v_rows[ok].astype(fv.dtype)

    def gather_heads(self, phys: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-head compact gather: phys (B, H_kv, k) physical ids ->
        (kg, vg) each (B, H_kv, k, d) — head h's slice follows its own
        selected rows, so exactly budget·2·d·kv_bytes bytes per kv head
        cross PCIe per wave."""
        fk, fv = self._flat()
        hi = np.arange(fk.shape[1])[None, :, None]
        return fk[phys, hi], fv[phys, hi]

    def logical(self, block_table: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded logical view (B, T*page, H_kv, d) — the dense
        fallback / prefill context read (garbage past the fill, masked
        by the consumer like ``paged_cache.logical_view``)."""
        b, t = block_table.shape
        page = self.page_size
        logical = np.broadcast_to(np.arange(t * page)[None],
                                  (b, t * page))
        phys = physical_rows_np(block_table, logical, page)
        fk, fv = self._flat()
        return fk[phys], fv[phys]


class HostMLAPool:
    """MLA twin of :class:`HostPool`: the shared latent stream's
    (ckv, krope) rows in host page buffers."""

    def __init__(self, num_pages: int, page_size: int, lora_rank: int,
                 rope_dim: int, dtype=np.float32):
        self.ckv = np.zeros((num_pages, page_size, lora_rank), dtype)
        self.krope = np.zeros((num_pages, page_size, rope_dim), dtype)

    @property
    def num_pages(self) -> int:
        return self.ckv.shape[0]

    @property
    def page_size(self) -> int:
        return self.ckv.shape[1]

    @property
    def nbytes(self) -> int:
        return self.ckv.nbytes + self.krope.nbytes

    def _flat(self) -> Tuple[np.ndarray, np.ndarray]:
        n = self.num_pages * self.page_size
        return (self.ckv.reshape((n,) + self.ckv.shape[2:]),
                self.krope.reshape((n,) + self.krope.shape[2:]))

    def scatter_rows(self, ckv_rows: np.ndarray, krope_rows: np.ndarray,
                     phys: np.ndarray) -> None:
        fc, fr = self._flat()
        ok = phys < fc.shape[0]
        fc[phys[ok]] = ckv_rows[ok].astype(fc.dtype)
        fr[phys[ok]] = krope_rows[ok].astype(fr.dtype)

    def gather_rows(self, phys: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """phys (B, k) -> (ckv (B, k, r), krope (B, k, rd))."""
        fc, fr = self._flat()
        return fc[phys], fr[phys]

    def logical(self, block_table: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
        b, t = block_table.shape
        page = self.page_size
        logical = np.broadcast_to(np.arange(t * page)[None],
                                  (b, t * page))
        phys = physical_rows_np(block_table, logical, page)
        fc, fr = self._flat()
        return fc[phys], fr[phys]


# ---------------------------------------------------------------------------
# Double-buffered staging + PCIe accounting
# ---------------------------------------------------------------------------
class PrefetchPipeline:
    """A/B staging slots + the PCIe ledger, shared across a model's
    offloaded layers (one pipeline per engine).

    Each ``stage(name, ...)`` upload lands in the slot of opposite
    parity to the previous one under the same name, so at most *two*
    waves' staged rows are device-resident per stream at any time —
    the in-kernel chunk pipeline's double buffer, one tier up. Wave
    *t*'s attention reads slot ``t % 2`` while wave *t+1*'s host
    gather + DMA lands in the other; on hardware with an async DMA
    engine the two proceed concurrently (the cost model's
    ``overlap=True`` schedule), and the functional tier preserves the
    exact same buffer discipline so the device-resident staging bound
    (``device_staged_bytes() <= 2 waves``) is a tested invariant, not
    an aspiration.

    The byte ledger is what the benchmarks and the serving stats read:
    ``bytes_up`` (host -> HBM row uploads), ``bytes_down`` (append
    spills), ``waves`` (gather waves staged).
    """

    def __init__(self, plat: Optional[OffloadPlatform] = None):
        self.plat = plat or OffloadPlatform()
        self.bytes_up = 0
        self.bytes_down = 0
        self.waves = 0
        self._slots = {}              # name -> [tuple | None, tuple | None]
        self._parity = {}             # name -> next slot to fill

    @property
    def bytes_pcie(self) -> int:
        return self.bytes_up + self.bytes_down

    def stage(self, name: str, *host_arrays: np.ndarray):
        """Upload host arrays into the next staging slot for ``name``;
        returns the device arrays (one, or a tuple). Accounts the
        upload and flips the slot parity."""
        devs = tuple(ops.device_put_accounted(a) for a in host_arrays)
        self.bytes_up += sum(a.nbytes for a in host_arrays)
        par = self._parity.get(name, 0)
        self._slots.setdefault(name, [None, None])[par] = devs
        self._parity[name] = par ^ 1
        self.waves += 1
        return devs[0] if len(devs) == 1 else devs

    def account_down(self, nbytes: int) -> None:
        """Append-path spill: fresh K/V rows streaming down to host."""
        self.bytes_down += int(nbytes)

    def account_up(self, nbytes: int) -> None:
        """Un-staged upload (dense fallback / prefill context reads)."""
        self.bytes_up += int(nbytes)

    def device_staged_bytes(self) -> int:
        """HBM held by staging right now — bounded by two waves' rows
        per stream (the double-buffer invariant)."""
        return sum(a.nbytes for slots in self._slots.values()
                   for devs in slots if devs is not None for a in devs)


# ---------------------------------------------------------------------------
# Offloaded layer pools (device codes + host rows) — what the serving
# engine holds per layer and core.cache_view.OffloadedView wraps
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class OffloadedKVPool:
    """One GQA/MHA layer's tiered pool: hash codes resident on device
    (``(P, page, H_kv, W)`` — the only per-token state HATA needs to
    *score*), K/V rows on host. NOT a pytree: the host half is plain
    numpy and the pipeline is a mutable ledger — offloaded waves run
    eagerly (see ``cache_view.OffloadedView``)."""
    codes: jax.Array
    host: HostPool
    pipeline: PrefetchPipeline

    @property
    def num_pages(self) -> int:
        return self.host.num_pages

    @property
    def page_size(self) -> int:
        return self.host.page_size

    def hbm_resident_bytes(self) -> int:
        """Device bytes this layer pins: resident codes + its share of
        the staging buffers (the pipeline total is engine-wide)."""
        return int(self.codes.nbytes)


@dataclasses.dataclass
class OffloadedMLAPool:
    """MLA twin: latent codes (P, page, W) on device, (ckv, krope)
    rows on host."""
    codes: jax.Array
    host: HostMLAPool
    pipeline: PrefetchPipeline

    @property
    def num_pages(self) -> int:
        return self.host.num_pages

    @property
    def page_size(self) -> int:
        return self.host.page_size

    def hbm_resident_bytes(self) -> int:
        return int(self.codes.nbytes)


def init_offloaded_kv_pool(num_pages: int, page_size: int,
                           n_kv_heads: int, head_dim: int, *, rbit: int,
                           dtype=np.float32,
                           pipeline: Optional[PrefetchPipeline] = None,
                           ) -> OffloadedKVPool:
    _require_packable(rbit)
    codes = jnp.zeros((num_pages, page_size, n_kv_heads, rbit // 32),
                      jnp.uint32)
    host = HostPool(num_pages, page_size, n_kv_heads, head_dim,
                    dtype=np.dtype(dtype))
    return OffloadedKVPool(codes, host, pipeline or PrefetchPipeline())


def init_offloaded_mla_pool(num_pages: int, page_size: int,
                            lora_rank: int, rope_dim: int, *, rbit: int,
                            dtype=np.float32,
                            pipeline: Optional[PrefetchPipeline] = None,
                            ) -> OffloadedMLAPool:
    _require_packable(rbit)
    codes = jnp.zeros((num_pages, page_size, rbit // 32), jnp.uint32)
    host = HostMLAPool(num_pages, page_size, lora_rank, rope_dim,
                       dtype=np.dtype(dtype))
    return OffloadedMLAPool(codes, host, pipeline or PrefetchPipeline())


# ---------------------------------------------------------------------------
# Functional simulator (host KV + device codes), exact w.r.t. hata_decode
# ---------------------------------------------------------------------------
class OffloadedKV:
    """One layer's offloaded cache: codes on device, K/V on host.

    The seed prefetch simulator, kept as the *oracle* for the tiered
    :class:`~repro.core.cache_view.OffloadedView`: its selection path
    is the shared batched pipeline (``ha.aggregate_q_codes`` encode,
    ``ha.mask_scores`` validity/window masking, the *static*
    ``ha.clamped_budget`` top-k via ``chunked_topk``), so view and
    simulator pick bit-identical rows; only the final attend differs
    (reference einsum here vs the fused gathered kernel there)."""

    def __init__(self, batch: int, max_len: int, n_kv: int, d: int,
                 rbit: int, dtype=np.float32):
        _require_packable(rbit)
        self.k_host = np.zeros((batch, max_len, n_kv, d), dtype)
        self.v_host = np.zeros((batch, max_len, n_kv, d), dtype)
        self.codes = jnp.zeros((batch, max_len, n_kv, rbit // 32),
                               jnp.uint32)
        self.pos = 0
        self.rbit = rbit
        self.bytes_pcie = 0       # accounting for benchmarks

    def append(self, k: np.ndarray, v: np.ndarray, w_h: jax.Array):
        s_new = k.shape[1]
        self.k_host[:, self.pos:self.pos + s_new] = k
        self.v_host[:, self.pos:self.pos + s_new] = v
        codes = ops.hash_encode_heads(jnp.asarray(k), w_h)
        self.codes = jax.lax.dynamic_update_slice(
            self.codes, codes, (0, self.pos, 0, 0))
        self.pos += s_new
        # prefill streams K/V down to host once:
        self.bytes_pcie += k.nbytes + v.nbytes

    def decode_step(self, q: jax.Array, k_new: np.ndarray,
                    v_new: np.ndarray, w_h: jax.Array,
                    hcfg: HataConfig,
                    window: Optional[int] = None) -> jax.Array:
        """q: (B, H, d) device; k/v_new: (B, 1, n_kv, d) host."""
        self.append(k_new, v_new, w_h)
        b, h, d = q.shape
        n_kv = self.k_host.shape[2]
        # one encode implementation repo-wide: the shared per-group
        # batched q encode (models/attention.py's _hata_score_select)
        q_codes = ha.aggregate_q_codes(q, w_h, n_kv)
        scores = ops.hamming_scores(q_codes, self.codes, rbit=self.rbit)
        scores = ha.mask_scores(scores, self.pos, window=window)
        # the budget is STATIC — derived from the cache capacity (and
        # window), exactly like the model stack's clamped_budget call —
        # so every decode step shares one trace and one selection shape
        budget = ha.clamped_budget(hcfg, self.codes.shape[1], window)
        # same two-stage on-device top-k as the serving decode path
        # (core/topk.chunked_topk, bit-identical to lax.top_k): the
        # offload simulator's prefetch selection and the on-device
        # pipeline share one implementation.
        top, idx = chunked_topk(scores, budget)       # (B, n_kv, k)
        idx_np = np.asarray(idx)
        # host gather + PCIe up (the prefetch step)
        bi = np.arange(b)[:, None, None]
        hi = np.arange(n_kv)[None, :, None]
        kg = self.k_host[bi, idx_np, hi]              # (B, n_kv, k, d)
        vg = self.v_host[bi, idx_np, hi]
        self.bytes_pcie += kg.nbytes + vg.nbytes
        kj, vj = jnp.asarray(kg), jnp.asarray(vg)
        qg = q.reshape(b, n_kv, h // n_kv, d)
        qf = qg.astype(jnp.float32) * (d ** -0.5)
        logits = jnp.einsum("bhgd,bhkd->bhgk", qf, kj.astype(jnp.float32))
        # the static budget can exceed the live row count — selections
        # carrying the -1 mask floor are excluded from the softmax
        # (same sel_valid convention as the fused gather kernels)
        logits = jnp.where((top >= 0)[:, :, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgk,bhkd->bhgd", probs, vj.astype(jnp.float32))
        return out.reshape(b, h, d).astype(q.dtype)
