"""Host-sharded, prefetching data pipeline.

In a multi-host deployment each process generates only its slice of the
global batch (``host_slice``), and the arrays are assembled into a
globally-sharded jax.Array with ``jax.make_array_from_process_local_data``.
On this single-process container that collapses to a ``device_put`` with
the batch sharding — same code path, one process.

Prefetch: a background thread keeps ``depth`` batches ready so host-side
generation overlaps device compute. ``skip_to(step)`` is O(1) thanks to
the deterministic ``batch_at`` contract — restart never replays or
skips data.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.data.synthetic import SyntheticLM


class DataPipeline:
    def __init__(self, source: SyntheticLM, *, sharding=None,
                 depth: int = 2, start_step: int = 0):
        self.source = source
        self.sharding = sharding
        self.depth = depth
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---- multi-host slicing -------------------------------------------
    def host_slice(self, arr: np.ndarray) -> np.ndarray:
        n = jax.process_count()
        i = jax.process_index()
        per = arr.shape[0] // n
        return arr[i * per:(i + 1) * per]

    def _put_device(self, arr: np.ndarray):
        local = self.host_slice(arr)
        if self.sharding is not None:
            if jax.process_count() > 1:
                return jax.make_array_from_process_local_data(
                    self.sharding, local)
            return jax.device_put(local, self.sharding)
        return local

    # ---- iteration -----------------------------------------------------
    def skip_to(self, step: int):
        assert self._thread is None, "skip before starting iteration"
        self._step = step

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        try:
            while True:
                step, batch = self._q.get()
                yield step, self._put_device(batch)
        finally:
            self._stop.set()

    def stop(self):
        self._stop.set()
