"""Deterministic synthetic LM data.

Two generators:
  * ``zipf``       — iid Zipf-distributed tokens (throughput testing).
  * ``induction``  — sequences built from repeated random segments, so a
    small model can learn in-context copying and the loss measurably
    drops within a few hundred steps (the e2e training example's task).

Deterministic in (seed, step): ``batch_at(step)`` is a pure function, so
restart-after-failure resumes the exact stream (no data replay drift) —
the property the checkpoint/restart test asserts.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, batch: int, *,
                 kind: str = "induction", seed: int = 0,
                 n_codebooks: int = 0):
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.kind = kind
        self.seed = seed
        self.n_codebooks = n_codebooks

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        if self.n_codebooks:
            return rng.integers(
                0, self.vocab,
                (self.batch, self.seq_len, self.n_codebooks),
                dtype=np.int32)
        if self.kind == "zipf":
            z = rng.zipf(1.2, (self.batch, self.seq_len))
            return (z % self.vocab).astype(np.int32)
        return self._induction(rng)

    def _induction(self, rng) -> np.ndarray:
        """Repeat random segments: ...[seg A][seg B][seg A][seg C]...
        Predicting inside a repeat is learnable; boundaries are not."""
        out = np.empty((self.batch, self.seq_len), np.int32)
        for b in range(self.batch):
            toks = []
            segs = []
            while len(toks) < self.seq_len:
                if segs and rng.random() < 0.5:
                    seg = segs[rng.integers(len(segs))]
                else:
                    seg = rng.integers(0, self.vocab,
                                       rng.integers(8, 24)).tolist()
                    segs.append(seg)
                toks.extend(seg)
            out[b] = toks[: self.seq_len]
        return out
