from repro.data.hash_dataset import build_triplets, harvest_qk
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLM

__all__ = ["SyntheticLM", "DataPipeline", "build_triplets", "harvest_qk"]
