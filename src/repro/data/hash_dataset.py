"""Hash-training data construction (paper App. B.1).

From a prefill run of a real model we harvest per-head (Q, K); for each
sampled query q_m (m uniform in [n/2, n)) the causal keys k_1..k_m are
scored, the top-10% become positives with linearly decayed labels in
[1, 20] (best rank -> 20), the rest get label -1. Triplets are grouped
as (q, M keys, M labels) batches for the Eq. 9 trainer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HataConfig, ModelConfig
from repro.models import attention as attn_mod
from repro.models import blocks as blocks_mod
from repro.models.layers import rms_norm
from repro.models.transformer import Model


# ---------------------------------------------------------------------------
# Harvest q/k from a model layer (prefill-time capture)
# ---------------------------------------------------------------------------
def harvest_qk(model: Model, params, batch: Dict, layer: int,
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (q (B, S, H, dh), k (B, S, H_kv, dh)) of one layer.

    For MLA (beyond-paper), returns the *latent-space* pair:
    q (B, S, H, r+rope) absorbed queries, k (B, S, 1, r+rope) latents —
    exactly the vectors HashEncode sees at inference.
    """
    cfg = model.cfg
    x = model.embed(params, batch["tokens"])
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(x.dtype) @ params["img_proj"]

    def layer_params(i):
        if i < model.n_pre:
            return params["pre"][i], "main"
        j = i - model.n_pre
        if cfg.family == "vlm":
            ce = cfg.vlm.cross_every
            g, r = divmod(j, ce)
            if r == ce - 1:
                return jax.tree.map(lambda t: t[g],
                                    params["cross_stack"]), "cross"
            return jax.tree.map(lambda t: t[g][r],
                                params["stack"]), "main"
        return jax.tree.map(lambda t: t[j], params["stack"]), "main"

    for i in range(layer):
        bp, kind = layer_params(i)
        kind_name = "cross" if kind == "cross" else model.kind
        x, _ = blocks_mod.block_train(cfg, bp, None, x, kind_name,
                                      img=img)
    bp, kind = layer_params(layer)
    assert kind == "main", "harvest target must be a self-attention layer"
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    positions = jnp.arange(h.shape[1])
    if cfg.mla is not None:
        q_nope, q_rope, ckv, krope = attn_mod._mla_qkv(
            cfg, bp["attn"], h, positions)
        b, s = h.shape[0], h.shape[1]
        q_lat = jax.vmap(lambda qn, qr: attn_mod._mla_latent_q(
            cfg, bp["attn"], qn, qr), in_axes=1, out_axes=1)(
            q_nope, q_rope)                         # (B, S, H, r+rd)
        k_lat = jnp.concatenate([ckv, krope], -1)[:, :, None, :]
        return np.asarray(q_lat, np.float32), np.asarray(k_lat, np.float32)
    q, k, _ = attn_mod._project_qkv(cfg, bp["attn"], h, positions)
    return np.asarray(q, np.float32), np.asarray(k, np.float32)


# ---------------------------------------------------------------------------
# Triplet construction (App. B.1 steps 2-5)
# ---------------------------------------------------------------------------
def build_triplets(q: np.ndarray, k: np.ndarray, hcfg: HataConfig, *,
                   n_queries: int = 64, m_keys: int = 64,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray]:
    """One kv-head group's triplets.

    q: (B, S, G, d) the query heads sharing this kv head;
    k: (B, S, d) this head's keys.
    Returns (qs (N, d), ks (N, M, d), labels (N, M)), N = B*G*n_queries.
    """
    rng = np.random.default_rng(seed)
    b, s, g, d = q.shape
    qs, ks, ls = [], [], []
    for bi in range(b):
        for gi in range(g):
            for _ in range(n_queries):
                m = int(rng.integers(s // 2, s))
                qv = q[bi, m, gi]                       # (d,)
                keys = k[bi, : m + 1]                   # (m+1, d)
                scores = keys @ qv
                order = np.argsort(-scores)
                npos = max(1, int(np.ceil(hcfg.pos_frac * (m + 1))))
                labels = np.full(m + 1, hcfg.neg_label, np.float32)
                ranks = np.arange(npos, dtype=np.float32)
                # linear decay: best rank -> pos_label_max, last -> 1
                decay = (hcfg.pos_label_max
                         - ranks * (hcfg.pos_label_max - 1.0)
                         / max(npos - 1, 1))
                labels[order[:npos]] = decay
                # subsample a fixed-size key set: keep positives first
                pos_take = min(npos, m_keys // 4)
                pos_idx = order[:pos_take]
                neg_pool = order[npos:]
                if len(neg_pool) == 0:
                    neg_pool = order
                neg_idx = rng.choice(neg_pool, m_keys - pos_take,
                                     replace=len(neg_pool) < m_keys)
                sel = np.concatenate([pos_idx, neg_idx])
                qs.append(qv)
                ks.append(keys[sel])
                ls.append(labels[sel])
    return (np.stack(qs).astype(np.float32),
            np.stack(ks).astype(np.float32),
            np.stack(ls).astype(np.float32))


def build_triplets_per_head(model: Model, params, batches, layer: int,
                            hcfg: HataConfig, **kw):
    """All kv heads of one layer, multiple sequences (B.1 'dozens of
    sequences'). Returns (H_kv, N, d), (H_kv, N, M, d), (H_kv, N, M)."""
    cfg = model.cfg
    per_head: Dict[int, list] = {}
    for batch in batches:
        q, k = harvest_qk(model, params, batch, layer)
        b, s, h, d = q.shape
        h_kv = k.shape[2]
        g = h // h_kv
        qg = q.reshape(b, s, h_kv, g, d)
        for hi in range(h_kv):
            per_head.setdefault(hi, []).append(
                build_triplets(qg[:, :, hi], k[:, :, hi], hcfg, **kw))
    out_q, out_k, out_l = [], [], []
    for hi in sorted(per_head):
        qs = np.concatenate([t[0] for t in per_head[hi]])
        ks = np.concatenate([t[1] for t in per_head[hi]])
        ls = np.concatenate([t[2] for t in per_head[hi]])
        out_q.append(qs), out_k.append(ks), out_l.append(ls)
    return (np.stack(out_q), np.stack(out_k), np.stack(out_l))
