"""Analytic FLOP/byte models per (arch x shape) cell.

The §Roofline table reports BOTH the while-aware HLO parse (pessimistic:
includes CPU-backend legalization residue the TPU wouldn't execute) and
these first-principles numbers (optimistic: perfect fusion). The truth
on hardware lies between; the ratio MODEL_FLOPS / HLO_FLOPS is the
"useful compute" fraction the brief asks for.

MODEL_FLOPS: 6·N·D (train, active params for MoE), 2·N·D (prefill)
plus exact attention terms; decode adds the HATA scoring/gather bytes
(the paper's mechanism) to MODEL_BYTES.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.budgets import resolve_budget

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _attn_flops_per_layer(cfg: ModelConfig, s: int, causal: bool) -> float:
    """qk + pv flops for one full-attention layer, one sequence."""
    if cfg.attention_free:
        return 0.0
    factor = 0.5 if causal else 1.0
    if cfg.mla is not None:
        m = cfg.mla
        d_qk = m.qk_nope_dim + m.qk_rope_dim
        return 2.0 * s * s * factor * cfg.n_heads * (d_qk
                                                     + m.v_head_dim)
    return 2.0 * s * s * factor * cfg.n_heads * 2 * cfg.head_dim


def _ssm_flops_per_layer(cfg: ModelConfig, s: int) -> float:
    if cfg.ssm is None:
        return 0.0
    ss = cfg.ssm
    di = ss.d_inner(cfg.d_model)
    nh = ss.n_heads(cfg.d_model)
    q = ss.chunk
    # intra-chunk dual form + state path per chunk
    per_chunk = (2 * q * q * nh * ss.d_state        # C Bᵀ
                 + 2 * q * q * di                   # M @ u
                 + 2 * 2 * q * di * ss.d_state)     # state in/out
    return (s / q) * per_chunk


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Per-STEP global flops (all chips), plus MODEL_FLOPS = 6ND."""
    b, s = shape.global_batch, shape.seq_len
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = b * s
        dense = 6.0 * n_active * tokens
        attn = 3.0 * b * cfg.n_layers * _attn_flops_per_layer(
            cfg, s, True)
        ssm = 3.0 * b * cfg.n_layers * _ssm_flops_per_layer(cfg, s)
        return {"model_flops": dense + attn + ssm, "six_nd": dense}
    if shape.kind == "prefill":
        tokens = b * s
        dense = 2.0 * n_active * tokens
        attn = b * cfg.n_layers * _attn_flops_per_layer(cfg, s, True)
        ssm = b * cfg.n_layers * _ssm_flops_per_layer(cfg, s)
        return {"model_flops": dense + attn + ssm, "six_nd": dense}
    # decode: one token per sequence
    dense = 2.0 * n_active * b
    budget = resolve_budget(cfg.hata, s) if cfg.hata.enabled else s
    if cfg.attention_free:
        attn = b * cfg.n_layers * (4.0 * cfg.ssm.d_inner(cfg.d_model)
                                   * cfg.ssm.d_state)
    else:
        rows_dense = s * cfg.hata.dense_layers
        rows_hata = budget * (cfg.n_layers - cfg.hata.dense_layers)
        d_qk = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
                if cfg.mla else 2 * cfg.head_dim)
        attn = 2.0 * b * cfg.n_heads * d_qk * (rows_dense + rows_hata)
    return {"model_flops": dense + attn, "six_nd": dense}


def model_bytes(cfg: ModelConfig, shape: ShapeConfig,
                hata: bool = True) -> float:
    """Per-step global HBM bytes (dominant streams only)."""
    b, s = shape.global_batch, shape.seq_len
    dt = 2  # bf16
    p_bytes = cfg.param_count() * dt
    if shape.kind == "train":
        # fwd+bwd param reads + grad writes + optimizer state touch
        return 3 * p_bytes + 2 * cfg.param_count() * 4 * 2
    if shape.kind == "prefill":
        kv_write = (b * s * cfg.n_layers
                    * _kv_row_bytes(cfg))
        return p_bytes + kv_write
    # decode
    if cfg.attention_free:
        di = cfg.ssm.d_inner(cfg.d_model)
        state = cfg.n_layers * b * (cfg.ssm.n_heads(cfg.d_model)
                                    * cfg.ssm.head_dim * cfg.ssm.d_state
                                    * 4) * 2
        return p_bytes + state
    row = _kv_row_bytes(cfg)
    budget = resolve_budget(cfg.hata, s)
    nl, ndl = cfg.n_layers, cfg.hata.dense_layers
    if not (hata and cfg.hata.enabled):
        return p_bytes + nl * b * s * row
    codes = s * (cfg.hata.rbit // 8) * (cfg.n_kv_heads
                                        if cfg.mla is None else 1)
    per_hata_layer = b * (codes + budget * row)
    per_dense_layer = b * s * row
    return p_bytes + ndl * per_dense_layer + (nl - ndl) * per_hata_layer


def _kv_row_bytes(cfg: ModelConfig) -> int:
    dt = 2
    if cfg.mla is not None:
        return (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * dt
    return 2 * cfg.n_kv_heads * cfg.head_dim * dt


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_dev: float) -> Dict[str, float]:
    """Per-device roofline terms in seconds + the dominant bottleneck."""
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_n = coll_dev / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "bottleneck": dom[1],
            "bound_s": max(t_c, t_m, t_n)}
