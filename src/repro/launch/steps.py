"""Step builders shared by the dry-run, trainer and server.

``input_specs`` (the brief's contract): ShapeDtypeStruct stand-ins for
every model input of a (config, shape) cell — weak-type-correct,
shardable, zero allocation.

``make_train_step`` builds the jit-able (params, opt, batch) -> (params,
opt, metrics) function with microbatched gradient accumulation (the
knob that bounds activation memory at the 405B train shape) and AdamW.

``make_prefill_step`` / ``make_decode_step`` build the serving steps
(paper Alg. 1 / Alg. 3); decode expects the SPDecode strategy installed
when caches are sequence-sharded.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import linear_warmup_cosine


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one cell (no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            toks = jax.ShapeDtypeStruct((b, s, cfg.audio.n_codebooks), i32)
        else:
            toks = jax.ShapeDtypeStruct((b, s), i32)
        out = {"tokens": toks}
        if cfg.family == "vlm":
            out["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm.n_image_tokens, cfg.vlm.vision_dim),
                jnp.bfloat16)
        return out
    # decode: one new token against a seq_len cache
    if cfg.family == "audio":
        return {"tokens": jax.ShapeDtypeStruct((b, cfg.audio.n_codebooks),
                                               i32)}
    return {"tokens": jax.ShapeDtypeStruct((b,), i32)}


def cache_specs_abstract(model: Model, shape: ShapeConfig,
                         layout: str = "stacked"):
    """Abstract decode caches for one cell (eval_shape, no allocation)."""
    return jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq_len,
                                  layout=layout))


# ---------------------------------------------------------------------------
# training step
# ---------------------------------------------------------------------------
def pick_micro_batches(cfg: ModelConfig, batch: int, dp: int,
                       seq_len: int = 4096,
                       tokens_per_device: int = 16384) -> int:
    """Microbatch count: bound LIVE tokens per device per microbatch
    (~16k) so activation memory is flat in global batch. §Perf note:
    the original heuristic keyed on d_model and left every model under
    4096 wide unmicrobatched — hymba's train_4k sat at 2.1 TiB/device
    of scan-saved SSD intermediates (EXPERIMENTS.md §Perf, iteration
    H1). Always returns a divisor of the batch with micro_batch >= dp.
    """
    target_mb = max(dp, (tokens_per_device * dp) // max(seq_len, 1))
    target_mb = min(batch, target_mb)
    n = max(1, batch // target_mb)
    while batch % n:
        n -= 1
    return n


def make_train_step(model: Model, *, n_micro: int = 1,
                    base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000):
    cfg = model.cfg

    def mb_grads(params, mb):
        def loss_fn(p):
            loss, metrics = model.loss(p, mb)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        return loss, grads

    def train_step(params, opt: AdamWState, batch):
        if n_micro == 1:
            loss, grads = mb_grads(params, batch)
        else:
            def re(x):
                return x.reshape(n_micro, x.shape[0] // n_micro,
                                 *x.shape[1:])
            mbs = jax.tree.map(re, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                loss, grads = mb_grads(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, g_sum)
            loss = l_sum / n_micro
        lr = linear_warmup_cosine(opt.step, base_lr=base_lr,
                                  warmup=warmup, total_steps=total_steps)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss, "lr": lr}

    return train_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(model: Model):
    def prefill_step(params, batch, caches):
        return model.prefill(params, batch, caches, jnp.int32(0))
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, caches, pos):
        return model.decode_step(params, tokens, caches, pos)
    return decode_step
