"""Serving driver: continuous-batching engine over synthetic requests.

Demonstrates the paper's end-to-end inference loop (Alg. 1 prefill +
Alg. 3 HATA decode) with batched requests; prints per-request latency
and engine throughput. Reduced configs run on this CPU container; the
same engine serves full configs on a pod (decode is the jit'd
sequence-parallel step).

``--paged`` serves on the paged scheduler instead: one shared page pool
per layer, chunked prefill interleaved with decode waves, prefix
sharing, preemption — the model is driven through the same view-typed
``decode_step``/``prefill_chunk`` as the dense engine (the pools +
block table are wrapped in ``core.cache_view.PagedView``s per wave).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import Model
from repro.serving import PagedServingEngine, Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve on the paged scheduler (page pools + "
                         "block tables through the cache-view API)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--offload", action="store_true",
                    help="tiered pools on the paged scheduler: HATA "
                         "layers keep only hash codes in HBM, K/V rows "
                         "page to host and only the top-k budget "
                         "crosses PCIe per wave (implies --paged)")
    ap.add_argument("--hbm-budget-mb", type=float, default=None,
                    help="with --offload: watermark admission against "
                         "this HBM-resident budget (codes + staging)")
    args = ap.parse_args(argv)
    if args.offload:
        args.paged = True

    cfg = (get_reduced(args.arch) if args.reduced
           else get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.paged:
        # pool sized to the dense engine's row budget; max_len_pages
        # covers its per-request capacity (rounded UP to whole pages —
        # equal, and the HATA budget identical, when page_size divides
        # max_len; rounding down would truncate sooner than dense)
        table_pages = -(-args.max_len // args.page_size)
        budget = (None if args.hbm_budget_mb is None
                  else int(args.hbm_budget_mb * 2**20))
        engine = PagedServingEngine(
            model, params,
            num_pages=args.max_batch * table_pages + 1,
            page_size=args.page_size, max_batch=args.max_batch,
            max_len_pages=table_pages, offload=args.offload,
            hbm_budget_bytes=budget)
    else:
        engine = ServingEngine(model, params, max_batch=args.max_batch,
                               max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    nb = cfg.audio.n_codebooks if cfg.family == "audio" else 0
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len))
        shape = (plen, nb) if nb else (plen,)
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, shape,
                                dtype=np.int32),
            max_new_tokens=args.new_tokens))

    t0 = time.monotonic()
    done = engine.run(reqs)
    dt = time.monotonic() - t0
    for r in sorted(done, key=lambda r: r.id):
        ttft = (r.t_first_token - r.t_submit) * 1e3
        total = (r.t_done - r.t_submit) * 1e3
        print(f"req {r.id:3d} prompt={r.prompt_len:4d} "
              f"out={len(r.output):4d} ttft={ttft:8.1f}ms "
              f"total={total:8.1f}ms")
    mode = ("offload" if args.offload
            else "paged" if args.paged else "dense")
    print(f"[serve/{mode}] {engine.stats} wall={dt:.2f}s "
          f"tok/s={engine.stats['tokens_out'] / dt:.1f}")
    return done


if __name__ == "__main__":
    main()
