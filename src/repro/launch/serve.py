"""Serving driver: continuous-batching engine over synthetic requests.

Demonstrates the paper's end-to-end inference loop (Alg. 1 prefill +
Alg. 3 HATA decode) with batched requests; prints per-request latency
and engine throughput. Reduced configs run on this CPU container; the
same engine serves full configs on a pod (decode is the jit'd
sequence-parallel step).

``--paged`` serves on the paged scheduler instead: one shared page pool
per layer, chunked prefill interleaved with decode waves, prefix
sharing, preemption — the model is driven through the same view-typed
``decode_step``/``prefill_chunk`` as the dense engine (the pools +
block table are wrapped in ``core.cache_view.PagedView``s per wave).

Serving-plane knobs (DESIGN.md §8): ``--async-waves`` double-buffers
decode waves (launch n+1 before harvesting n; outputs stay bit-exact),
``--lookahead N`` lets admission consider the first N+1 queued requests
(first-fit within the window — relieves head-of-line blocking behind an
oversized prompt), ``--disaggregate`` splits prefill and decode into
separate page pools (implies --paged; with ``--prefill-devices`` /
``--decode-devices`` each side runs on its own device and finished
prefills ship their pages across the transfer boundary).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import Model
from repro.serving import (BudgetDraft, LayerSubsetDraft,
                           PagedServingEngine, Request, ServingEngine,
                           SpeculationController)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve on the paged scheduler (page pools + "
                         "block tables through the cache-view API)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--offload", action="store_true",
                    help="tiered pools on the paged scheduler: HATA "
                         "layers keep only hash codes in HBM, K/V rows "
                         "page to host and only the top-k budget "
                         "crosses PCIe per wave (implies --paged)")
    ap.add_argument("--hbm-budget-mb", type=float, default=None,
                    help="with --offload: watermark admission against "
                         "this HBM-resident budget (codes + staging)")
    ap.add_argument("--async-waves", action="store_true",
                    help="double-buffered decode waves: launch wave "
                         "n+1 before harvesting wave n (bit-exact)")
    ap.add_argument("--lookahead", type=int, default=0,
                    help="admission lookahead window; 0 = strict FCFS")
    ap.add_argument("--speculate-depth", type=int, default=0,
                    help="speculative decoding: draft this many tokens "
                         "per slot per round and verify them in ONE "
                         "batched wave (0 = off; outputs stay "
                         "bit-exact with non-speculative serving)")
    ap.add_argument("--draft-budget", type=int, default=8,
                    help="with --speculate-depth: self-draft under a "
                         "uniform per-layer HATA budget of this many "
                         "rows (the hash-aware draft)")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="with --speculate-depth: draft through only "
                         "the first N layers instead of the budget "
                         "draft (0 = use --draft-budget)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split prefill/decode page pools; finished "
                         "prefills ship pages across the transfer "
                         "boundary (implies --paged)")
    ap.add_argument("--prefill-devices", type=int, default=0,
                    help="with --disaggregate: host the prefill pool "
                         "on device 0 of this many reserved devices")
    ap.add_argument("--decode-devices", type=int, default=0,
                    help="with --disaggregate: host the decode pool on "
                         "the first device after the prefill reserve")
    args = ap.parse_args(argv)
    if args.offload or args.disaggregate:
        args.paged = True

    cfg = (get_reduced(args.arch) if args.reduced
           else get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    speculate = None
    if args.speculate_depth > 0:
        draft = (LayerSubsetDraft(args.draft_layers)
                 if args.draft_layers > 0
                 else BudgetDraft(args.draft_budget))
        speculate = SpeculationController(depth=args.speculate_depth,
                                          draft=draft)
    if args.paged:
        # pool sized to the dense engine's row budget; max_len_pages
        # covers its per-request capacity (rounded UP to whole pages —
        # equal, and the HATA budget identical, when page_size divides
        # max_len; rounding down would truncate sooner than dense)
        table_pages = -(-args.max_len // args.page_size)
        budget = (None if args.hbm_budget_mb is None
                  else int(args.hbm_budget_mb * 2**20))
        prefill_dev = decode_dev = None
        if args.disaggregate and (args.prefill_devices
                                  or args.decode_devices):
            devs = jax.devices()
            need = max(args.prefill_devices, 1) + \
                max(args.decode_devices, 1)
            assert len(devs) >= need, (
                f"{len(devs)} devices available, "
                f"--prefill-devices + --decode-devices need {need} "
                "(use XLA_FLAGS=--xla_force_host_platform_device_count"
                "=N on CPU)")
            prefill_dev = devs[0]
            decode_dev = devs[max(args.prefill_devices, 1)]
        engine = PagedServingEngine(
            model, params,
            num_pages=args.max_batch * table_pages + 1,
            page_size=args.page_size, max_batch=args.max_batch,
            max_len_pages=table_pages, offload=args.offload,
            hbm_budget_bytes=budget, lookahead=args.lookahead,
            async_waves=args.async_waves,
            disaggregate=args.disaggregate,
            prefill_device=prefill_dev, decode_device=decode_dev,
            speculate=speculate)
    else:
        engine = ServingEngine(model, params, max_batch=args.max_batch,
                               max_len=args.max_len,
                               lookahead=args.lookahead,
                               async_waves=args.async_waves,
                               speculate=speculate)
    rng = np.random.default_rng(args.seed)
    nb = cfg.audio.n_codebooks if cfg.family == "audio" else 0
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len))
        shape = (plen, nb) if nb else (plen,)
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, shape,
                                dtype=np.int32),
            max_new_tokens=args.new_tokens))

    t0 = time.monotonic()
    done = engine.run(reqs)
    dt = time.monotonic() - t0
    for r in sorted(done, key=lambda r: r.id):
        ttft = (r.t_first_token - r.t_submit) * 1e3
        total = (r.t_done - r.t_submit) * 1e3
        print(f"req {r.id:3d} prompt={r.prompt_len:4d} "
              f"out={len(r.output):4d} ttft={ttft:8.1f}ms "
              f"total={total:8.1f}ms")
    mode = ("offload" if args.offload
            else "disagg" if args.disaggregate
            else "paged" if args.paged else "dense")
    if args.async_waves:
        mode += "+async"
    if speculate is not None:
        mode += f"+{speculate.describe()}"
        drafted = max(engine.stats["spec_drafted"], 1)
        hits = (engine.stats["spec_accepted"]
                - sum(engine.stats["spec_acc_hist"]))
        print(f"[serve/spec] rounds={engine.stats['spec_rounds']} "
              f"accept={max(hits, 0) / drafted:.3f} "
              f"hist={engine.stats['spec_acc_hist']}")
    print(f"[serve/{mode}] {engine.stats} wall={dt:.2f}s "
          f"tok/s={engine.stats['tokens_out'] / dt:.1f}")
    return done


if __name__ == "__main__":
    main()
