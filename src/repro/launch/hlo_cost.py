"""While-aware HLO cost model (the §Roofline engine).

``compiled.cost_analysis()`` visits while bodies ONCE, so every scanned
layer stack (126 layers at llama3-405b) is undercounted by the trip
count (verified empirically; see EXPERIMENTS.md §Dry-run). This module
re-derives FLOPs / HBM bytes / collective bytes from the post-
optimization HLO text with loop trip counts applied.

Rules (per-device — the HLO module is the per-partition program):
  * dot: 2 · |result| · K, K = product of lhs contracting dims;
  * other compute ops: |result| element-ops (VPU noise next to MXU);
  * HBM bytes per top-level instruction: operands + result, EXCEPT
      - dynamic-update-slice: 2 x |update| (XLA aliases the buffer —
        only the updated region moves; this is the KV-cache append),
      - dynamic-slice / gather: result only (row gather from a cache
        reads the rows, not the cache),
      - fusion: the fusion op's own operands + result (internals live
        in registers/VMEM; their flops still count);
  * collectives: result bytes for all-gather / all-reduce / all-to-all /
    collective-permute; operand bytes for reduce-scatter ("-start"
    variants normalized); bucketed by kind;
  * while: (body + cond) x trip count — the trip count is the compare
    constant in the loop-condition computation (XLA's lax.scan
    pattern); call recurses; conditional takes the max-cost branch.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# tuple shapes of >5 elements carry /*index=N*/ comments (which contain
# '='), so the tuple alternative must only exclude nested parens
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
               "all-to-all", "collective-permute")
_SKIP_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "all-gather-done", "all-reduce-done",
             "collective-permute-done", "copy-done", "copy-start",
             # plain copies: donation aliasing / CPU copy-insertion
             # artifacts — elided on TPU for the patterns we emit
             "copy")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "collectives": dict(self.coll),
                "collective_bytes": self.coll_bytes}


def parse_hlo(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode = m.groups()
        after = line[m.end():]
        depth, i = 1, 0
        while i < len(after) and depth:
            if after[i] == "(":
                depth += 1
            elif after[i] == ")":
                depth -= 1
            i += 1
        oper_str, attrs = after[:i - 1], after[i:]
        operands = re.findall(r"%([\w\.\-]+)", oper_str)
        comps[cur].append(Instr(name, shape, opcode, operands, attrs,
                                line))
    return comps


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}
        self._shapes: Dict[Tuple[str, str], str] = {}
        for cname, instrs in self.comps.items():
            for ins in instrs:
                self._shapes[(cname, ins.name)] = ins.shape

    def _oshape(self, comp: str, ref: str) -> str:
        return self._shapes.get((comp, ref), "")

    @staticmethod
    def _called(ins: Instr) -> List[str]:
        out = []
        for key in ("calls=", "body=", "condition=", "to_apply=",
                    "true_computation=", "false_computation="):
            for m in re.finditer(re.escape(key) + r"%?([\w\.\-]+)",
                                 ins.attrs):
                out.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
        if m:
            out.extend(re.findall(r"%?([\w\.\-]+)", m.group(1)))
        return out

    def _trip(self, cond_name: str) -> int:
        """Max compare constant in the condition comp (+ its callees)."""
        best = 1
        names = [cond_name]
        for ins in self.comps.get(cond_name, []):
            names.extend(self._called(ins))
        for n in names:
            for ins in self.comps.get(n, []):
                for m in re.finditer(r"constant\((\d+)\)", ins.line):
                    best = max(best, int(m.group(1)))
        return best

    # ------------------------------------------------------------------
    def instr_cost(self, comp: str, ins: Instr) -> Cost:
        c = Cost()
        op = ins.opcode
        if op in _SKIP_OPS:
            return c
        res_bytes = shape_bytes(ins.shape)
        oper_bytes = sum(shape_bytes(self._oshape(comp, o))
                         for o in ins.operands)

        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            trip = self._trip(cm.group(1)) if cm else 1
            if bm:
                c += self.comp_cost(bm.group(1)).scaled(trip)
            return c
        if op == "conditional":
            branches = [b for b in self._called(ins)]
            if branches:
                costs = [self.comp_cost(b) for b in branches]
                c += max(costs, key=lambda x: x.flops + x.bytes)
            return c
        if op == "call":
            for callee in self._called(ins):
                c += self.comp_cost(callee)
            return c
        if op == "fusion":
            # CPU-backend bf16 legalization (FloatNormalization) wraps
            # while-carried bf16 buffers in f32 convert round-trips and
            # runs the row DUS on the f32 copy — none of which exists on
            # TPU (native bf16). Normalize: a fusion whose only
            # non-trivial ops are converts is free; one whose only real
            # op is a small dynamic-update-slice costs 2x the update.
            kind = self._fusion_kind(ins)
            if kind == "convert-only":
                return c
            if kind == "inplace-update":
                upd = self._fusion_update_bytes(ins)
                c.bytes += 2.0 * upd
                return c
            for callee in self._called(ins):
                inner = self.comp_cost(callee)
                c += Cost(inner.flops, 0.0, dict(inner.coll))
            c.bytes += res_bytes + self._fusion_operand_bytes(comp, ins)
            return c

        base = op[:-6] if op.endswith("-start") else op
        if base in _COLL_KINDS:
            vol = oper_bytes if base == "reduce-scatter" else res_bytes
            c.coll[base] = c.coll.get(base, 0.0) + vol
            c.bytes += res_bytes + oper_bytes
            return c

        # ---- compute + memory ----
        if op == "dot":
            lhs_shape = self._oshape(comp, ins.operands[0])
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
            k = 1
            if m and lhs_shape:
                dm = _SHAPE_RE.search(lhs_shape)
                if dm and dm.group(2):
                    ldims = [int(d) for d in dm.group(2).split(",")]
                    for ci in m.group(1).split(","):
                        if ci:
                            k *= ldims[int(ci)]
            c.flops += 2.0 * shape_elems(ins.shape) * k
            c.bytes += res_bytes + oper_bytes
        elif op == "convert":
            pass    # fused (or nonexistent: CPU bf16 legalization) on TPU
        elif op == "slice":
            # a (static) slice reads only its region — the unrolled
            # decode slices per-layer weights out of (L, ...) stacks
            c.bytes += res_bytes
        elif op == "dynamic-update-slice":
            upd = (shape_bytes(self._oshape(comp, ins.operands[1]))
                   if len(ins.operands) > 1 else res_bytes)
            c.bytes += 2.0 * upd
        elif op == "dynamic-slice":
            c.bytes += res_bytes        # assume fused into its consumer
        elif op == "gather":
            c.bytes += 2.0 * res_bytes  # write + consumer read
        elif op == "scatter":
            upd = (shape_bytes(self._oshape(comp, ins.operands[2]))
                   if len(ins.operands) > 2 else res_bytes)
            c.bytes += 2.0 * upd
            c.flops += shape_elems(ins.shape)
        else:
            c.flops += float(shape_elems(ins.shape))
            c.bytes += res_bytes + oper_bytes
        return c

    _TRIVIAL = {"parameter", "constant", "convert", "copy", "bitcast",
                "tuple", "get-tuple-element", "reshape", "transpose",
                "broadcast", "iota"}
    _UPDATE_EXTRA = {"dynamic-update-slice", "dynamic-slice", "select",
                     "select-n", "compare", "clamp", "add", "subtract",
                     "multiply", "and", "or", "minimum", "maximum",
                     "pad", "slice", "concatenate"}

    def _fusion_kind(self, ins: Instr) -> str:
        """Classify a fusion: 'convert-only' (free on TPU),
        'inplace-update' (row DUS + index math, possibly wrapped in
        CPU-legalization converts — costs only the update region), or
        'compute'."""
        res = max(shape_elems(ins.shape), 1)
        for callee in self._called(ins):
            has_dus = False
            for inner in self.comps.get(callee, []):
                iop = inner.opcode
                if iop in self._TRIVIAL:
                    continue
                if iop == "dynamic-update-slice":
                    has_dus = True
                    continue
                if iop in self._UPDATE_EXTRA:
                    # index math / row-sized masking, not bulk work
                    if shape_elems(inner.shape) <= max(res // 8, 4096):
                        continue
                    return "compute"
                return "compute"
            return "inplace-update" if has_dus else "convert-only"
        return "compute"

    def _fusion_operand_bytes(self, comp: str, ins: Instr) -> float:
        """Operand traffic of a fusion, slice-aware: a parameter whose
        only inner uses are slice/dynamic-slice/gather ops contributes
        the sliced bytes, not the full (e.g. layer-stacked) array."""
        total = 0.0
        callees = self._called(ins)
        if not callees:
            return sum(shape_bytes(self._oshape(comp, o))
                       for o in ins.operands)
        callee = callees[0]
        instrs = self.comps.get(callee, [])
        param_names = {}
        for inner in instrs:
            if inner.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", inner.line)
                if m:
                    param_names[inner.name] = int(m.group(1))
        uses: Dict[str, List[Instr]] = {n: [] for n in param_names}
        for inner in instrs:
            for o in inner.operands:
                if o in uses:
                    uses[o].append(inner)
        for pname, pidx in param_names.items():
            if pidx >= len(ins.operands):
                continue
            full = shape_bytes(self._oshape(comp, ins.operands[pidx]))
            ulist = uses[pname]
            if ulist and all(u.opcode in ("slice", "dynamic-slice",
                                          "gather", "convert")
                             for u in ulist):
                eff = sum(shape_bytes(u.shape) for u in ulist
                          if u.opcode != "convert")
                eff += sum(0.0 for u in ulist)
                if any(u.opcode == "convert" for u in ulist) and not \
                        any(u.opcode != "convert" for u in ulist):
                    eff = full
                total += min(full, eff) if eff else full
            else:
                total += full
        # operands beyond named params (rare) count fully
        for extra in ins.operands[len(param_names):]:
            total += shape_bytes(self._oshape(comp, extra))
        return total

    def _fusion_update_bytes(self, ins: Instr) -> float:
        total = 0.0
        for callee in self._called(ins):
            for inner in self.comps.get(callee, []):
                if inner.opcode == "dynamic-update-slice" \
                        and len(inner.operands) > 1:
                    total += shape_bytes(
                        self._oshape(callee, inner.operands[1]))
        return total

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()      # cycle guard
        total = Cost()
        for ins in self.comps.get(name, []):
            total += self.instr_cost(name, ins)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        called = set()
        for instrs in self.comps.values():
            for ins in instrs:
                called.update(self._called(ins))
        total = Cost()
        for name in self.comps:
            if name not in called:
                total += self.comp_cost(name)
        return total


def analyze(text: str) -> Cost:
    return HloCostModel(text).entry_cost()
