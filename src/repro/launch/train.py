"""Training driver: data pipeline -> jit'd train step -> checkpoints,
with the fault-tolerance contract wired in (watchdog, heartbeat,
auto-resume, deterministic data skip).

On this CPU container it trains reduced configs end-to-end (see
examples/train_lm.py); on a pod the same driver runs the full configs —
only the mesh and --arch change. ``--mesh`` accepts e.g. "4x2" (data x
model); omit for single-device.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.checkpoint.checkpointer import config_fingerprint
from repro.configs import get_config, get_reduced
from repro.data import DataPipeline, SyntheticLM
from repro.distributed.fault_tolerance import Heartbeat, StepWatchdog
from repro.distributed.sharding import ShardingPolicy, dp_axes
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step, pick_micro_batches
from repro.models import Model
from repro.optim.adamw import adamw_init


def build(args):
    cfg = (get_reduced(args.arch) if args.reduced
           else get_config(args.arch))
    if args.seq:
        cfg = dataclasses.replace(cfg, max_seq_len=args.seq)
    model = Model(cfg)
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[:len(shape)]
                         if len(shape) == 2 else ("data",))
    return cfg, model, mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--n-micro", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, model, mesh = build(args)
    nb = cfg.audio.n_codebooks if cfg.family == "audio" else 0
    source = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                         seed=args.seed, n_codebooks=nb)
    n_micro = args.n_micro or 1
    step_fn = make_train_step(model, n_micro=n_micro, base_lr=args.lr,
                              total_steps=args.steps)

    ckpt = None
    start_step = 0
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    opt = adamw_init(params)
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir,
                            fingerprint=config_fingerprint(cfg))
        latest = ckpt.latest()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}")

    if mesh is not None:
        policy = ShardingPolicy(cfg, mesh)
        pshard = policy.named(policy.param_specs(params))
        params = jax.device_put(params, pshard)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    pipe = DataPipeline(source, start_step=start_step)
    watchdog = StepWatchdog()
    hb = Heartbeat(os.path.join(args.ckpt_dir or ".", "heartbeats"),
                   jax.process_index()) if args.ckpt_dir else None

    losses = []
    t_start = time.time()
    for step, tokens in pipe:
        if step >= args.steps:
            break
        batch = {"tokens": jnp.asarray(tokens)}
        watchdog.step_start()
        params, opt, metrics = jitted(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        flag = watchdog.step_end(step)
        if flag:
            print(f"[watchdog] {flag}")
        if hb:
            hb.beat(step)
        if step % args.log_every == 0:
            tput = (args.batch * args.seq * (step - start_step + 1)
                    / max(time.time() - t_start, 1e-9))
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tput:.0f}",
                  flush=True)
        if ckpt and step and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt})
    pipe.stop()
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt},
                  blocking=True)
    print(f"[train] done; first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
