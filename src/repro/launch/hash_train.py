"""Hash-weight training driver (paper §3.1 + App. B) — thin CLI.

All the heavy lifting lives in :mod:`repro.training`: one-pass
harvesting (``harvest.build_datasets``), the per-head trainers
(``trainer.train_layer`` — linear Eq. 9 or the 2-layer-MLP-before-sign
variant via ``--hidden``), held-out recall over ALL query heads of
every kv group, install into the params tree, and the recall-vs-budget
calibrator (``--calibrate`` writes the core/budgets.py table plus the
CI baseline JSON). This file only parses flags and prints metrics.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.data.synthetic import SyntheticLM
from repro.models import Model
from repro.training import (calibrate_budget_table, train_model_hashes,
                            write_json)


def train_layer_hash(model: Model, params, batches, layer: int, *,
                     rbit: int, epochs: int = 15, iters: int = 20,
                     seed: int = 0):
    """Back-compat single-layer entry (examples/train_lm.py).

    Returns (w (H_kv, d_hash, rbit), recall_hata, recall_lsh), with the
    held-out recall averaged over all G query heads per kv group and
    all rows of the held-out batch (the old in-file trainer scored only
    head ``hi*g`` of batch 0).
    """
    from repro.core import hashing
    from repro.training import harvest, trainer
    cfg = model.cfg
    hcfg = dataclasses.replace(cfg.hata, rbit=rbit)
    datasets = harvest.build_datasets(model, params, batches[:-1],
                                      [layer], hcfg, seed=seed)
    w = trainer.train_layer(datasets[layer], rbit=rbit, hcfg=hcfg,
                            epochs=epochs, iters=iters, seed=seed)
    qh, kh = harvest.harvest_all_layers(model, params, batches[-1],
                                        layers=[layer])[layer]
    budget = max(4, int(0.1 * qh.shape[1]))
    rec = trainer.heldout_recall(qh, kh, w, budget, rbit=rbit)
    d = qh.shape[-1]
    w_lsh = jnp.broadcast_to(
        hashing.random_projection_lsh(jax.random.PRNGKey(seed), d, rbit),
        (kh.shape[2], d, rbit))
    rec_lsh = trainer.heldout_recall(qh, kh, w_lsh, budget, rbit=rbit)
    return w, rec, rec_lsh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    # BooleanOptionalAction gives --reduced/--no-reduced; the old
    # `action="store_true", default=True` made full configs unreachable
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--rbit", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=0,
                    help="MLP hidden width (0 = linear Eq. 9 hash; "
                         "2*rbit warm-starts from the linear hash)")
    ap.add_argument("--layers", default="all",
                    help="'all' = every selecting self-attention layer")
    ap.add_argument("--sequences", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calibrate", default=None, metavar="DIR",
                    help="sweep recall-vs-budget on the held-out batch "
                         "and write DIR/budget_table.json + "
                         "DIR/recall_baseline.json")
    args = ap.parse_args(argv)

    cfg = (get_reduced(args.arch) if args.reduced
           else get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    src = SyntheticLM(cfg.vocab_size, args.seq_len, 1, seed=args.seed)
    batches = [{"tokens": jnp.asarray(src.batch_at(i))}
               for i in range(max(2, args.sequences))]
    layers = (None if args.layers == "all"
              else [int(x) for x in args.layers.split(",")])
    params, trained, metrics = train_model_hashes(
        model, params, batches, layers=layers, rbit=args.rbit,
        hidden=args.hidden, epochs=args.epochs, iters=args.iters,
        seed=args.seed)
    for m in metrics:
        print(f"layer {m.layer:3d} recall@{m.budget}: "
              f"trained={m.recall_trained:.3f} seed={m.recall_seed:.3f} "
              f"lsh={m.recall_lsh:.3f}", flush=True)
    if args.calibrate:
        table, baseline = calibrate_budget_table(
            model, params, batches[-1],
            layers=sorted(trained), weights=trained)
        write_json(os.path.join(args.calibrate, "budget_table.json"),
                   table)
        write_json(os.path.join(args.calibrate, "recall_baseline.json"),
                   baseline)
        print(f"[hash_train] budget table -> {args.calibrate} "
              f"(mean budget {baseline['mean_budget']} vs global "
              f"{baseline['global_budget']}, "
              f"mean recall {baseline['mean_recall']})", flush=True)
    print("[hash_train] done")
    return params, trained


if __name__ == "__main__":
    main()
