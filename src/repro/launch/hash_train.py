"""Hash-weight training driver (paper §3.1 + App. B).

Pipeline: train (or load) a model -> harvest per-layer/per-head (q, k)
from prefill runs over sampled sequences (App. B.1) -> build labeled
triplets -> train W_H per head with the Eq. 9 objective (SGD lr 0.1,
momentum 0.9, wd 1e-6; 15 epochs x 20 iters) -> report held-out top-k
recall vs exact attention and vs random-projection LSH at equal bits ->
write the weights into the params tree (hash_stack / hash_pre).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import hashing
from repro.data.hash_dataset import build_triplets_per_head, harvest_qk
from repro.data.synthetic import SyntheticLM
from repro.models import Model


def train_layer_hash(model: Model, params, batches, layer: int, *,
                     rbit: int, epochs: int = 15, iters: int = 20,
                     seed: int = 0):
    """Returns (w (H_kv, d_hash, rbit), recall_hata, recall_lsh)."""
    cfg = model.cfg
    hcfg = dataclasses.replace(cfg.hata, rbit=rbit)
    q, k, s = build_triplets_per_head(model, params, batches, layer,
                                      hcfg, seed=seed)
    key = jax.random.PRNGKey(seed)
    w = hashing.train_hash_weights_per_head(
        key, jnp.asarray(q), jnp.asarray(k), jnp.asarray(s),
        rbit=rbit, hcfg=hcfg, epochs=epochs, iters=iters)
    # held-out recall on a fresh batch
    qh, kh = harvest_qk(model, params, batches[-1], layer)
    b, ss, h, d = qh.shape
    h_kv = kh.shape[2]
    g = h // h_kv
    budget = max(4, int(0.1 * ss))
    recs, recs_lsh = [], []
    w_lsh = hashing.random_projection_lsh(key, d, rbit)
    for hi in range(h_kv):
        qs = jnp.asarray(qh[0, ss // 2:, hi * g])
        ks = jnp.asarray(kh[0, :, hi])
        recs.append(hashing.hash_topk_recall(qs, ks, w[hi], budget,
                                             rbit=rbit).mean())
        recs_lsh.append(hashing.hash_topk_recall(qs, ks, w_lsh, budget,
                                                 rbit=rbit).mean())
    return w, float(jnp.mean(jnp.stack(recs))), \
        float(jnp.mean(jnp.stack(recs_lsh)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rbit", type=int, default=64)
    ap.add_argument("--layers", default="all")
    ap.add_argument("--sequences", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced(args.arch) if args.reduced
           else get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    src = SyntheticLM(cfg.vocab_size, args.seq_len, 1, seed=args.seed)
    batches = [{"tokens": jnp.asarray(src.batch_at(i))}
               for i in range(args.sequences)]
    layers = (range(cfg.n_layers) if args.layers == "all"
              else [int(x) for x in args.layers.split(",")])
    trained = {}
    for layer in layers:
        w, rec, rec_lsh = train_layer_hash(
            model, params, batches, layer, rbit=args.rbit,
            epochs=args.epochs, iters=args.iters, seed=args.seed)
        trained[layer] = w
        print(f"layer {layer:3d} recall@10%: hata={rec:.3f} "
              f"lsh={rec_lsh:.3f}", flush=True)
    # write into params
    if "hash_stack" in params and params["hash_stack"] is not None:
        hs = params["hash_stack"]
        for layer, w in trained.items():
            j = layer - model.n_pre
            if 0 <= j < model.n_stack:
                hs = hs.at[j].set(w)
            elif layer < model.n_pre:
                params["hash_pre"][layer] = w
        params["hash_stack"] = hs
    print("[hash_train] done")
    return params, trained


if __name__ == "__main__":
    main()
