"""Production mesh definitions.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its
first jax import; everyone else sees the real device count).

Topology: TPU v5e-class pods. Single pod = 16x16 = 256 chips,
axes ("data", "model"); multi-pod adds a leading "pod" axis (2 pods =
512 chips) carrying hierarchical data parallelism (reduce-scatter over
ICI in-pod, cross-pod all-reduce over DCI) and optionally pipeline
stages (distributed/pipeline.py).
"""
from __future__ import annotations

import jax

try:                                  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                   # older jax: every axis is Auto
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/small runs (Auto axis types).

    Version-compat shim: jax.make_mesh grew the ``axis_types`` kwarg in
    0.5; on older jax the default (Auto everywhere) is already what we
    want. Every mesh in the repo — including test subprocess snippets —
    goes through here so the suite runs on both.
    """
    shape, axes = tuple(shape), tuple(axes)
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
