import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms (deliverables e & g).

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves the sharding policy (params / optimizer / batch / caches),
  3. jits the right step (train / prefill / decode) against
     ShapeDtypeStruct inputs — zero real allocation,
  4. ``.lower().compile()`` — any sharding mismatch, unsupported
     collective or partitioning failure dies HERE, which is the point,
  5. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (XLA's numbers), and the while-aware HLO cost
     model (launch/hlo_cost.py) for FLOPs / HBM bytes / per-kind
     collective bytes,
  6. writes one JSON per cell under --out (benchmarks/roofline.py turns
     these into the §Roofline table).

Decode cells install the sequence-parallel SPDecode strategy
(--decode-mode two_stage|local_split|naive — the §Perf ladder) and lower
the steady-state HATA path statically; --dense-baseline lowers the same
cell with HATA off for the dense-vs-HATA comparison (Fig. 4/5 analogue).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, ALL_ARCHS, get_config,
                           get_shape, shapes_for)
from repro.distributed import strategy as dist_strategy
from repro.distributed.decode import SPDecode
from repro.distributed.sharding import ShardingPolicy, dp_axes
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (cache_specs_abstract, input_specs,
                                make_decode_step, make_prefill_step,
                                make_train_step, pick_micro_batches)
from repro.models import Model
from repro.optim.adamw import adamw_init


def _mem_dict(mem) -> Dict[str, float]:
    if mem is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    return {k: float(getattr(mem, k, 0) or 0) for k in keys}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               decode_mode: str = "two_stage", hata: bool = True,
               dtype_override: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one cell; returns the raw cost record."""
    import dataclasses

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if not hata:
        cfg = dataclasses.replace(
            cfg, hata=dataclasses.replace(cfg.hata, enabled=False))
    if dtype_override:
        cfg = dataclasses.replace(cfg, dtype=dtype_override)
    shape = get_shape(shape_name)
    model = Model(cfg)
    policy = ShardingPolicy(cfg, mesh)
    dp = dp_axes(mesh)
    dp_size = int(jnp.prod(jnp.array([mesh.shape[a] for a in dp])))

    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = policy.param_specs(params_abs)
    pshard = policy.named(pspecs)
    batch_abs = input_specs(cfg, shape)
    b = shape.global_batch
    b_shardable = b % dp_size == 0
    bspec = {k: NamedSharding(mesh, P(dp if b_shardable else None,
                                      *([None] * (len(v.shape) - 1))))
             for k, v in batch_abs.items()}

    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(jnp.prod(jnp.array(list(mesh.shape.values())))),
        "kind": shape.kind, "hata": hata, "decode_mode": None,
    }
    # pin post-embedding activations to (batch over DP, D replicated)
    # for TRAIN/PREFILL: sharding propagation from the vocab-sharded
    # embedding otherwise degrades into large gathers (§Perf T1).
    # NOT for decode: with B tokens the optimum is partial-sum
    # projections + tiny activation psums; the pinned layout flips
    # GSPMD into ~params/TP-shards of weight all-gathers per step
    # (measured +100x collective on 405B decode — §Perf T1b, refuted).
    act_b = dp if b_shardable else None

    def _act_constraint(x):
        spec = P(act_b, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    dist_strategy.set_activation_constraint(
        _act_constraint if shape.kind != "decode" else None)
    t0 = time.time()
    try:
        if shape.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            oshard = policy.named(policy.opt_specs(pspecs))
            n_micro = pick_micro_batches(cfg, b, dp_size,
                                         seq_len=shape.seq_len)
            record["n_micro"] = n_micro
            step = make_train_step(model, n_micro=n_micro)
            jitted = jax.jit(step,
                             in_shardings=(pshard, oshard, bspec),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            caches_abs = cache_specs_abstract(model, shape)
            cshard = policy.named(policy.cache_specs(caches_abs, b))
            step = make_prefill_step(model)
            logits_sh = NamedSharding(
                mesh, P(dp if b_shardable else None, None))
            jitted = jax.jit(step,
                             in_shardings=(pshard, bspec, cshard),
                             out_shardings=(logits_sh, cshard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, batch_abs, caches_abs)
        else:  # decode
            record["decode_mode"] = decode_mode
            seq_axes = ("model",) if b_shardable else dp + ("model",)
            sp = SPDecode(mesh, seq_axes=seq_axes,
                          batch_axes=dp if b_shardable else (),
                          mode=decode_mode)
            dist_strategy.set_decode_strategy(
                sp if decode_mode != "naive" else None)
            caches_abs = cache_specs_abstract(model, shape,
                                              layout="list")
            cshard = policy.named(policy.cache_specs(caches_abs, b))
            step = make_decode_step(model)
            tok_sh = {k: NamedSharding(
                mesh, P(dp if b_shardable else None,
                        *([None] * (len(v.shape) - 1))))
                for k, v in batch_abs.items()}
            logits_sh = NamedSharding(
                mesh, P(dp if b_shardable else None, None))
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, tok_sh["tokens"], cshard, None),
                out_shardings=(None, cshard),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, batch_abs["tokens"],
                                   caches_abs, pos_abs)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)
        record["memory"] = _mem_dict(compiled.memory_analysis())
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):    # jax<0.5: one dict per device
            ca = ca[0] if ca else {}
        record["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0) or 0),
            "bytes_accessed": float(ca.get("bytes accessed", 0) or 0)}
        cost = hlo_cost.analyze(compiled.as_text())
        record["hlo_cost"] = cost.as_dict()
        record["ok"] = True
    except Exception as e:  # recorded, cell marked failed
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    finally:
        dist_strategy.set_decode_strategy(None)
        dist_strategy.set_activation_constraint(None)
    record["total_s"] = round(time.time() - t0, 2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="assigned",
                    help="'assigned', 'all', or comma list")
    ap.add_argument("--shape", default="all", help="'all' or comma list")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--decode-mode", default="two_stage",
                    choices=["two_stage", "local_split", "naive"])
    ap.add_argument("--dense-baseline", action="store_true",
                    help="also lower decode cells with HATA disabled")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.arch == "assigned":
        archs = ASSIGNED_ARCHS
    elif args.arch == "all":
        archs = ALL_ARCHS
    else:
        archs = args.arch.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for mesh_kind in meshes:
        multi = mesh_kind == "multi"
        for arch in archs:
            shape_names = ([s.name for s in shapes_for(arch)]
                           if args.shape == "all"
                           else args.shape.split(","))
            for shape_name in shape_names:
                variants = [(True, args.decode_mode)]
                if args.dense_baseline and \
                        get_shape(shape_name).kind == "decode":
                    variants.append((False, args.decode_mode))
                for hata, mode in variants:
                    tag = "" if hata else "_dense"
                    fn = os.path.join(
                        args.out,
                        f"{mesh_kind}_{arch}_{shape_name}{tag}.json")
                    if args.skip_existing and os.path.exists(fn):
                        with open(fn) as f:
                            if json.load(f).get("ok"):
                                print(f"[skip] {fn}")
                                continue
                    rec = lower_cell(arch, shape_name, multi_pod=multi,
                                     decode_mode=mode, hata=hata)
                    with open(fn, "w") as f:
                        json.dump(rec, f, indent=1)
                    status = "OK " if rec["ok"] else "FAIL"
                    n_fail += 0 if rec["ok"] else 1
                    mem = rec.get("memory", {})
                    hc = rec.get("hlo_cost", {})
                    print(f"[{status}] {mesh_kind:6s} {arch:22s} "
                          f"{shape_name:12s}{tag:7s} "
                          f"compile={rec.get('compile_s', '-'):>7}s "
                          f"args/dev={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                          f"temp/dev={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                          f"flops/dev={hc.get('flops', 0):.3e} "
                          f"coll/dev={hc.get('collective_bytes', 0):.3e}",
                          flush=True)
                    if not rec["ok"]:
                        print(rec["error"], flush=True)
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
