"""Llama-3.2-Vision 90B — dense GQA backbone + gated cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision scaled per assignment]

Modality frontend is a STUB: ``input_specs`` supplies precomputed patch
embeddings (B, n_image_tokens, vision_dim); the model owns the projection
and the cross-attention layers (every 5th layer).
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    vlm=VLMConfig(cross_every=5, n_image_tokens=1601, vision_dim=1280),
    rope_theta=500000.0,
    max_seq_len=131072,
)
