from repro.configs.base import (AudioConfig, HataConfig, MLAConfig,
                                ModelConfig, MoEConfig, SHAPES, SSMConfig,
                                ShapeConfig, VLMConfig, reduced)
from repro.configs.registry import (ALL_ARCHS, ASSIGNED_ARCHS, PAPER_ARCHS,
                                    cells, get_config, get_reduced, get_shape,
                                    shapes_for)

__all__ = [
    "AudioConfig", "HataConfig", "MLAConfig", "ModelConfig", "MoEConfig",
    "SSMConfig", "ShapeConfig", "VLMConfig", "SHAPES", "reduced",
    "ALL_ARCHS", "ASSIGNED_ARCHS", "PAPER_ARCHS", "cells", "get_config",
    "get_reduced", "get_shape", "shapes_for",
]
