"""Mamba2-130M — attention-free SSD (state-space duality). [arXiv:2405.21060]

HATA is INAPPLICABLE here (no qk scores / KV cache to hash) — see
DESIGN.md §Arch-applicability. The arch is implemented without it.
"""
import dataclasses

from repro.configs.base import HataConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    hata=HataConfig(enabled=False),
    max_seq_len=1048576,
)
