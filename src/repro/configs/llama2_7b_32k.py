"""Llama-2-7B-32K-Instruct — the paper's MHA evaluation model (Table 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b-32k",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    head_dim=128,
    rope_theta=10000.0,
    max_seq_len=32768,
)
