"""Llama-3.1-8B-Instruct — the paper's GQA evaluation model (Table 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    max_seq_len=131072,
)
