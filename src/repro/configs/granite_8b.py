"""Granite-8B-Code — llama-arch dense GQA. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10000000.0,
    max_seq_len=131072,
)
