"""Hymba-1.5B — hybrid parallel attention+Mamba heads, meta tokens.
[arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1),
    meta_tokens=128,
    rope_theta=10000.0,
    max_seq_len=8192 * 64,
)
