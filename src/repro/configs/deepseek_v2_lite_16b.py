"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512) + MoE 64 routed top-6 + 2 shared.
[arXiv:2405.04434]

Assignment-line note: the line says both "MoE 64e top-6" and "160 routed";
160 routed belongs to full DeepSeek-V2 (236B). We implement the hf-verified
V2-Lite: 64 routed + 2 shared, top-6, first layer dense (d_ff=10944).

HATA+MLA is a beyond-paper extension (the paper lists MLA as future work):
hash codes are computed over the compressed latent [c_kv ; k_rope].
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,   # MLA: one shared latent cache; q heads = 16
    d_ff=1408,       # assignment lists the expert d_ff here
    vocab_size=102400,
    head_dim=128,    # qk_nope head dim
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, first_dense_layers=1,
                  d_ff_dense=10944, parallelism="ep"),
    rope_theta=10000.0,
    max_seq_len=163840,
)
