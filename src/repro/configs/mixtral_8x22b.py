"""Mixtral-8x22B — MoE 8 experts top-2, GQA, SWA per assignment.
[arXiv:2401.04088]

8 experts do not divide the 16-way model axis, so MoE parallelism is
intra-expert TP (sorted block-gather grouped GEMM, d_ff sharded).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                  parallelism="tp"),
    rope_theta=1000000.0,
    max_seq_len=65536,
)
