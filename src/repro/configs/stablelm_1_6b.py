"""StableLM-2 1.6B — dense MHA, partial rotary. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    head_dim=64,
    partial_rotary=0.25,
    rope_theta=10000.0,
    max_seq_len=4096 * 32,  # extended for the assigned long shapes
)
