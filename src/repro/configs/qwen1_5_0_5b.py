"""Qwen1.5-0.5B — dense, QKV bias, large vocab. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    max_seq_len=32768,
)
