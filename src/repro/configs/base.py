"""Configuration dataclasses for the repro framework.

Every architecture in ``repro/configs/<arch>.py`` instantiates a
:class:`ModelConfig`. Configs are frozen (hashable) so they can be closed
over by jitted functions and used as static args.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# HATA (the paper's technique)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HataConfig:
    """Hash-Aware Top-k Attention settings (paper §3, Table 5)."""
    enabled: bool = True
    rbit: int = 128                 # hash bits per vector (paper default)
    budget_frac: float = 0.0156     # top-k as fraction of context (1.56%)
    budget_min: int = 512           # floor (paper: 512 @ LongBench)
    budget_max: int = 8192
    dense_layers: int = 2           # first-N layers stay dense (paper §5.1)
    # 0 = linear projection (paper Eq. 9); >0 = hidden width of a
    # 2-layer MLP before sign (Spotlight-style non-linear hash — one
    # extra fused matmul in hash_encode)
    hash_hidden: int = 0
    # learning-to-hash hyper-parameters (paper Table 11)
    sigma: float = 0.1
    epsilon: float = 0.01
    lam: float = 1.0
    eta: float = 2.0
    # training-data construction (paper App. B.1)
    pos_frac: float = 0.10          # top-10% of qk pairs are positives
    pos_label_max: float = 20.0     # linearly decayed labels in [1, 20]
    neg_label: float = -1.0

    def __post_init__(self):
        # codes are bit-packed into uint32 words (rbit // 32 per code);
        # a non-multiple would silently drop the trailing hash bits at
        # every encode — fail loudly at construction instead
        if self.rbit <= 0 or self.rbit % 32:
            raise ValueError(
                f"HataConfig.rbit={self.rbit} must be a positive "
                "multiple of 32 (codes are bit-packed into uint32 "
                f"words; {self.rbit % 32} bits would be dropped)")
        if self.hash_hidden < 0:
            raise ValueError(
                f"HataConfig.hash_hidden={self.hash_hidden} must be >= 0 "
                "(0 = linear hash, >0 = MLP hidden width)")

    def budget(self, context_len: int) -> int:
        k = int(context_len * self.budget_frac)
        k = max(self.budget_min, min(k, self.budget_max))
        return min(k, context_len)


# ---------------------------------------------------------------------------
# Sub-family configs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                  # routed experts
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    first_dense_layers: int = 0     # leading layers that keep a dense FFN
    d_ff_dense: int = 0             # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    # 'ep' = expert-parallel all-to-all (shard_map); 'tp' = intra-expert
    # tensor parallel with sorted block-gather grouped GEMM.
    parallelism: str = "ep"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = direct q projection (V2-Lite)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD mixer."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class VLMConfig:
    """Cross-attention VLM wrapper (Llama-3.2-Vision style).

    The modality frontend is a STUB per the assignment: ``input_specs``
    provides precomputed patch embeddings of shape (B, n_image_tokens,
    vision_dim); the model owns only the projection into d_model and the
    gated cross-attention layers.
    """
    cross_every: int = 5            # every 5th layer is a cross-attn layer
    n_image_tokens: int = 1601      # one 560x560 tile -> 1601 patches
    vision_dim: int = 1280


@dataclass(frozen=True)
class AudioConfig:
    """MusicGen-style decoder over EnCodec tokens.

    Frontend stub: ``input_specs`` provides precomputed frame embeddings
    (the sum of the 4 codebook embeddings); the model owns the backbone and
    the 4 parallel codebook heads.
    """
    n_codebooks: int = 4


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                    # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 500000.0
    partial_rotary: float = 1.0     # fraction of head_dim that is rotated
    sliding_window: Optional[int] = None
    norm_eps: float = 1e-5
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    vlm: Optional[VLMConfig] = None
    audio: Optional[AudioConfig] = None
    hata: HataConfig = field(default_factory=HataConfig)
    meta_tokens: int = 0            # Hymba learnable prefix tokens
    remat: str = "dots"             # none | dots | full  (activation ckpt)
    scan_layers: bool = True

    # ---- derived ---------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def group_size(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1) if self.n_heads else 0

    def padded_vocab(self, multiple: int = 2048) -> int:
        """Vocab padded so embeddings shard over any mesh axis <= multiple."""
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    @property
    def hash_input_dim(self) -> int:
        """Dimensionality of the vectors fed to HashEncode.

        GQA/MHA: the per-head head_dim. MLA (beyond-paper extension): the
        compressed latent [c_kv ; k_rope]."""
        if self.mla is not None:
            return self.mla.kv_lora_rank + self.mla.qk_rope_dim
        return self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS=6ND)."""
        D, L, V = self.d_model, self.n_layers, self.vocab_size
        n_emb = self.audio.n_codebooks if self.audio is not None else 1
        total = n_emb * V * D  # embeddings (+ per-codebook heads)
        if not self.tie_embeddings:
            total += n_emb * V * D
        if self.vlm is not None:
            total += self.vlm.vision_dim * D
        n_cross = self.n_cross_layers()
        n_dense_ffn = self.moe.first_dense_layers if self.moe else 0
        n_self = L - n_cross - n_dense_ffn
        for is_cross in [False] * n_self + [True] * n_cross:
            total += self.layer_param_count(is_cross)
        for _ in range(n_dense_ffn):
            total += self.layer_param_count(False, dense_ffn=True)
        total += D  # final norm
        return total

    def n_cross_layers(self) -> int:
        if self.vlm is None:
            return 0
        return self.n_layers // self.vlm.cross_every

    def layer_param_count(self, is_cross: bool = False,
                          dense_ffn: bool = False) -> int:
        D = self.d_model
        total = 2 * D  # two norms
        # --- mixer ---
        if self.family == "ssm":
            total += self._ssm_params()
            return total
        if self.family == "hybrid":
            total += self._ssm_params()
        total += self._attn_params()
        if is_cross:
            total += self._attn_params() + 2  # extra cross-attn + gates
        # --- ffn ---
        if self.moe is not None and not dense_ffn:
            e = self.moe
            expert = 3 * D * e.d_ff_expert
            total += (e.n_experts + e.n_shared_experts) * expert
            total += D * e.n_experts  # router
        elif self.moe is not None and dense_ffn:
            total += 3 * D * (self.moe.d_ff_dense or self.d_ff)
        else:
            total += 3 * D * self.d_ff
        return total

    def _attn_params(self) -> int:
        D = self.d_model
        if self.mla is not None:
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            total = D * qdim                                    # W_q
            total += D * (m.kv_lora_rank + m.qk_rope_dim)       # W_dkv, W_kr
            total += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            total += self.n_heads * m.v_head_dim * D            # W_o
            return total
        hd = self.head_dim
        return (D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                + self.n_heads * hd * D)

    def _ssm_params(self) -> int:
        s = self.ssm
        D = self.d_model
        di = s.d_inner(D)
        nh = s.n_heads(D)
        conv_dim = di + 2 * s.n_groups * s.d_state
        total = D * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
        total += conv_dim * s.d_conv                            # conv
        total += 2 * nh + nh                                    # A, D, dt_bias
        total += di * D                                         # out_proj
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k active)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        D = self.d_model
        inactive_per_layer = (e.n_experts - e.top_k) * 3 * D * e.d_ff_expert
        n_moe_layers = self.n_layers - e.first_dense_layers
        return self.param_count() - n_moe_layers * inactive_per_layer


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

LM_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

SHAPES = {s.name: s for s in LM_SHAPES}


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
            seq_len: int = 128, vocab: int = 256) -> ModelConfig:
    """Shrink a config to a smoke-test size preserving the family structure."""
    kw: dict = dict(
        name=cfg.name + "-reduced",
        n_layers=max(n_layers, 2),
        d_model=d_model,
        vocab_size=vocab,
        max_seq_len=seq_len,
        d_ff=d_model * 3,
        remat="none",
    )
    if cfg.n_heads:
        n_heads = 4 if cfg.n_heads % 4 == 0 or cfg.n_heads >= 4 else cfg.n_heads
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
        kw["n_heads"] = n_heads
        kw["n_kv_heads"] = max(1, n_heads // ratio)
        kw["head_dim"] = d_model // n_heads
    else:
        kw["n_heads"] = 0
        kw["n_kv_heads"] = 0
        kw["head_dim"] = 0
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k),
            d_ff_expert=d_model * 2, d_ff_dense=d_model * 3,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla is not None:
        hd = d_model // kw["n_heads"]
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=hd, qk_rope_dim=8,
                              v_head_dim=hd)
        kw["head_dim"] = hd
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        chunk=32)
    if cfg.vlm is not None:
        kw["vlm"] = dataclasses.replace(cfg.vlm, cross_every=2,
                                        n_image_tokens=16, vision_dim=32)
        kw["n_layers"] = 4
    if cfg.audio is not None:
        kw["audio"] = cfg.audio
    if cfg.meta_tokens:
        kw["meta_tokens"] = 8
    kw["hata"] = dataclasses.replace(
        cfg.hata, rbit=64, budget_min=16, budget_max=64, dense_layers=1)
    kw["sliding_window"] = min(cfg.sliding_window, seq_len) if cfg.sliding_window else None
    kw["qkv_bias"] = cfg.qkv_bias
    kw["partial_rotary"] = cfg.partial_rotary
    kw["family"] = cfg.family
    return ModelConfig(**kw)
