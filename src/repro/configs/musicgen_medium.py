"""MusicGen-medium — decoder-only over EnCodec tokens (4 codebooks).
[arXiv:2306.05284]

Frontend stub: ``input_specs`` provides precomputed frame embeddings
(sum of codebook embeddings); the backbone predicts 4 parallel codebook
heads of vocab 2048 each.
"""
from repro.configs.base import AudioConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    audio=AudioConfig(n_codebooks=4),
    rope_theta=10000.0,
    max_seq_len=524288 + 8,
)
