"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (LM_SHAPES, SHAPES, ModelConfig, ShapeConfig,
                                reduced)

# arch-id -> module name. The 10 assigned architectures + the paper's own
# two evaluation models.
_MODULES = {
    "llama3-405b": "llama3_405b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "stablelm-1.6b": "stablelm_1_6b",
    "granite-8b": "granite_8b",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-130m": "mamba2_130m",
    "llama2-7b-32k": "llama2_7b_32k",
    "llama3.1-8b": "llama3_1_8b",
}

ASSIGNED_ARCHS: List[str] = list(_MODULES)[:10]
PAPER_ARCHS: List[str] = list(_MODULES)[10:]
ALL_ARCHS: List[str] = list(_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    if arch not in _cache:
        mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
        _cache[arch] = mod.CONFIG
    return _cache[arch]


def get_reduced(arch: str, **kw) -> ModelConfig:
    return reduced(get_config(arch), **kw)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def shapes_for(arch: str) -> List[ShapeConfig]:
    """The assigned shape set for an arch (all LM shapes here)."""
    return list(LM_SHAPES)


def cells() -> List[tuple]:
    """All (arch, shape) dry-run cells — 10 archs x 4 shapes = 40."""
    return [(a, s.name) for a in ASSIGNED_ARCHS for s in shapes_for(a)]
