"""SGD with momentum + decoupled weight decay — the hash trainer's
optimizer (paper Table 11: lr 0.1, momentum 0.9, wd 1e-6)."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: jax.Array


def sgd_init(params) -> SGDState:
    return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))


def sgd_update(params, grads, state: SGDState, *, lr: float,
               momentum: float = 0.9, weight_decay: float = 0.0,
               ) -> Tuple[jax.Array, SGDState]:
    def upd(p, g, m):
        g = g + weight_decay * p
        m_new = momentum * m + g
        return p - lr * m_new, m_new

    out = jax.tree.map(upd, params, grads, state.momentum)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(momentum=new_m)
