"""int8 error-feedback gradient compression for the data-parallel
all-reduce (distributed-optimization trick; see DESIGN.md §4).

Per-tensor symmetric int8 quantization with an error-feedback residual:
the quantization error of step t is added back into the gradient at
step t+1, so the compression bias telescopes away and SGD/Adam converge
as with exact gradients (Karimireddy et al., 2019). Cuts DP collective
bytes 2x vs bf16 grads / 4x vs f32.

Used inside a shard_map'd train step:
    q, scale, err = compress(g, err)
    g_sum = psum(dequant(q, scale))      # int8 on the wire
(The psum itself runs on the dequantized values so scales need no
cross-replica agreement; the wire payload that matters — the all-reduce
operand — is the int8 tensor + one f32 scale.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(g: jax.Array, err: jax.Array,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression of one gradient tensor.

    Returns (q int8, scale, new_err) with new_err = (g+err) - deq(q)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, err_state, axis_name: str):
    """Tree-wise int8 error-feedback psum over ``axis_name``.

    Returns (mean-reduced grads f32, new error state). Must be called
    inside shard_map/pmap with ``axis_name`` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = compress_with_feedback(g, e)
        deq = dequantize_int8(q, scale)
        summed = jax.lax.psum(deq, axis_name)
        return summed / n, new_e

    out = jax.tree.map(one, grads, err_state)
    flat, treedef = jax.tree.flatten(out,
                                     is_leaf=lambda t: isinstance(t, tuple))
    g_new = jax.tree.unflatten(treedef, [t[0] for t in flat])
    e_new = jax.tree.unflatten(treedef, [t[1] for t in flat])
    return g_new, e_new
