from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
from repro.optim.sgd import SGDState, sgd_init, sgd_update

__all__ = ["AdamWState", "adamw_init", "adamw_update", "SGDState",
           "sgd_init", "sgd_update", "cosine_schedule",
           "linear_warmup_cosine"]
