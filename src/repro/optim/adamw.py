"""AdamW for model training.

State dtype is configurable: full-f32 (m, v) by default, or bf16 m +
f32 v ("mem_efficient") to cut optimizer bytes 25% — the knob the 405B
train-shape memory analysis exercises (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: jax.Array       # pytree
    v: jax.Array       # pytree


def adamw_init(params, *, m_dtype=jnp.float32) -> AdamWState:
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, m_dtype), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[jax.Array, AdamWState]:
    """Returns (new_params, new_state). ``lr`` may be a scalar array."""
    step = state.step + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        p_new = p.astype(jnp.float32) - lr * (update + weight_decay
                                              * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    leaves, treedef = jax.tree.flatten(
        out, is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in leaves])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in leaves])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in leaves])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
