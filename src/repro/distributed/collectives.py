"""SPMD collective building blocks (used inside shard_map).

``distributed_topk``  — two-stage exact top-k over a sequence-sharded
score axis: local top-k, all-gather the (value, global-index) candidate
pairs (k·P·8 bytes instead of S·4), global top-k on every shard. Exact
whenever k <= S_local (each shard's winners are within its local top-k);
for k > S_local the local stage takes the whole shard and the gather
degenerates to a (sorted) full gather — see EXPERIMENTS.md §Perf for the
byte accounting of both regimes.

``merge_partial_softmax`` — flash-style (m, l, o) merge across shards:
pmax(m), rescale, psum. The only cross-shard traffic of the
sequence-parallel decode attention is these statistics: (2+dv)·G·4 bytes
per (batch, kv-head), independent of S and k.

``hierarchical_psum`` — reduce-scatter in-pod then cross-pod all-reduce
for the multi-pod gradient sync (DCI hops carry 1/16th of the bytes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def distributed_topk(local_scores: jax.Array, k: int, axis_name,
                     s_local: int) -> Tuple[jax.Array, jax.Array]:
    """local_scores: (..., S_local). Returns (values, global indices),
    both (..., k), identical on every shard along ``axis_name``.

    ``axis_name`` may be a tuple of mesh axes; the reduction is then
    HIERARCHICAL — candidates reduce over the innermost axis first,
    cutting gather traffic from P_total·min(k, S_local) pairs to
    roughly Σ_axis P_axis·k pairs while staying exact (every element of
    the global top-k survives each stage's local top-k by the same
    subset argument as the flat two-stage). §Perf iteration H2.
    """
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    k_local = min(k, s_local)
    lv, li = jax.lax.top_k(local_scores, k_local)
    offset = _flat_index(axes) * s_local
    gi = li + offset
    for ax in reversed(axes):
        av = jax.lax.all_gather(lv, ax, axis=-2, tiled=False)
        ai = jax.lax.all_gather(gi, ax, axis=-2, tiled=False)
        av = av.reshape(*av.shape[:-2], -1)
        ai = ai.reshape(*ai.shape[:-2], -1)
        kk = min(k, av.shape[-1])
        lv, sel = jax.lax.top_k(av, kk)
        gi = jnp.take_along_axis(ai, sel, axis=-1)
    return lv, gi


def _flat_index(axes) -> jax.Array:
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def merge_partial_softmax(m: jax.Array, l: jax.Array, o: jax.Array,
                          axis_name: str) -> jax.Array:
    """m/l: (...,), o: (..., dv) per-shard flash stats -> merged output.

    Shards with nothing to contribute must pass m = -inf-like (-1e30),
    l = 0, o = 0.
    """
    m_g = jax.lax.pmax(m, axis_name)
    alpha = jnp.exp(m - m_g)
    l_g = jax.lax.psum(alpha * l, axis_name)
    o_g = jax.lax.psum(alpha[..., None] * o, axis_name)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


def hierarchical_psum(x: jax.Array, pod_axis: str, inner_axis: str,
                      ) -> jax.Array:
    """psum factored as inner-pod reduce then cross-pod reduce: XLA lowers
    each stage onto its own link class (ICI in-pod, DCI across)."""
    return jax.lax.psum(jax.lax.psum(x, inner_axis), pod_axis)


# ---------------------------------------------------------------------------
# HLO collective-count regression guards (alpa-style)
# ---------------------------------------------------------------------------
# A scheduler/strategy refactor can silently double the all-reduces —
# nothing in a bit-exactness test notices, the step just gets slower.
# The guard counts collective ops in the COMPILED HLO text of the
# serving plane's decode/prefill steps and pins them against a
# committed baseline (tests/data/hlo_collectives.json); alpa does the
# same to keep its pipeshard stages honest. Counting is literal
# substring matching on the optimized module — crude but stable for a
# fixed jax version, and a version bump that shifts the lowering shows
# up as an explicit baseline regen, not a silent perf cliff.

COLLECTIVE_OPS = ("all-reduce", "all-gather", "all-to-all",
                  "collective-permute", "reduce-scatter")


def collective_counts(hlo_text: str) -> dict:
    """Count collective instructions in (compiled) HLO text.

    Matches both plain (``all-reduce(``) and async-pair
    (``all-reduce-start(``) forms; the async ``-done`` halves are not
    counted (one logical collective = one count).
    """
    counts = {}
    for op in COLLECTIVE_OPS:
        n = hlo_text.count(f" {op}(") + hlo_text.count(f" {op}-start(")
        if n:
            counts[op] = n
    return counts


def compiled_collective_counts(jitted, *args, **kwargs) -> dict:
    """Lower + compile a jitted callable on example args (nothing is
    executed) and return its collective counts."""
    compiled = jitted.lower(*args, **kwargs).compile()
    return collective_counts(compiled.as_text())


def assert_collective_counts(got: dict, expected: dict,
                             label: str) -> None:
    """Raise if ``got`` differs from ``expected`` on ANY collective op
    — extra collectives are a perf regression, missing ones mean the
    step silently changed shape (stale baseline either way)."""
    keys = sorted(set(got) | set(expected))
    drift = {k: (expected.get(k, 0), got.get(k, 0))
             for k in keys if expected.get(k, 0) != got.get(k, 0)}
    if drift:
        lines = "; ".join(f"{k}: expected {e}, got {g}"
                          for k, (e, g) in drift.items())
        raise AssertionError(
            f"[hlo-guard] {label}: collective counts drifted — {lines}. "
            "If the change is intentional, regenerate the baseline "
            "(python -m repro.distributed.hlo_guard --write).")
