"""HLO collective-count regression guards for the serving plane.

GSPMD/shard_map partitioning regressions rarely fail tests — they show
up as *extra collectives* in the compiled step (an accidental
all-gather of a sharded pool, a resharding all-to-all from a changed
in_spec), which silently multiply the interconnect traffic per decode
step. The guard compiles the serving workers' actual step functions,
counts the collective ops in the optimized HLO text (alpa-style
``" op("`` counting, ``distributed/collectives.py``), and compares the
counts EXACTLY against a committed baseline
(``tests/data/hlo_collectives.json``):

  * ``colocated_paged`` (single device): decode + prefill-chunk steps
    of the default engine must contain ZERO collectives — a nonzero
    count means something dragged a collective into the single-host
    path;
  * ``sharded_pool_p<N>``: the sharded-pool engine's SPDecode
    (two_stage, global page ids) decode step and its GSPMD prefill
    chunk at N host devices — the counts pin the communication
    schedule of the sequence-parallel wave (partial-softmax merge
    all-reduces, distributed top-k all-gathers).

Regenerate after an INTENDED schedule change:

    python -m repro.distributed.hlo_guard --write

(sets ``--xla_force_host_platform_device_count`` before first jax use,
so run it from a fresh process). Tier-1 runs the single-device case
in-process and the sharded case in a subprocess
(tests/test_hlo_guard.py), including an injected-regression check that
patches an extra psum into the merge and asserts the guard trips.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Dict

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                     "..", "..", ".."))
BASELINE_PATH = os.path.join(_REPO, "tests", "data",
                             "hlo_collectives.json")


def load_baseline(path: str = BASELINE_PATH) -> Dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# case builders (lazy imports: --write must set XLA_FLAGS pre-jax)
# ---------------------------------------------------------------------------
def _setup(arch: str = "qwen1.5-0.5b"):
    import jax

    from repro.configs import get_reduced
    from repro.models import Model
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _engine_counts(eng) -> Dict[str, Dict[str, int]]:
    """Compile the engine's OWN worker step fns on representative
    shapes and count collectives in the optimized HLO."""
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.collectives import compiled_collective_counts
    decode_args = (eng._decode_params, eng._tok_feed,
                   eng.decode_group.pools, jnp.asarray(eng.bt),
                   jnp.asarray(eng.pos), jnp.asarray(eng._ids),
                   jnp.asarray(eng._steps))
    chunk = np.zeros((1, eng.prefill_chunk), np.int32)
    bt_row = eng.prefill_group.scratch_cols[None].copy()
    chunk_args = (eng._prefill_params, jnp.asarray(chunk),
                  eng.prefill_group.pools, jnp.asarray(bt_row),
                  jnp.int32(0), jnp.int32(eng.prefill_chunk - 1))
    return {
        "decode": compiled_collective_counts(eng.decode.step_jit,
                                             *decode_args),
        "prefill_chunk": compiled_collective_counts(eng.prefill.step_jit,
                                                    *chunk_args),
    }


def colocated_case() -> Dict[str, Dict[str, int]]:
    from repro.serving import PagedServingEngine
    model, params = _setup()
    eng = PagedServingEngine(model, params, num_pages=16, page_size=8,
                             max_batch=2, prefill_chunk=8)
    return _engine_counts(eng)


def sharded_case(n_shards: int = 4) -> Dict[str, Dict[str, int]]:
    from repro.launch.mesh import make_mesh
    from repro.serving import PagedServingEngine
    model, params = _setup()
    mesh = make_mesh((n_shards,), ("model",))
    eng = PagedServingEngine(model, params, num_pages=16, page_size=8,
                             max_batch=2, prefill_chunk=8, mesh=mesh,
                             sp_mode="two_stage")
    return _engine_counts(eng)


def build_cases(n_shards: int = 4) -> Dict:
    import jax
    cases = {"colocated_paged": colocated_case()}
    if jax.device_count() >= n_shards:
        cases[f"sharded_pool_p{n_shards}"] = sharded_case(n_shards)
    return cases


def check_against_baseline(cases: Dict, baseline: Dict) -> None:
    """Exact comparison, guard-style error messages."""
    from repro.distributed.collectives import assert_collective_counts
    for name, steps in baseline["cases"].items():
        assert name in cases, f"hlo_guard: case {name!r} was not built"
        for step, expected in steps.items():
            assert_collective_counts(cases[name][step], expected,
                                     label=f"{name}/{step}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="regenerate the committed baseline")
    ap.add_argument("--devices", type=int, default=4,
                    help="host device count for the sharded case")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args()
    # the device count locks at first jax/XLA touch, which the
    # package imports already triggered — re-exec with the flag set
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count="
                            f"{args.devices}").strip()
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.distributed.hlo_guard"]
            + sys.argv[1:], env=env))
    cases = build_cases(args.devices)
    if args.write:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump({"arch": "qwen1.5-0.5b", "cases": cases}, f,
                      indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline}")
    else:
        check_against_baseline(cases, load_baseline(args.baseline))
        print("hlo_guard: all collective counts match the baseline")


if __name__ == "__main__":
    main()
