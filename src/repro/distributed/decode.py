"""Sequence-parallel (SP) HATA decode — the paper's Alg. 3 made SPMD.

At production shapes the KV+code caches are sequence-sharded over the
``model`` axis (and over *everything* for the 500k single-sequence
cell); replicating them is impossible (405B @ 32k x 128 = 2.2 TB). This
module runs the score -> select -> attend pipeline under shard_map with
three selectable modes (the §Perf hillclimb ladder):

``naive``      GSPMD semantics: the strategy steps aside (returns None)
               and the caller runs the global batched pipeline —
               ``core.hash_attention.hata_score_select`` +
               ``hata_attend``, i.e. the same score -> select -> gather
               path as ``hata_decode_batched`` — and XLA all-gathers
               the full score vector and the gathered rows. Baseline.
``two_stage``  exact: local Hamming scores -> two-stage distributed
               top-k (only (value, index) candidate pairs cross the
               ICI) -> each shard attends over the winners it *owns*
               (clamped local gather + ownership mask) -> flash-stat
               (m, l, o) psum merge. Bit-exact vs single-device HATA
               (same scores -> same lax.top_k tie-breaks).
``local_split``  beyond-paper approximation: every shard takes its local
               top-(k/P) and attends, merge as above. Zero index
               traffic, only the O(G·d) stat psum; selection differs
               from exact top-k only when >k/P winners collide on one
               shard (recall measured in benchmarks/distributed_topk).

The dense path (first-N dense layers / HATA off) is the same machinery
minus selection: local partial attention + stat merge — i.e. classic
sequence-parallel flash decode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.core import hash_attention as ha
from repro.core.kvcache import LayerKVCache, MLACache
from repro.distributed.collectives import (distributed_topk,
                                           merge_partial_softmax)
from repro.kernels import ops


def _flat_axis_index(axes: Sequence[str]) -> jax.Array:
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def _partial_stats(q: jax.Array, k: jax.Array, v: jax.Array,
                   mask: jax.Array, scale: float):
    """q: (B, Hkv, G, d), k/v: (B, R, Hkv, d|dv) — native cache layout,
    never transposed (a moveaxis here materializes a transposed copy of
    the whole local cache every layer). mask: (B, Hkv, R).
    Returns flash stats m/l: (B, Hkv, G), o: (B, Hkv, G, dv).

    bf16 caches stay bf16 (f32 MXU accumulation via
    preferred_element_type) — an .astype(f32) here makes XLA hoist an
    f32 copy of the whole layer-stacked cache out of the decode scan
    (measured: +2.8 GiB temp on qwen decode_32k; EXPERIMENTS.md §Perf).
    """
    logits = jnp.einsum("bhgd,brhd->bhgr", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, :, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.maximum(m, -1e30)
    p = jnp.exp(logits - m_safe[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgr,brhd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m_safe, l, o


class SPDecode:
    """Strategy object installed via repro.distributed.strategy."""

    def __init__(self, mesh: Mesh, *, seq_axes: Tuple[str, ...] = ("model",),
                 batch_axes: Optional[Tuple[str, ...]] = None,
                 mode: str = "two_stage"):
        assert mode in ("naive", "two_stage", "local_split"), mode
        self.mesh = mesh
        self.seq_axes = tuple(seq_axes)
        self.batch_axes = tuple(batch_axes or ())
        self.mode = mode
        self.n_seq_shards = int(math.prod(
            mesh.shape[a] for a in self.seq_axes))

    # ------------------------------------------------------------------
    def append_leaf(self, leaf: jax.Array, new: jax.Array, lead,
                    pos) -> jax.Array:
        """In-place row append into a sequence-sharded stacked cache.

        leaf: (*lead_dims, B, S_max, ...), new: (B, S_new, ...).
        GSPMD lowers a dynamic-update-slice on a sharded dim as
        local-update + whole-buffer ownership select (measured: full
        cache r/w per layer per decode step — EXPERIMENTS.md §Perf).
        Inside shard_map every shard instead writes exactly one row:
        owners write the new value, non-owners rewrite the row already
        there. O(row) traffic, fully in place.
        """
        nlead = len(lead)
        b_ax = self.batch_axes or None
        tail = leaf.ndim - nlead - 2
        leaf_spec = P(*([None] * nlead + [b_ax, self.seq_axes]
                        + [None] * tail))
        s_new = new.shape[1]
        s_max = leaf.shape[nlead + 1]
        if 1 < s_new < s_max:
            # partial multi-row write (chunked prefill): rows may
            # straddle shard boundaries — let GSPMD lower the DUS
            idx = tuple(lead) + (0, pos) + (0,) * tail
            return jax.lax.dynamic_update_slice(
                leaf, new.reshape((1,) * nlead + new.shape
                                  ).astype(leaf.dtype), idx)
        lead_arr = (jnp.stack([jnp.asarray(l, jnp.int32) for l in lead])
                    if nlead else jnp.zeros((0,), jnp.int32))
        if s_new == s_max:
            # full overwrite (prefill at pos 0): shard-aligned write
            new_spec = P(*([b_ax, self.seq_axes] + [None] * tail))

            def write_full(lf, nw, la):
                idx = tuple(la[i] for i in range(nlead)) \
                    + (0,) * (lf.ndim - nlead)
                nw = nw.reshape((1,) * nlead + nw.shape).astype(lf.dtype)
                return jax.lax.dynamic_update_slice(lf, nw, idx)

            return shard_map(write_full, mesh=self.mesh,
                             in_specs=(leaf_spec, new_spec, P(None)),
                             out_specs=leaf_spec,
                             check_rep=False)(leaf, new, lead_arr)

        new_spec = P(*([b_ax, None] + [None] * tail))

        def write_rows(lf, nw, la, p_):
            s_local = lf.shape[nlead + 1]
            offset = _flat_axis_index(self.seq_axes) * s_local
            lpos = p_ - offset
            own = (lpos >= 0) & (lpos <= s_local - s_new)
            lclamped = jnp.clip(lpos, 0, s_local - s_new)
            idx = tuple(la[i] for i in range(nlead)) \
                + (0, lclamped) + (0,) * tail
            cur = jax.lax.dynamic_slice(
                lf, idx, (1,) * nlead + (nw.shape[0], s_new)
                + nw.shape[2:])
            nw = nw.reshape((1,) * nlead + nw.shape).astype(lf.dtype)
            val = jnp.where(own, nw, cur)
            return jax.lax.dynamic_update_slice(lf, val, idx)

        return shard_map(write_rows, mesh=self.mesh,
                         in_specs=(leaf_spec, new_spec, P(None), P()),
                         out_specs=leaf_spec, check_rep=False)(
            leaf, new, lead_arr, jnp.asarray(pos, jnp.int32))

    # ------------------------------------------------------------------
    def gqa(self, cfg: ModelConfig, q: jax.Array, w_h, cache: LayerKVCache,
            n_valid: jax.Array, use_hata) -> jax.Array:
        """q: (B, H, d) global; cache arrays (B, S, Hkv, d) sequence-
        sharded. Returns (B, H, d) attention output (pre-Wo)."""
        if self.mode == "naive":
            return None                      # caller keeps GSPMD path
        b_ax = self.batch_axes or None
        kv_spec = P(b_ax, self.seq_axes, None, None)
        hata_possible = (cache.codes is not None and cfg.hata.enabled
                         and w_h is not None)
        if hata_possible and not (isinstance(use_hata, bool)
                                  and not use_hata):
            static = use_hata if isinstance(use_hata, bool) else None
            fn = shard_map(
                functools.partial(self._gqa_local, cfg, static),
                mesh=self.mesh,
                in_specs=(P(b_ax, None, None), P(None, None, None),
                          kv_spec, kv_spec, kv_spec, P(), P()),
                out_specs=P(b_ax, None, None),
                check_rep=False)
            return fn(q, w_h, cache.k, cache.v, cache.codes,
                      jnp.asarray(n_valid, jnp.int32),
                      jnp.asarray(use_hata, jnp.bool_))
        fn = shard_map(
            functools.partial(self._gqa_local_dense, cfg),
            mesh=self.mesh,
            in_specs=(P(b_ax, None, None), kv_spec, kv_spec, P()),
            out_specs=P(b_ax, None, None),
            check_rep=False)
        return fn(q, cache.k, cache.v, jnp.asarray(n_valid, jnp.int32))

    def _gqa_local_dense(self, cfg: ModelConfig, q, k_cache, v_cache,
                         n_valid):
        """Sequence-parallel dense flash decode (no selection)."""
        b, h, d = q.shape
        h_kv = k_cache.shape[2]
        s_local = k_cache.shape[1]
        offset = _flat_axis_index(self.seq_axes) * s_local
        abs_pos = offset + jnp.arange(s_local)
        valid = abs_pos[None, None, :] < n_valid
        if cfg.sliding_window is not None:
            valid = valid & (abs_pos[None, None, :]
                             > n_valid - 1 - cfg.sliding_window)
        qg = q.reshape(b, h_kv, h // h_kv, d)
        m, l, o = _partial_stats(
            qg, k_cache, v_cache,
            jnp.broadcast_to(valid, (b, h_kv, s_local)), d ** -0.5)
        out = merge_partial_softmax(m, l, o, self.seq_axes)
        return out.reshape(b, h, d).astype(q.dtype)

    def _gqa_local(self, cfg: ModelConfig, static_flag, q, w_h, k_cache,
                   v_cache, codes, n_valid, use_hata):
        b, h, d = q.shape
        h_kv = k_cache.shape[2]
        g = h // h_kv
        s_local = k_cache.shape[1]
        shard = _flat_axis_index(self.seq_axes)
        offset = shard * s_local
        abs_pos = offset + jnp.arange(s_local)
        valid = abs_pos[None, None, :] < n_valid          # (1,1,S_l)
        if cfg.sliding_window is not None:
            valid = valid & (abs_pos[None, None, :]
                             > n_valid - 1 - cfg.sliding_window)
        qg = q.reshape(b, h_kv, g, d)
        scale = d ** -0.5

        def dense():
            mask = jnp.broadcast_to(valid, (b, h_kv, s_local))
            return _partial_stats(qg, k_cache, v_cache, mask, scale)

        def hata():
            # local shard of the same batched score -> select -> gather
            # pipeline as hata_decode_batched: shared q aggregation,
            # batched Hamming kernel, shared validity/window masking at
            # shard offsets, then the stats-emitting paged fused-gather
            # kernel over the winners this shard holds — no transposed
            # cache copy, no XLA row gather (the merge below is the only
            # cross-shard traffic).
            q_codes = ha.aggregate_q_codes(q, w_h, h_kv)
            scores = ops.hamming_scores(q_codes, codes,
                                        rbit=cfg.hata.rbit)
            scores = ha.mask_scores(scores, n_valid,
                                    window=cfg.sliding_window,
                                    positions=abs_pos)
            budget = ha.clamped_budget(cfg.hata,
                                       s_local * self.n_seq_shards,
                                       cfg.sliding_window)
            if self.mode == "local_split":
                k_loc = min(max(budget // self.n_seq_shards, 1), s_local)
                top_s, idx_l = jax.lax.top_k(scores, k_loc)
                return ops.gather_decode_stats(q, k_cache, v_cache,
                                               idx_l, top_s >= 0)
            # two-stage exact: attend only over the global winners this
            # shard owns — an arbitrary (non-prefix) selection mask.
            gv, gi = distributed_topk(scores, budget, self.seq_axes,
                                      s_local)
            li = gi - offset
            owned = (li >= 0) & (li < s_local) & (gv >= 0)
            li_c = jnp.clip(li, 0, s_local - 1)
            return ops.gather_decode_stats(q, k_cache, v_cache, li_c,
                                           owned)

        if static_flag is None:
            m, l, o = jax.lax.cond(use_hata, hata, dense)
        else:
            m, l, o = hata() if static_flag else dense()
        out = merge_partial_softmax(m, l, o, self.seq_axes)
        return out.reshape(b, h, d).astype(q.dtype)

    # ------------------------------------------------------------------
    def mla(self, cfg: ModelConfig, p, w_h, q_lat: jax.Array,
            cache: MLACache, n_valid: jax.Array, use_hata) -> jax.Array:
        """q_lat: (B, H, r+rope) absorbed queries; returns (B, H, v_dim)
        in f32 (caller applies Wo)."""
        if self.mode == "naive":
            return None
        b_ax = self.batch_axes or None
        seq_spec = P(b_ax, self.seq_axes, None)
        m = cfg.mla
        h = cfg.n_heads
        wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        hata_possible = (cache.codes is not None and cfg.hata.enabled
                         and w_h is not None)
        if hata_possible and not (isinstance(use_hata, bool)
                                  and not use_hata):
            static = use_hata if isinstance(use_hata, bool) else None
            fn = shard_map(
                functools.partial(self._mla_local, cfg, static),
                mesh=self.mesh,
                in_specs=(P(b_ax, None, None), P(None, None, None),
                          P(None, None, None), seq_spec, seq_spec,
                          seq_spec, P(), P()),
                out_specs=P(b_ax, None, None),
                check_rep=False)
            return fn(q_lat, wuv, w_h, cache.ckv, cache.krope,
                      cache.codes, jnp.asarray(n_valid, jnp.int32),
                      jnp.asarray(use_hata, jnp.bool_))
        fn = shard_map(
            functools.partial(self._mla_local_dense, cfg),
            mesh=self.mesh,
            in_specs=(P(b_ax, None, None), P(None, None, None),
                      seq_spec, seq_spec, P()),
            out_specs=P(b_ax, None, None),
            check_rep=False)
        return fn(q_lat, wuv, cache.ckv, cache.krope,
                  jnp.asarray(n_valid, jnp.int32))

    def _mla_logits(self, cfg: ModelConfig, q_lat, ckv_rows, krope_rows):
        """Split-latent logits: q·[c;k_r] = q_c·c + q_r·k_r — avoids
        materializing a concatenated copy of the latent cache."""
        r = cfg.mla.kv_lora_rank
        scale = (cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) ** -0.5
        q_c = q_lat[..., :r].astype(ckv_rows.dtype)
        q_r = q_lat[..., r:].astype(krope_rows.dtype)
        logits = (jnp.einsum("bhr,bsr->bhs", q_c, ckv_rows,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bhr,bsr->bhs", q_r, krope_rows,
                               preferred_element_type=jnp.float32))
        return logits * scale

    @staticmethod
    def _mla_stats(logits, mask, ckv_rows):
        """Flash stats from precomputed logits. logits: (B, H, R) f32,
        mask: (B, R), ckv_rows: (B, R, r)."""
        logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
        m = jnp.maximum(jnp.max(logits, axis=-1), -1e30)
        p = jnp.exp(logits - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhs,bsr->bhr", p.astype(ckv_rows.dtype),
                       ckv_rows, preferred_element_type=jnp.float32)
        return m, l, o

    def _mla_local_dense(self, cfg: ModelConfig, q_lat, wuv, ckv, krope,
                         n_valid):
        s_local = ckv.shape[1]
        offset = _flat_axis_index(self.seq_axes) * s_local
        valid = (offset + jnp.arange(s_local))[None] < n_valid
        logits = self._mla_logits(cfg, q_lat, ckv, krope)
        mm, ll, oo = self._mla_stats(logits, valid, ckv)
        o_lat = merge_partial_softmax(mm, ll, oo, self.seq_axes)
        return jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))

    def _mla_local(self, cfg: ModelConfig, static_flag, q_lat, wuv, w_h,
                   ckv, krope, codes, n_valid, use_hata):
        b, h, _ = q_lat.shape
        s_local = ckv.shape[1]
        shard = _flat_axis_index(self.seq_axes)
        offset = shard * s_local
        abs_pos = offset + jnp.arange(s_local)
        valid = abs_pos[None] < n_valid                    # (1, S_l)

        def dense():
            logits = self._mla_logits(cfg, q_lat, ckv, krope)
            return self._mla_stats(
                logits, jnp.broadcast_to(valid, (b, s_local)), ckv)

        def hata():
            # local shard of the MLA latent pipeline: batched Hamming
            # kernel over the shared code stream, shard-offset masking,
            # then the split-latent stats-emitting paged gather kernel
            # (q_c·c + q_r·k_r logits computed in-kernel; W_uv applied
            # after the cross-shard merge).
            q_codes = ops.hash_encode(q_lat, w_h[0])       # (B, H, W)
            scores = ops.hamming_scores_latent(q_codes, codes,
                                               rbit=cfg.hata.rbit)
            scores = ha.mask_scores(scores[:, None], n_valid,
                                    window=cfg.sliding_window,
                                    positions=abs_pos)[:, 0]  # (B, S_l)
            s_total = s_local * self.n_seq_shards
            budget = ha.clamped_budget(cfg.hata, s_total,
                                       cfg.sliding_window)
            if self.mode == "local_split":
                k_loc = min(max(budget // self.n_seq_shards, 1), s_local)
                top_s, idx_l = jax.lax.top_k(scores, k_loc)
                mask = top_s >= 0
            else:
                gv, gi = distributed_topk(scores, budget, self.seq_axes,
                                          s_local)
                li = gi - offset
                mask = (li >= 0) & (li < s_local) & (gv >= 0)
                idx_l = jnp.clip(li, 0, s_local - 1)
            m = cfg.mla
            return ops.mla_gather_decode(
                q_lat, ckv, krope, idx_l, lora_rank=m.kv_lora_rank,
                scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5,
                sel_mask=mask, return_stats=True)

        if static_flag is None:
            mm, ll, oo = jax.lax.cond(use_hata, hata, dense)
        else:
            mm, ll, oo = hata() if static_flag else dense()
        o_lat = merge_partial_softmax(mm, ll, oo, self.seq_axes)
        o = jnp.einsum("bhr,rhd->bhd", o_lat,
                       wuv.astype(jnp.float32))            # (B,H,dv)
        return o
