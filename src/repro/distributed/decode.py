"""Sequence-parallel (SP) HATA decode — the paper's Alg. 3 made SPMD.

At production shapes the KV+code caches are sequence-sharded over the
``model`` axis (and over *everything* for the 500k single-sequence
cell); replicating them is impossible (405B @ 32k x 128 = 2.2 TB). This
module runs the score -> select -> attend pipeline under shard_map with
three selectable modes (the §Perf hillclimb ladder):

``naive``      GSPMD semantics: the strategy steps aside (returns None)
               and the caller runs the global batched pipeline —
               ``core.hash_attention.hata_score_select`` +
               ``hata_attend``, i.e. the same score -> select -> gather
               path as ``hata_decode_batched`` — and XLA all-gathers
               the full score vector and the gathered rows. Baseline.
``two_stage``  exact: local Hamming scores -> two-stage distributed
               top-k (only (value, index) candidate pairs cross the
               ICI) -> each shard attends over the winners it *owns*
               (clamped local gather + ownership mask) -> flash-stat
               (m, l, o) psum merge. Bit-exact vs single-device HATA
               (same scores -> same lax.top_k tie-breaks).
``local_split``  beyond-paper approximation: every shard takes its local
               top-(k/P) and attends, merge as above. Zero index
               traffic, only the O(G·d) stat psum; selection differs
               from exact top-k only when >k/P winners collide on one
               shard (recall measured in benchmarks/distributed_topk).

The dense path (first-N dense layers / HATA off) is the same machinery
minus selection: local partial attention + stat merge — i.e. classic
sequence-parallel flash decode.

Cache layouts come in through :mod:`repro.core.cache_view`:
``SPDecode.gqa``/``mla`` accept a ``ContiguousView`` (sequence-sharded
plain cache) *or* a ``PagedView``/``PagedMLAView`` — a page pool whose
page axis is sharded over the sequence axes plus a block table whose
column axis is sharded the same way, each shard's table naming *local*
pages (or GLOBAL ids with ``global_page_ids=True`` — the serving
plane's convention, localized by subtracting the shard base; see
DESIGN.md §8). Inside shard_map both layouts collapse to one
:class:`~repro.core.cache_view.ShardedView` (local slice + absolute
offset), so the two_stage/local_split local math is written once:
physical-row translation (the paged inner view) composes with the
ownership-mask stats kernels, and paged SP decode is bit-exact vs the
contiguous SP decode holding the same rows — zero new kernel code.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.core import cache_view as cv
from repro.core import hash_attention as ha
from repro.core import hash_weights as hw
from repro.core import paged_cache as paged
from repro.core.kvcache import LayerKVCache, MLACache
from repro.distributed.collectives import (distributed_topk,
                                           merge_partial_softmax)
from repro.kernels import ops


def _flat_axis_index(axes: Sequence[str]) -> jax.Array:
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def _partial_stats(q: jax.Array, k: jax.Array, v: jax.Array,
                   mask: jax.Array, scale: float):
    """q: (B, Hkv, G, d), k/v: (B, R, Hkv, d|dv) — native cache layout,
    never transposed (a moveaxis here materializes a transposed copy of
    the whole local cache every layer). mask: (B, Hkv, R).
    Returns flash stats m/l: (B, Hkv, G), o: (B, Hkv, G, dv).

    bf16 caches stay bf16 (f32 MXU accumulation via
    preferred_element_type) — an .astype(f32) here makes XLA hoist an
    f32 copy of the whole layer-stacked cache out of the decode scan
    (measured: +2.8 GiB temp on qwen decode_32k; EXPERIMENTS.md §Perf).
    """
    logits = jnp.einsum("bhgd,brhd->bhgr", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, :, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.maximum(m, -1e30)
    p = jnp.exp(logits - m_safe[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgr,brhd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m_safe, l, o


class SPDecode:
    """Strategy object installed via repro.distributed.strategy."""

    def __init__(self, mesh: Mesh, *, seq_axes: Tuple[str, ...] = ("model",),
                 batch_axes: Optional[Tuple[str, ...]] = None,
                 mode: str = "two_stage", global_page_ids: bool = False):
        assert mode in ("naive", "two_stage", "local_split"), mode
        self.mesh = mesh
        self.seq_axes = tuple(seq_axes)
        self.batch_axes = tuple(batch_axes or ())
        self.mode = mode
        # Paged-view block-table address convention. Default (False):
        # each shard's table column slice names LOCAL page ids of its
        # pool slice (the PR-5 layout, used by the slow SP sweeps).
        # True: tables carry GLOBAL page ids — the serving plane's
        # sharded-pool engine needs this because appends and prefill
        # run on the GSPMD path OUTSIDE shard_map (physical_rows must
        # see global ids there), and the engine's ShardedPageAllocator
        # guarantees column c's page is owned by c's shard, so inside
        # shard_map the local id is just global - shard_base.
        self.global_page_ids = global_page_ids
        self.n_seq_shards = int(math.prod(
            mesh.shape[a] for a in self.seq_axes))

    # ------------------------------------------------------------------
    def append_leaf(self, leaf: jax.Array, new: jax.Array, lead,
                    pos) -> jax.Array:
        """In-place row append into a sequence-sharded stacked cache.

        leaf: (*lead_dims, B, S_max, ...), new: (B, S_new, ...).
        GSPMD lowers a dynamic-update-slice on a sharded dim as
        local-update + whole-buffer ownership select (measured: full
        cache r/w per layer per decode step — EXPERIMENTS.md §Perf).
        Inside shard_map every shard instead writes exactly one row:
        owners write the new value, non-owners rewrite the row already
        there. O(row) traffic, fully in place.
        """
        nlead = len(lead)
        b_ax = self.batch_axes or None
        tail = leaf.ndim - nlead - 2
        leaf_spec = P(*([None] * nlead + [b_ax, self.seq_axes]
                        + [None] * tail))
        s_new = new.shape[1]
        s_max = leaf.shape[nlead + 1]
        if 1 < s_new < s_max:
            # partial multi-row write (chunked prefill): rows may
            # straddle shard boundaries — let GSPMD lower the DUS
            idx = tuple(lead) + (0, pos) + (0,) * tail
            return jax.lax.dynamic_update_slice(
                leaf, new.reshape((1,) * nlead + new.shape
                                  ).astype(leaf.dtype), idx)
        lead_arr = (jnp.stack([jnp.asarray(l, jnp.int32) for l in lead])
                    if nlead else jnp.zeros((0,), jnp.int32))
        if s_new == s_max:
            # full overwrite (prefill at pos 0): shard-aligned write
            new_spec = P(*([b_ax, self.seq_axes] + [None] * tail))

            def write_full(lf, nw, la):
                idx = tuple(la[i] for i in range(nlead)) \
                    + (0,) * (lf.ndim - nlead)
                nw = nw.reshape((1,) * nlead + nw.shape).astype(lf.dtype)
                return jax.lax.dynamic_update_slice(lf, nw, idx)

            return shard_map(write_full, mesh=self.mesh,
                             in_specs=(leaf_spec, new_spec, P(None)),
                             out_specs=leaf_spec,
                             check_rep=False)(leaf, new, lead_arr)

        new_spec = P(*([b_ax, None] + [None] * tail))

        def write_rows(lf, nw, la, p_):
            s_local = lf.shape[nlead + 1]
            offset = _flat_axis_index(self.seq_axes) * s_local
            lpos = p_ - offset
            own = (lpos >= 0) & (lpos <= s_local - s_new)
            lclamped = jnp.clip(lpos, 0, s_local - s_new)
            idx = tuple(la[i] for i in range(nlead)) \
                + (0, lclamped) + (0,) * tail
            cur = jax.lax.dynamic_slice(
                lf, idx, (1,) * nlead + (nw.shape[0], s_new)
                + nw.shape[2:])
            nw = nw.reshape((1,) * nlead + nw.shape).astype(lf.dtype)
            val = jnp.where(own, nw, cur)
            return jax.lax.dynamic_update_slice(lf, val, idx)

        return shard_map(write_rows, mesh=self.mesh,
                         in_specs=(leaf_spec, new_spec, P(None), P()),
                         out_specs=leaf_spec, check_rep=False)(
            leaf, new, lead_arr, jnp.asarray(pos, jnp.int32))

    # ------------------------------------------------------------------
    # view plumbing: global view -> shard_map leaves -> local ShardedView
    # ------------------------------------------------------------------
    # view type -> (storage attr, storage ctor, field names) — the last
    # field is the optional codes stream in every family
    _VIEW_TABLE = {
        cv.PagedView: ("pool", paged.PagedKVPool, ("k", "v", "codes")),
        cv.PagedMLAView: ("pool", paged.PagedMLAPool,
                          ("ckv", "krope", "codes")),
        cv.ContiguousView: ("cache", LayerKVCache, ("k", "v", "codes")),
        cv.ContiguousMLAView: ("cache", MLACache,
                               ("ckv", "krope", "codes")),
    }

    def _view_leaves(self, view):
        """Decompose a global view into (leaves, in_specs, rebuild).

        ``rebuild(*local_leaves)`` reconstructs the shard's *local*
        inner view inside shard_map. Contiguous caches shard their
        sequence axis (dim 1, after batch); paged layouts shard the
        pool's page axis (dim 0) AND the block table's column axis
        together (each shard's table names local pages), so a shard's
        slice is itself a well-formed paged view.
        """
        b_ax = self.batch_axes or None
        view_cls = type(view)
        attr, ctor, fields = self._VIEW_TABLE[view_cls]
        store = getattr(view, attr)
        is_paged = attr == "pool"
        data = [getattr(store, f) for f in fields]
        has_codes = data[-1] is not None
        leaves = tuple(d for d in data if d is not None)
        if is_paged:
            specs = tuple(P(self.seq_axes, *([None] * (d.ndim - 1)))
                          for d in leaves)
            leaves += (view.block_table,)
            specs += (P(b_ax, self.seq_axes),)
        else:
            specs = tuple(
                P(b_ax, self.seq_axes, *([None] * (d.ndim - 2)))
                for d in leaves)

        def rebuild(*loc):
            if is_paged:
                *vals, bt = loc
                if self.global_page_ids:
                    # global -> local ids: this shard's column slice
                    # only ever names pages it owns (allocator
                    # invariant), so subtracting the shard base maps
                    # every entry into [0, pages_per_shard)
                    bt = bt - _flat_axis_index(self.seq_axes) \
                        * vals[0].shape[0]
            else:
                vals, bt = list(loc), None
            if not has_codes:
                vals = list(vals) + [None]
            storage = ctor(**dict(zip(fields, vals)))
            return view_cls(storage, bt) if is_paged else view_cls(storage)
        return leaves, specs, rebuild

    def _sharded(self, inner) -> cv.ShardedView:
        """Wrap a shard's local inner view with its absolute offset."""
        offset = _flat_axis_index(self.seq_axes) * inner.capacity
        return cv.ShardedView(inner=inner, offset=offset,
                              n_shards=self.n_seq_shards)

    def _run(self, local_fn, view, operands, operand_specs, out_spec):
        """shard_map ``local_fn(sharded_view, *operands)`` over the
        view's leaves."""
        leaves, leaf_specs, rebuild = self._view_leaves(view)

        def body(*args):
            ops_ = args[:len(operands)]
            sv = self._sharded(rebuild(*args[len(operands):]))
            return local_fn(sv, *ops_)

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=tuple(operand_specs) + tuple(leaf_specs),
                       out_specs=out_spec, check_rep=False)
        return fn(*operands, *leaves)

    # ------------------------------------------------------------------
    def gqa(self, cfg: ModelConfig, q: jax.Array, w_h, view,
            n_valid: jax.Array, use_hata) -> jax.Array:
        """q: (B, H, d) global; ``view`` a sequence-sharded cache view
        (or a raw ``LayerKVCache``, coerced). Returns (B, H, d)
        attention output (pre-Wo)."""
        if self.mode == "naive":
            return None                      # caller keeps GSPMD path
        view = cv.as_gqa_view(view)
        b_ax = self.batch_axes or None
        q_spec = P(b_ax, None, None)
        hata_possible = (view.has_codes and cfg.hata.enabled
                         and w_h is not None)
        if hata_possible and not (isinstance(use_hata, bool)
                                  and not use_hata):
            static = use_hata if isinstance(use_hata, bool) else None
            local = functools.partial(self._gqa_sharded, cfg, static)
            return self._run(
                local, view,
                (q, w_h, jnp.asarray(n_valid, jnp.int32),
                 jnp.asarray(use_hata, jnp.bool_)),
                (q_spec, P(None, None, None), P(), P()), q_spec)

        def local_dense(sv, q_, nv_):
            return self._gqa_sharded(cfg, False, sv, q_, None, nv_,
                                     False)
        return self._run(
            local_dense, view,
            (q, jnp.asarray(n_valid, jnp.int32)),
            (q_spec, P()), q_spec)

    def _gqa_sharded(self, cfg: ModelConfig, static_flag,
                     sv: cv.ShardedView, q, w_h, n_valid, use_hata):
        """One shard of the SP GQA decode over a :class:`ShardedView` —
        the same local math for contiguous slices and paged pools:
        batched Hamming scores at absolute positions, exact two-stage
        top-k or local split, then the stats-emitting gather over the
        rows this shard holds (the paged inner translates winners to
        physical rows; the merge below is the only cross-shard
        traffic)."""
        b, h, d = q.shape
        h_kv = cfg.n_kv_heads
        g = h // h_kv
        s_local = sv.s_local
        abs_pos = sv.positions()
        # n_valid may be scalar (offline SP decode) or (B,) — serving
        # waves run slots at different depths, so the validity mask is
        # per row
        nv = jnp.reshape(jnp.asarray(n_valid, jnp.int32), (-1, 1, 1))
        valid = abs_pos[None, None, :] < nv               # (1|B,1,S_l)
        if cfg.sliding_window is not None:
            valid = valid & (abs_pos[None, None, :]
                             > nv - 1 - cfg.sliding_window)
        qg = q.reshape(b, h_kv, g, d)
        scale = d ** -0.5

        def dense():
            k_loc, v_loc = sv.kv_logical()
            mask = jnp.broadcast_to(valid, (b, h_kv, s_local))
            return _partial_stats(qg, k_loc, v_loc, mask, scale)

        def hata():
            q_codes = ha.aggregate_q_codes(q, w_h, h_kv)
            scores = sv.hamming_scores(q_codes, n_valid,
                                       rbit=cfg.hata.rbit,
                                       window=cfg.sliding_window)
            budget = ha.clamped_budget(cfg.hata,
                                       s_local * self.n_seq_shards,
                                       cfg.sliding_window)
            if self.mode == "local_split":
                k_loc = min(max(budget // self.n_seq_shards, 1), s_local)
                top_s, idx_l = jax.lax.top_k(scores, k_loc)
                return sv.gather_stats(q, idx_l, top_s >= 0)
            # two-stage exact: attend only over the global winners this
            # shard owns — an arbitrary (non-prefix) selection mask.
            gv, gi = distributed_topk(scores, budget, self.seq_axes,
                                      s_local)
            li = gi - sv.offset
            owned = (li >= 0) & (li < s_local) & (gv >= 0)
            li_c = jnp.clip(li, 0, s_local - 1)
            return sv.gather_stats(q, li_c, owned)

        if static_flag is None:
            m, l, o = jax.lax.cond(use_hata, hata, dense)
        else:
            m, l, o = hata() if static_flag else dense()
        out = merge_partial_softmax(m, l, o, self.seq_axes)
        return out.reshape(b, h, d).astype(q.dtype)

    # ------------------------------------------------------------------
    def mla(self, cfg: ModelConfig, p, w_h, q_lat: jax.Array, view,
            n_valid: jax.Array, use_hata) -> jax.Array:
        """q_lat: (B, H, r+rope) absorbed queries; ``view`` a sequence-
        sharded latent view (or raw ``MLACache``). Returns (B, H, v_dim)
        in f32 (caller applies Wo)."""
        if self.mode == "naive":
            return None
        view = cv.as_mla_view(view)
        b_ax = self.batch_axes or None
        q_spec = P(b_ax, None, None)
        m = cfg.mla
        h = cfg.n_heads
        wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        hata_possible = (view.has_codes and cfg.hata.enabled
                         and w_h is not None)
        if hata_possible and not (isinstance(use_hata, bool)
                                  and not use_hata):
            static = use_hata if isinstance(use_hata, bool) else None
            local = functools.partial(self._mla_sharded, cfg, static)
            return self._run(
                local, view,
                (q_lat, wuv, w_h, jnp.asarray(n_valid, jnp.int32),
                 jnp.asarray(use_hata, jnp.bool_)),
                (q_spec, P(None, None, None), P(None, None, None),
                 P(), P()), q_spec)

        def local_dense(sv, q_, wuv_, nv_):
            return self._mla_sharded(cfg, False, sv, q_, wuv_, None,
                                     nv_, False)
        return self._run(
            local_dense, view,
            (q_lat, wuv, jnp.asarray(n_valid, jnp.int32)),
            (q_spec, P(None, None, None), P()), q_spec)

    def _mla_logits(self, cfg: ModelConfig, q_lat, ckv_rows, krope_rows):
        """Split-latent logits: q·[c;k_r] = q_c·c + q_r·k_r — avoids
        materializing a concatenated copy of the latent cache."""
        r = cfg.mla.kv_lora_rank
        scale = (cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) ** -0.5
        q_c = q_lat[..., :r].astype(ckv_rows.dtype)
        q_r = q_lat[..., r:].astype(krope_rows.dtype)
        logits = (jnp.einsum("bhr,bsr->bhs", q_c, ckv_rows,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bhr,bsr->bhs", q_r, krope_rows,
                               preferred_element_type=jnp.float32))
        return logits * scale

    @staticmethod
    def _mla_stats(logits, mask, ckv_rows):
        """Flash stats from precomputed logits. logits: (B, H, R) f32,
        mask: (B, R), ckv_rows: (B, R, r)."""
        logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
        m = jnp.maximum(jnp.max(logits, axis=-1), -1e30)
        p = jnp.exp(logits - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhs,bsr->bhr", p.astype(ckv_rows.dtype),
                       ckv_rows, preferred_element_type=jnp.float32)
        return m, l, o

    def _mla_sharded(self, cfg: ModelConfig, static_flag,
                     sv: cv.ShardedView, q_lat, wuv, w_h, n_valid,
                     use_hata):
        """One shard of the SP MLA latent decode over a
        :class:`ShardedView` (contiguous or paged inner): batched
        Hamming kernel over the shared code stream, shard-offset
        masking, then the split-latent stats-emitting gather (q_c·c +
        q_r·k_r logits computed in-kernel; W_uv applied after the
        cross-shard merge)."""
        b, h, _ = q_lat.shape
        s_local = sv.s_local
        abs_pos = sv.positions()
        # scalar or (B,) n_valid — per-row masks for serving waves
        nv = jnp.reshape(jnp.asarray(n_valid, jnp.int32), (-1, 1))
        valid = abs_pos[None] < nv                         # (1|B, S_l)

        def dense():
            ckv_loc, kr_loc = sv.latents_logical()
            logits = self._mla_logits(cfg, q_lat, ckv_loc, kr_loc)
            return self._mla_stats(
                logits, jnp.broadcast_to(valid, (b, s_local)), ckv_loc)

        def hata():
            q_codes = ops.hash_encode(q_lat, hw.head0(w_h))  # (B, H, W)
            scores = sv.hamming_scores(q_codes, n_valid,
                                       rbit=cfg.hata.rbit,
                                       window=cfg.sliding_window)
            s_total = s_local * self.n_seq_shards
            budget = ha.clamped_budget(cfg.hata, s_total,
                                       cfg.sliding_window)
            if self.mode == "local_split":
                k_loc = min(max(budget // self.n_seq_shards, 1), s_local)
                top_s, idx_l = jax.lax.top_k(scores, k_loc)
                mask = top_s >= 0
            else:
                gv, gi = distributed_topk(scores, budget, self.seq_axes,
                                          s_local)
                li = gi - sv.offset
                mask = (li >= 0) & (li < s_local) & (gv >= 0)
                idx_l = jnp.clip(li, 0, s_local - 1)
            m = cfg.mla
            return sv.gather_latent(
                q_lat, idx_l, lora_rank=m.kv_lora_rank,
                scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5,
                sel_mask=mask, return_stats=True)

        if static_flag is None:
            mm, ll, oo = jax.lax.cond(use_hata, hata, dense)
        else:
            mm, ll, oo = hata() if static_flag else dense()
        o_lat = merge_partial_softmax(mm, ll, oo, self.seq_axes)
        o = jnp.einsum("bhr,rhd->bhd", o_lat,
                       wuv.astype(jnp.float32))            # (B,H,dv)
        return o
