"""Fault tolerance: step watchdog, heartbeats, restart supervision.

On a 1000+-node fleet three failure classes dominate:
  * hard failures (process/host death)   -> heartbeat files + supervisor
    restart from the latest atomic checkpoint (elastic to a new mesh);
  * stragglers (slow HBM, thermal, ECC)  -> per-step latency watchdog
    flags outliers for drain/replace;
  * hangs (collective deadlock)          -> watchdog timeout escalates
    to a restart.

The heartbeat directory abstracts the coordination plane: every process
writes ``host_<i>.json`` each step; anyone can audit liveness. On this
single-process container the same code paths are exercised by the test
suite with simulated peers/crashes (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class StepWatchdog:
    """Tracks step latencies; flags stragglers and hangs."""

    def __init__(self, *, window: int = 50, straggler_factor: float = 2.0,
                 hang_timeout_s: float = 300.0):
        self.window = window
        self.factor = straggler_factor
        self.hang_timeout_s = hang_timeout_s
        self.durations: List[float] = []
        self._t0: Optional[float] = None
        self.flagged: List[Dict] = []

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> Optional[Dict]:
        dt = time.monotonic() - self._t0
        report = None
        hist = self.durations[-self.window:]
        if len(hist) >= 10:
            med = float(np.median(hist))
            if dt > self.factor * med:
                report = {"step": step, "duration": dt, "median": med,
                          "kind": "straggler"}
                self.flagged.append(report)
        self.durations.append(dt)
        return report

    def check_hang(self) -> bool:
        return (self._t0 is not None
                and time.monotonic() - self._t0 > self.hang_timeout_s)


class Heartbeat:
    """File-based liveness: one JSON per process, refreshed each step."""

    def __init__(self, directory: str, host_id: int, *,
                 stale_after_s: float = 60.0):
        self.dir = directory
        self.host_id = host_id
        self.stale_after_s = stale_after_s
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int):
        path = os.path.join(self.dir, f"host_{self.host_id}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, path)

    def dead_peers(self) -> List[int]:
        now = time.time()
        dead = []
        for name in os.listdir(self.dir):
            if not name.startswith("host_"):
                continue
            with open(os.path.join(self.dir, name)) as f:
                hb = json.load(f)
            if now - hb["time"] > self.stale_after_s:
                dead.append(int(name.split("_")[1].split(".")[0]))
        return sorted(dead)


def run_with_restarts(make_state: Callable, run: Callable, *,
                      max_restarts: int = 3) -> Dict:
    """Supervisor loop: (re)build state and run; on failure, rebuild from
    the latest checkpoint and continue. ``run(state) -> state`` raises to
    signal failure; returns final state dict with restart count."""
    restarts = 0
    state = make_state()
    while True:
        try:
            state = run(state)
            state["restarts"] = restarts
            return state
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            state = make_state()
