"""Decode-attention strategy hook.

The model's decode path (models/attention.py) calls the local HATA
math unless a strategy is installed; launchers install the
sequence-parallel SPMD strategy from :mod:`repro.distributed.decode`.
Read at *trace* time — jitted steps must be (re)traced after a change.
"""
from __future__ import annotations

from typing import Callable, Optional

_STRATEGY: Optional[Callable] = None
_ACT_CONSTRAINT: Optional[Callable] = None


def set_decode_strategy(fn: Optional[Callable]) -> None:
    global _STRATEGY
    _STRATEGY = fn


def get_decode_strategy() -> Optional[Callable]:
    return _STRATEGY


def set_activation_constraint(fn: Optional[Callable]) -> None:
    """fn(x) -> x with a sharding constraint applied. Installed by
    launchers so the (B, S, D) stream after the embedding lookup lands
    in the canonical layout (batch over DP, D replicated over model) —
    otherwise sharding propagation from a D-sharded embedding table can
    flip GSPMD into all-gathering every weight over the data axis
    (§Perf iteration T1)."""
    global _ACT_CONSTRAINT
    _ACT_CONSTRAINT = fn


def get_activation_constraint() -> Optional[Callable]:
    return _ACT_CONSTRAINT
