"""SPMD pipeline parallelism (GPipe schedule) over a mesh axis.

The layer stack is split into ``n_stages`` contiguous groups; stage s
holds layers [s·L/P, (s+1)·L/P). Stacked layer params are sharded on
their leading L axis over the stage axis (usually ``pod``), so each
stage stores only its slice. Microbatches stream through: at step t,
stage s processes microbatch (t - s) and ``ppermute``s its activations
to stage s+1 — the standard shard_map pipeline pattern. The bubble is
(P-1)/(M+P-1); gradients flow through the same schedule reversed
(autodiff of ppermute is ppermute).

Used by launch/train.py when ``--pipeline pod`` is set; the multi-pod
dry-run exercises it as an alternative to pure hierarchical-DP over the
pod axis.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def spmd_pipeline(stage_fn: Callable, mesh: Mesh, axis: str, *,
                  n_micro: int, data_axes=()):
    """Build a pipelined apply: (stage_params_local, xs) -> ys.

    stage_fn(params_slice, x_mb) -> y_mb applies one stage's layers.
    xs: (n_micro, mb, ...) microbatched inputs (replicated over the
    stage axis; sharded over ``data_axes`` on the mb dim).
    Layer-stacked params must be sharded over ``axis`` on dim 0.
    """
    n_stages = mesh.shape[axis]

    def pipelined(params_local, xs):
        stage = jax.lax.axis_index(axis)
        steps = n_micro + n_stages - 1
        mb_shape = xs.shape[1:]

        def step(carry, t):
            buf, ys = carry
            # stage 0 pulls the next microbatch; others take the buffer
            idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, xs[idx], buf)
            y = stage_fn(params_local, x_in)
            # pass activations downstream (ring; last->0 is ignored)
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages)
                          for i in range(n_stages)])
            out_t = t - (n_stages - 1)
            write = (out_t >= 0) & (stage == n_stages - 1)
            ys = jnp.where(write,
                           ys.at[jnp.clip(out_t, 0, n_micro - 1)].set(y),
                           ys)
            return (buf_next, ys), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        ys0 = jnp.zeros_like(xs)
        (_, ys), _ = jax.lax.scan(step, (buf0, ys0), jnp.arange(steps))
        # every stage returns ys; only the last stage's is real —
        # broadcast it back with a psum of the masked buffer
        ys = jax.lax.psum(
            jnp.where(stage == n_stages - 1, ys, jnp.zeros_like(ys)),
            axis)
        return ys

    in_specs = (P(axis), P(None, data_axes or None))
    out_specs = P(None, data_axes or None)
    return shard_map(pipelined, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
