from repro.distributed import (collectives, decode, fault_tolerance,
                               pipeline, sharding)
from repro.distributed.strategy import (get_decode_strategy,
                                        set_decode_strategy)

__all__ = ["collectives", "decode", "fault_tolerance", "pipeline",
           "sharding", "get_decode_strategy", "set_decode_strategy"]
