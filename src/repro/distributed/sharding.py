"""Sharding policy engine: (config, mesh) -> PartitionSpecs for params,
optimizer state, batches and caches (DESIGN.md §4).

Train params: 2D "FSDP x TP" — the TP-natural dim over ``model``
(attention heads / d_ff / vocab / experts), the other dim over the DP
axes (ZeRO-3: XLA inserts per-layer all-gathers). Dims that don't divide
fall back to replication on that axis — the policy never fails, it
degrades and reports (``explain()``).

Decode caches: **sequence-sharded** over ``model`` (B over DP when it
divides; the 500k single-sequence cell shards S over every axis). This
is what makes 32k x 128 caches for the 405B fit: see EXPERIMENTS.md
§Dry-run bytes-per-device.

MoE experts: E over ``model`` when divisible (EP; XLA all-to-all),
otherwise intra-expert TP over d_ff (Mixtral 8e on a 16-way axis).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


class ShardingPolicy:
    """Resolves per-leaf PartitionSpecs by parameter path patterns."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.dp = dp_axes(mesh)
        self.notes: List[str] = []

    # ------------------------------------------------------------------
    def _spec2d(self, shape, in_axis, out_axis, n_lead: int) -> P:
        """Shard a (..., d_in, d_out) leaf: d_in over ``in_axis``,
        d_out over ``out_axis`` — dropping any axis that doesn't divide."""
        din, dout = shape[-2], shape[-1]
        ia = in_axis if _fits(din, self.mesh, in_axis) else None
        oa = out_axis if _fits(dout, self.mesh, out_axis) else None
        return P(*([None] * n_lead + [ia, oa]))

    def _repl(self, shape) -> P:
        return P(*([None] * len(shape)))

    # ------------------------------------------------------------------
    def param_spec(self, path: str, leaf) -> P:
        cfg, mesh, dp = self.cfg, self.mesh, self.dp
        shape = leaf.shape
        parts = [s.strip(".'[]\"") for s in path.split("/")]
        path = "/".join(parts)
        name = parts[-1]
        n_lead = 0
        if any("stack" in s for s in parts):
            n_lead = 1
            if cfg.family == "vlm" and "cross" not in path:
                n_lead = 2                      # (G, per_group, ...)
        if len(shape) <= n_lead:                # scalars / gates
            return self._repl(shape)

        # --- embeddings / heads ---
        if "embed" in name:
            # (Vp, D) [audio: (nb, V, D)] — vocab over model, D over dp.
            # NOTE (§Perf iteration T1): D-over-model variants trip an
            # XLA SPMD gather-partitioning bug; with the post-embedding
            # activation constraint installed the partitioner lowers
            # this layout to masked lookup + small psum (no table
            # all-gather), so it is both correct and cheap.
            lead = len(shape) - 2
            va = "model" if _fits(shape[-2], mesh, "model") else None
            da = dp if _fits(shape[-1], mesh, dp) else None
            return P(*([None] * lead + [va, da]))
        if "lm_head" in name:
            lead = len(shape) - 2
            da = dp if _fits(shape[-2], mesh, dp) else None
            va = "model" if _fits(shape[-1], mesh, "model") else None
            return P(*([None] * lead + [da, va]))
        if name in ("meta", "img_proj"):
            return self._spec2d(shape, None, dp, len(shape) - 2)

        # --- hash weights: small, replicated (loaded once per decode) ---
        if "hash" in path:
            return self._repl(shape)

        # --- MoE experts: (E, d, f) ---
        if "moe" in parts:
            if name == "router":
                return self._spec2d(shape, dp, None, n_lead)
            if name in ("wi", "wu", "wd") and "shared" not in path:
                e = cfg.moe
                if e.parallelism == "ep" and _fits(e.n_experts, mesh,
                                                   "model"):
                    return P(*([None] * n_lead + ["model", None, dp
                                if _fits(shape[-1], mesh, dp) else None]))
                # intra-expert TP: shard d_ff over model
                ff_axis = -1 if name in ("wi", "wu") else -2
                sp = [None] * (n_lead + 1) + [None, None]
                sp[ff_axis] = ("model" if _fits(shape[ff_axis], mesh,
                                                "model") else None)
                other = -2 if ff_axis == -1 else -1
                sp[other] = dp if _fits(shape[other], mesh, dp) else None
                return P(*sp)

        # --- attention projections ---
        if name in ("wq", "wuk", "wuv"):
            return self._spec2d(shape, dp, "model", n_lead)
        if name in ("wk", "wv"):
            # kv heads usually < model axis -> falls back to dp-only
            return self._spec2d(shape, dp, "model", n_lead)
        if name == "wo":
            return self._spec2d(shape, "model", dp, n_lead)
        if name in ("wdkv", "wkr"):
            return self._spec2d(shape, dp, None, n_lead)
        if name in ("bq", "bk", "bv"):
            a = "model" if _fits(shape[-1], mesh, "model") else None
            return P(*([None] * (len(shape) - 1) + [a]))

        # --- dense FFN ---
        if name in ("wi", "wu"):
            return self._spec2d(shape, dp, "model", n_lead)
        if name == "wd":
            return self._spec2d(shape, "model", dp, n_lead)

        # --- SSM ---
        if name == "in_proj":
            return self._spec2d(shape, dp, "model", n_lead)
        if name == "out_proj":
            return self._spec2d(shape, "model", dp, n_lead)
        if name in ("conv_w", "conv_b"):
            a = "model" if _fits(shape[-1], mesh, "model") else None
            return P(*([None] * (len(shape) - 1) + [a]))

        # norms, gates, scalars, dt_bias, a_log, d_skip ...
        return self._repl(shape)

    # ------------------------------------------------------------------
    def param_specs(self, params) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        specs = []
        for path, leaf in flat:
            p = "/".join(str(k) for k in path)
            specs.append(self.param_spec(p, leaf))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def opt_specs(self, param_specs) -> Any:
        """AdamWState specs: m/v mirror the params; step replicated."""
        from repro.optim.adamw import AdamWState
        return AdamWState(step=P(), m=param_specs, v=param_specs)

    # ------------------------------------------------------------------
    def batch_spec(self, kind: str) -> Dict[str, P]:
        dp = self.dp
        tok = P(dp) if kind != "audio" else P(dp, None, None)
        return {"tokens": (P(dp, None, None)
                           if self.cfg.family == "audio" else P(dp, None)),
                "image_embeds": P(dp, None, None)}

    def cache_spec(self, path: str, leaf, batch: int) -> P:
        """Decode caches: B over dp (if divisible), S over model.
        Works for both stacked (L, B, S, ...) and list (B, S, ...)
        layouts — lead dims are inferred from the leaf rank."""
        mesh, dp = self.mesh, self.dp
        shape = leaf.shape
        name = path.split("/")[-1].lstrip(".")
        if "cross" in path:
            # VLM cross-attention KV: (B, T_img, Hkv, hd) (+ lead dims)
            n_lead = max(0, len(shape) - 4)
            b_ax = dp if _fits(batch, mesh, dp) else None
            return P(*([None] * n_lead + [b_ax]
                       + [None] * (len(shape) - n_lead - 1)))
        base_rank = {"k": 4, "v": 4, "ckv": 3, "krope": 3, "conv": 3,
                     "ssm": 4}.get(name)
        if name == "codes":
            base_rank = 3 if self.cfg.mla is not None else 4
        if base_rank is None:
            base_rank = len(shape)
        n_lead = max(0, len(shape) - base_rank)
        body = shape[n_lead:]
        b_ax: Optional[Any] = dp if _fits(batch, mesh, dp) else None
        if name == "conv":
            return P(*([None] * n_lead + [b_ax] +
                       [None] * (len(body) - 1)))
        if name == "ssm":
            # (B, nh, hd, N): heads over model when divisible
            nh_ax = ("model" if len(body) >= 2
                     and _fits(body[1], mesh, "model") else None)
            sp = [None] * n_lead + [b_ax, nh_ax] + \
                [None] * (len(body) - 2)
            return P(*sp)
        # KV/code caches: (B, S, ...) — S over model; if B unsharded and
        # S divides by the whole mesh, spread S over everything.
        if len(body) >= 2:
            s_ax: Any = "model"
            if b_ax is None and _fits(body[1], mesh,
                                      dp + ("model",)):
                s_ax = dp + ("model",)
            if not _fits(body[1], mesh, s_ax):
                s_ax = None
            return P(*([None] * n_lead + [b_ax, s_ax] +
                       [None] * (len(body) - 2)))
        return P(*([None] * len(shape)))

    def cache_specs(self, caches, batch: int) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
        specs = []
        for path, leaf in flat:
            p = "/".join(str(k) for k in path)
            specs.append(self.cache_spec(p, leaf, batch))
        return jax.tree_util.tree_unflatten(treedef, specs)

    # ------------------------------------------------------------------
    def pool_spec(self, path: str, leaf) -> P:
        """Paged page-pool leaves: (P, page, H_kv, d) / (P, page, r).

        Pages are shared across requests (any request may hold any
        page), so neither the page axis nor the in-page row axis can be
        sequence-sharded the way a contiguous (B, S, ...) cache's S
        axis is — the TP-natural split for a pool is the kv-head axis
        over ``model`` (each device then holds every page of *its*
        heads, and the paged kernels' per-head grids read locally).
        Latent pools (MLA: one shared stream, no head axis) replicate;
        so does a head axis that doesn't divide the ``model`` axis.
        """
        mesh = self.mesh
        shape = leaf.shape
        name = path.split("/")[-1].lstrip(".")
        if name in ("k", "v") or (name == "codes" and len(shape) == 4):
            h_ax = ("model" if _fits(shape[2], mesh, "model") else None)
            if h_ax is None:
                self.notes.append(
                    f"pool {path}: H_kv={shape[2]} !% model -> replicated")
            return P(None, None, h_ax, None)
        return self._repl(shape)          # ckv / krope / latent codes

    def pool_specs(self, pools) -> Any:
        """Specs for a list of per-layer page pools (Model.init_paged_pools)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(pools)
        specs = []
        for path, leaf in flat:
            p = "/".join(str(k) for k in path)
            specs.append(self.pool_spec(p, leaf))
        return jax.tree_util.tree_unflatten(treedef, specs)

    # ------------------------------------------------------------------
    def named(self, spec_tree) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    def explain(self, params) -> str:
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        lines = []
        for path, leaf in flat:
            p = "/".join(str(k) for k in path)
            lines.append(f"{p:70s} {str(leaf.shape):24s} "
                         f"{self.param_spec(p, leaf)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Sequence-parallel paged pools (the sharded-pool serving engine)
# ---------------------------------------------------------------------------
# ``ShardingPolicy.pool_spec`` above is the GSPMD layout (pages
# replicated, kv-heads over ``model``). The sharded-pool engine instead
# runs SP decode waves: the pool's PAGE axis and the block table's
# COLUMN axis shard together over the sequence axis, with GLOBAL page
# ids in the tables (SPDecode(global_page_ids=True) localizes them
# inside shard_map; per-shard page ownership is the
# ``ShardedPageAllocator``'s invariant). These helpers are the one
# place that layout is spelled.

def seq_pool_spec(leaf, seq_axis: str = "model") -> P:
    """(P, page, ...) pool leaf: page axis over the sequence axis."""
    return P(seq_axis, *([None] * (leaf.ndim - 1)))


def shard_paged_pools(mesh: Mesh, pools, seq_axis: str = "model"):
    """Device_put a list of per-layer page pools with the page axis
    sharded over ``seq_axis`` (every other dim replicated)."""
    return jax.tree.map(
        lambda leaf: jax.device_put(
            leaf, NamedSharding(mesh, seq_pool_spec(leaf, seq_axis))),
        pools)


def block_table_sharding(mesh: Mesh,
                         seq_axis: str = "model") -> NamedSharding:
    """(B, T) block table: columns follow the pool's page axis."""
    return NamedSharding(mesh, P(None, seq_axis))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Small per-wave operands (tokens, pos, ids, steps)."""
    return NamedSharding(mesh, P())
