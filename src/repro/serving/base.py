"""Shared continuous-batching engine machinery.

:class:`EngineBase` owns everything that is policy-free and identical
across engines: the request queue, the static slot table, per-request
RNG sampling, the step/run driver loop, and — crucially — the ONE
retirement path that stamps a :class:`~repro.serving.request.Request`'s
terminal fields. The dense :class:`~repro.serving.engine.ServingEngine`
and the paged :class:`~repro.serving.scheduler.PagedServingEngine`
subclass it with only admission and capacity/eviction policy local
(which is exactly what *should* differ between a static-slab cache and
a page pool).

Why the retirement path is centralized: the two engines' finish logic
had drifted — the dense engine stamped ``truncated``/``t_done`` inline
at admission and at the cache wall (and never counted truncations),
the paged one via its own ``_finish_truncated`` (which did). Every
terminal transition now goes through :meth:`EngineBase._finish`, so
``truncated``, ``t_done`` and ``stats["truncated"]`` are set
identically whichever engine retires the request.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional, Union

import jax
import numpy as np

from repro.core import budgets as budgets_mod
from repro.models import Model
from repro.serving.request import Request
from repro.serving.sampling import pick_tokens


class EngineBase:
    """Queue + slots + RNG + retirement; subclasses add the waves."""

    def __init__(self, model: Model, params, *, max_batch: int,
                 sample: str = "greedy", seed: int = 0,
                 budget_table: Union[budgets_mod.BudgetTable, str,
                                     None] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.sample = sample
        # Per-layer HATA budget overrides (core/budgets.py). A path is
        # loaded+validated eagerly so a malformed table fails at
        # construction, not mid-serve. None inherits the ambient table
        # (set_budget_table / REPRO_BUDGET_TABLE), if any.
        if isinstance(budget_table, str):
            budget_table = budgets_mod.load_budget_table(budget_table)
        self.budget_table = budget_table
        # one base key, never split or advanced by engine-global events:
        # sampled picks derive a per-request stream from it (see _pick),
        # so a request's tokens are a pure function of (seed, request
        # id, step) — independent of which other requests happen to be
        # co-scheduled, and bit-exact under preemption replay.
        self._base_key = jax.random.PRNGKey(seed)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_out": 0, "truncated": 0}
        self._done_this_step: List[Request] = []

    # ------------------------------------------------------------------
    def _with_table(self, fn):
        """Run ``fn`` with this engine's budget table installed.

        Budgets are resolved at trace time (python-int layers under
        jit), so the table must be active whenever a wave traces — and
        on every call for the eager offload path. No-op when the engine
        has no table of its own (ambient table still applies).
        """
        if self.budget_table is None:
            return fn

        def wrapped(*a, **k):
            with budgets_mod.use_budget_table(self.budget_table):
                return fn(*a, **k)
        return wrapped

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _pick(self, logits, reqs):
        """Next-token pick for each logits row; ``reqs`` aligns a
        Request (or None) with every row — per-request (id, step) RNG
        streams, see serving/sampling.py."""
        return pick_tokens(self._base_key, logits, reqs, self.sample)

    @staticmethod
    def _to_py(tok):
        a = np.asarray(tok)
        return int(a) if a.ndim == 0 else a.tolist()

    # ------------------------------------------------------------------
    # unified retirement — the one place terminal fields are stamped
    # ------------------------------------------------------------------
    def _finish(self, req: Request, *, truncated: bool = False):
        """Retire ``req`` this step. ``truncated=True`` marks an
        engine-capacity termination (prompt too large, cache/pool wall)
        and counts it; both engines stamp the same fields in the same
        order."""
        if truncated:
            req.truncated = True
            self.stats["truncated"] += 1
        if req.t_done is None:
            req.t_done = time.monotonic()
        self._done_this_step.append(req)

    # ------------------------------------------------------------------
    # engine-specific hooks
    # ------------------------------------------------------------------
    def _admit(self):
        """Admission policy: move queued requests toward slots."""
        raise NotImplementedError

    def _advance(self):
        """One engine tick past admission (prefill chunks and/or the
        decode wave)."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit + advance one tick. Returns requests finished now."""
        self._done_this_step = []
        self._admit()
        self._advance()
        return self._done_this_step

    def run(self, requests: List[Request]) -> List[Request]:
        """Submit all, run to completion, return in completion order."""
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        guard = 0
        while len(done) < len(requests):
            done.extend(self.step())
            guard += 1
            assert guard < 100000, "engine livelock"
        return done
