"""Shared continuous-batching engine machinery.

:class:`EngineBase` owns everything that is policy-free and identical
across engines: the admission controller (queue + lookahead, see
serving/plane.py), the static slot table, per-request RNG sampling,
the step/run driver loop, and — crucially — the ONE token-emission
path and the ONE retirement path that stamp a
:class:`~repro.serving.request.Request`'s timing/terminal fields. The
dense :class:`~repro.serving.engine.ServingEngine` and the paged
:class:`~repro.serving.scheduler.PagedServingEngine` subclass it with
only admission and capacity/eviction policy local (which is exactly
what *should* differ between a static-slab cache and a page pool).

Why emission/retirement are centralized: the two engines' finish logic
had drifted once before (inline ``truncated``/``t_done`` stamping vs a
private ``_finish_truncated``), and per-token timing would have drifted
the same way — the dense engine stamped ``t_first_token`` in two
places and the paged one in two others, and neither kept per-token
stamps at all. Every emitted token now goes through
:meth:`EngineBase._record_token` (output append + ``t_tokens`` stamp +
``t_first_token`` + stats + the ``on_token`` callback) and every
terminal transition through :meth:`EngineBase._finish`, so TTFT/ITL
measurements mean the same thing whichever engine — or whichever
sync/async tick — produced them.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Union

import jax
import numpy as np

from repro.core import budgets as budgets_mod
from repro.models import Model
from repro.serving import speculative as spec_mod
from repro.serving.plane import AdmissionController
from repro.serving.request import Request
from repro.serving.sampling import pick_tokens


class EngineBase:
    """Admission + slots + RNG + emission/retirement; subclasses add
    the waves.

    ``async_waves=True`` switches the subclass tick to the
    double-buffered wave loop (launch wave *n+1* before harvesting
    wave *n* — see serving/plane.py); outputs are bit-exact vs the
    synchronous tick because tokens are pure functions of
    (seed, id, step). ``on_token(req, tok)`` fires from
    :meth:`_record_token` for every emitted token — the streaming/
    detokenize hook whose host cost the async tick hides under the
    next wave.
    """

    def __init__(self, model: Model, params, *, max_batch: int,
                 sample: str = "greedy", seed: int = 0,
                 budget_table: Union[budgets_mod.BudgetTable, str,
                                     None] = None,
                 lookahead: int = 0, async_waves: bool = False,
                 on_token: Optional[Callable[[Request, int],
                                             None]] = None,
                 speculate: Optional[
                     spec_mod.SpeculationController] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.sample = sample
        # Per-layer HATA budget overrides (core/budgets.py). A path is
        # loaded+validated eagerly so a malformed table fails at
        # construction, not mid-serve. None inherits the ambient table
        # (set_budget_table / REPRO_BUDGET_TABLE), if any.
        if isinstance(budget_table, str):
            budget_table = budgets_mod.load_budget_table(budget_table)
        self.budget_table = budget_table
        # one base key, never split or advanced by engine-global events:
        # sampled picks derive a per-request stream from it (see _pick),
        # so a request's tokens are a pure function of (seed, request
        # id, step) — independent of which other requests happen to be
        # co-scheduled, and bit-exact under preemption replay.
        self._base_key = jax.random.PRNGKey(seed)
        self.admission = AdmissionController(lookahead)
        self.async_waves = async_waves
        self.on_token = on_token
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)
        # per-slot (id, step) mirrors feeding the fused device-side
        # pick: step = len(req.output) at wave LAUNCH (the sampled
        # stream index of the token the wave will emit)
        self._ids = np.zeros(max_batch, np.int32)
        self._steps = np.zeros(max_batch, np.int32)
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_out": 0, "truncated": 0}
        # speculative decoding (serving/speculative.py): the decode
        # wave becomes a draft->verify ROUND committing 1..depth+1
        # tokens per slot per dispatch
        self.spec = speculate
        if speculate is not None:
            assert model.supports_paged, (
                f"{model.cfg.name}: speculative decoding needs the "
                "verify-chunk families (dense/moe attention KV, no "
                "meta rows) — even on the dense engine")
            self.stats.update(
                spec_rounds=0, spec_drafted=0, spec_accepted=0,
                # rounds by accepted count: index a-1 holds rounds that
                # committed a tokens (a-1 draft hits + the verify pick)
                spec_acc_hist=[0] * (speculate.depth + 1))
        self._done_this_step: List[Request] = []

    # ------------------------------------------------------------------
    @property
    def queue(self):
        """The admission controller's deque (compat view — tests and
        callers inspect/seed it directly)."""
        return self.admission.queue

    # ------------------------------------------------------------------
    def _with_table(self, fn):
        """Run ``fn`` with this engine's budget table installed.

        Budgets are resolved at trace time (python-int layers under
        jit), so the table must be active whenever a wave traces — and
        on every call for the eager offload path. No-op when the engine
        has no table of its own (ambient table still applies).
        """
        if self.budget_table is None:
            return fn

        def wrapped(*a, **k):
            with budgets_mod.use_budget_table(self.budget_table):
                return fn(*a, **k)
        return wrapped

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # restamp at hand-off: a frontend may construct requests long
        # before submitting them (closed-loop follow-ups), and queueing
        # time = t_admitted - t_submit must start here. Preemption
        # requeues bypass submit(), keeping the original stamp.
        req.t_submit = time.monotonic()
        self.admission.submit(req)

    def _pick(self, logits, reqs):
        """Next-token pick for each logits row; ``reqs`` aligns a
        Request (or None) with every row — per-request (id, step) RNG
        streams, see serving/sampling.py. (Decode waves fuse this into
        the worker jit via ``pick_tokens_device``; this eager entry is
        for prefill logits at admission.)"""
        return pick_tokens(self._base_key, logits, reqs, self.sample)

    @staticmethod
    def _to_py(tok):
        a = np.asarray(tok)
        return int(a) if a.ndim == 0 else a.tolist()

    # ------------------------------------------------------------------
    # unified token emission — the one place tokens + stamps land
    # ------------------------------------------------------------------
    def _record_token(self, req: Request, tok) -> None:
        """Append one emitted token to ``req`` and stamp its wall-clock
        time. EVERY token any engine emits (admission pick, sync wave,
        async harvest) lands here, so ``t_tokens``/``t_first_token``/
        ``tokens_out`` and the ``on_token`` streaming hook cannot drift
        between paths."""
        req.output.append(tok)
        now = time.monotonic()
        if req.t_first_token is None:
            req.t_first_token = now
        req.t_tokens.append(now)
        self.stats["tokens_out"] += 1
        if self.on_token is not None:
            self.on_token(req, tok)

    # ------------------------------------------------------------------
    # unified retirement — the one place terminal fields are stamped
    # ------------------------------------------------------------------
    def _finish(self, req: Request, *, truncated: bool = False):
        """Retire ``req`` this step. ``truncated=True`` marks an
        engine-capacity termination (prompt too large, cache/pool wall)
        and counts it; both engines stamp the same fields in the same
        order."""
        if truncated:
            req.truncated = True
            self.stats["truncated"] += 1
        if req.t_done is None:
            req.t_done = time.monotonic()
        self._done_this_step.append(req)

    # ------------------------------------------------------------------
    # speculative waves (shared: both engines launch SpecWaves when
    # self.spec is set; see serving/speculative.py for the round math)
    # ------------------------------------------------------------------
    def _settle_spec(self, wave: spec_mod.SpecWave) -> np.ndarray:
        """Block on a speculative wave's acceptance counts and COMMIT
        them: each live slot's pos/step mirrors advance by its count
        and (paged) surplus lookahead pages are returned — through the
        ONE rollback helper (``speculative.rollback_slot``). Idempotent
        (the wave caches ``acc_np``): the launch path settles the
        in-flight wave IN PLACE before page planning (plans need the
        true positions, but drains inside the planning ladder must
        still find the wave in the worker), the drain path settles
        again before harvesting. Token recording is NOT done here —
        settling is the part round n+1 needs; harvesting
        (:meth:`_apply_spec_wave`) can hide under its device time."""
        if wave.acc_np is None:
            wave.acc_np = np.asarray(wave.acc)
            for slot, req in enumerate(wave.reqs):
                if req is None or self.slots[slot] is not req:
                    continue
                acc = int(wave.acc_np[slot])
                spec_mod.rollback_slot(self, slot,
                                       int(wave.pos0[slot]) + acc)
                self._steps[slot] = int(wave.steps0[slot]) + acc
        return wave.acc_np

    def _apply_spec_wave(self,
                         wave: Optional[spec_mod.SpecWave]) -> None:
        """Harvest a speculative wave: record each slot's committed
        tokens (the TARGET picks — an accepted draft token and the
        target's own pick for that stream index are the same token by
        construction) and retire finished requests. Slots that turned
        over since launch discard their tokens against the snapshot,
        the same rule as plain waves."""
        if wave is None:
            return
        acc = self._settle_spec(wave)
        toks = np.asarray(wave.toks)           # blocks on the device
        depth = toks.shape[1] - 1
        self.stats["spec_rounds"] += 1
        for slot, req in enumerate(wave.reqs):
            if req is None or req.done or self.slots[slot] is not req:
                continue
            self.stats["spec_drafted"] += depth
            self.stats["spec_acc_hist"][int(acc[slot]) - 1] += 1
            st = req.stats
            st["spec_rounds"] = st.get("spec_rounds", 0) + 1
            st["spec_drafted"] = st.get("spec_drafted", 0) + depth
            emitted = 0
            for j in range(int(acc[slot])):
                self._record_token(req, self._to_py(toks[slot, j]))
                emitted += 1
                if req.done:
                    break
            self.stats["spec_accepted"] += emitted
            st["spec_accepted"] = st.get("spec_accepted", 0) + emitted
            if req.done:
                self._retire(slot, req)

    def _retire(self, slot: int, req: Request):
        """Free ``slot`` and finish its request (engine-specific slot
        teardown; subclasses override)."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    # engine-specific hooks
    # ------------------------------------------------------------------
    def _admit(self):
        """Admission policy: move queued requests toward slots."""
        raise NotImplementedError

    def _advance(self):
        """One engine tick past admission (prefill chunks and/or the
        decode wave)."""
        raise NotImplementedError

    def _drain(self):
        """Block on any in-flight async wave and apply its tokens.
        Synchronous engines have nothing in flight; async subclasses
        override. MUST be called before preempting/evicting or
        wall-truncating a live slot (the victim's in-flight token has
        to land before its state is torn down, or resume replay would
        drop a token the sync engine emitted)."""

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit + advance one tick. Returns requests finished now."""
        self._done_this_step = []
        self._admit()
        self._advance()
        return self._done_this_step

    def run(self, requests: List[Request]) -> List[Request]:
        """Submit all, run to completion, return in completion order.

        The livelock guard counts consecutive ticks WITHOUT PROGRESS
        (progress = any change to the tokens/prefill/truncation
        counters or a completed request), not raw ticks: a tick-count
        guard miscounts work that legitimately spans many ticks — a
        speculative round that rejects every draft token still commits
        the verify wave's own pick (tokens_out moved — that is
        progress), while an engine spinning on DEFERred admission
        moves nothing and should trip fast. A far looser absolute
        cap stays as the runaway backstop.
        """
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        guard = idle = 0
        sig = None
        while len(done) < len(requests):
            done.extend(self.step())
            guard += 1
            assert guard < 10_000_000, "engine runaway"
            now = (self.stats["tokens_out"], self.stats["prefills"],
                   self.stats["truncated"],
                   self.stats.get("prefill_chunks", 0), len(done))
            idle = idle + 1 if now == sig else 0
            sig = now
            assert idle < 1000, (
                f"engine livelock: 1000 ticks with no progress "
                f"(tokens_out/prefills/truncated/chunks/done = {now})")
        return done
