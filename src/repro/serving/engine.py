"""Continuous-batching serving engine with HATA decode (dense slab).

Slot model (static shapes, jit-friendly — the TPU serving pattern):
  * one batched KV+code cache of ``max_batch`` slots x ``max_len`` rows
    (list layout: per-layer buffers, in-place row appends);
  * admission: a new request is prefilled with B=1 (computing its own
    KV + hash codes, Alg. 1), then its cache rows are *inserted* into a
    free slot (one DUS per layer on dim 0);
  * decode: ONE jit'd step advances every active slot together with
    per-slot positions (slots sit at different depths — per-row RoPE,
    per-row validity masks, per-row cache appends). The HATA layers of
    that step bottom out in the batched score->select->gather pipeline
    of ``core.hash_attention.hata_decode_batched``: the (B,) position
    vector flows into per-row score masks, and the whole wave is served
    by one batched Hamming dispatch plus one batched fused-gather
    dispatch per layer — no per-slot or per-head kernel launches;
  * inactive slots decode garbage into their own rows (masked out of
    results, overwritten at next admission) — the standard price of
    static shapes.

The wave itself is owned by serving-plane workers (serving/plane.py):
the decode step fn FUSES the next-token pick, so the wave's tokens
stay device-resident and feed the next wave directly. With
``async_waves=True`` each tick launches wave *n+1* before blocking on
wave *n*'s tokens (double-buffered; host retirement/streaming work
overlaps device execution), and the per-request RNG streams plus the
drain-before-truncation rule keep outputs bit-exact vs the
synchronous tick.

The engine is model-agnostic: any family with a decode path works
(GQA/MLA/hybrid; HATA on or off per config). Queue, sampling,
token-emission and the unified retirement path live in
:class:`~repro.serving.base.EngineBase`; only the slab admission + the
max_len wall are local here.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.serving import plane
from repro.serving import speculative as spec_mod
from repro.serving.base import EngineBase
from repro.serving.plane import ADMIT, TRUNCATE, Wave
from repro.serving.request import Request


class ServingEngine(EngineBase):
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_len: int = 256, sample: str = "greedy",
                 seed: int = 0, budget_table=None, lookahead: int = 0,
                 async_waves: bool = False, on_token=None,
                 speculate: Optional[
                     spec_mod.SpeculationController] = None):
        super().__init__(model, params, max_batch=max_batch,
                         sample=sample, seed=seed,
                         budget_table=budget_table, lookahead=lookahead,
                         async_waves=async_waves, on_token=on_token,
                         speculate=speculate)
        self.max_len = max_len
        cfg = model.cfg
        self.meta = cfg.meta_tokens
        self.caches = model.init_caches(max_batch, max_len,
                                        layout="list")
        # the device-resident token feed: wave n's fused-pick output is
        # wave n+1's input without a host round-trip; admission patches
        # its slot in (a handle-level .at[].set, ordered after any
        # in-flight wave by data dependence)
        self._tok_feed = jnp.zeros(
            (max_batch, cfg.audio.n_codebooks)
            if cfg.family == "audio" else (max_batch,), jnp.int32)
        self.decode = plane.dense_decode_worker(
            model, sample=sample, base_key=self._base_key,
            wrap=self._with_table, speculate=speculate)
        self.prefill = plane.dense_prefill_worker(
            model, wrap=self._with_table)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _probe(self, req: Request) -> str:
        # the prompt alone overflowing the cache is a shape error at
        # prefill — truncate at admission; the slab has no other
        # admission resource (slot availability gates the loop), so the
        # dense probe never defers and lookahead is first-fit = FCFS
        return TRUNCATE if req.prompt_len > self.max_len else ADMIT

    def _admit(self):
        while None in self.slots:
            sel = self.admission.select(self._probe)
            if sel is None:
                return
            req, verdict = sel
            if verdict == TRUNCATE:
                self._finish(req, truncated=True)
                continue
            self._admit_one(req)

    def _admit_one(self, req: Request):
        slot = self.slots.index(None)
        req.slot = slot
        single = self.model.init_caches(1, self.max_len, layout="list")
        batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, single = self.prefill.extra["prefill"](
            self.params, batch, single)
        self.caches = self.prefill.extra["insert"](
            self.caches, single, jnp.int32(slot))
        tok = self._pick(logits, [req])[0]
        self._record_token(req, self._to_py(tok))
        self.stats["prefills"] += 1
        if req.done:
            # a zero/one-new-token request retires at admission —
            # same rule as the paged engine's _finish_prefill
            self._finish(req)
            return
        self._tok_feed = self._tok_feed.at[slot].set(
            jnp.asarray(tok, jnp.int32))
        self.pos[slot] = req.prompt_len + self.meta
        self._ids[slot] = req.id
        self._steps[slot] = len(req.output)
        self.slots[slot] = req

    # ------------------------------------------------------------------
    # waves
    # ------------------------------------------------------------------
    def _drain(self):
        if self.spec is not None:
            self._apply_spec_wave(self.decode.take())
        else:
            self._apply_wave(self.decode.take())

    def _launch_wave(self) -> Optional[Wave]:
        """Launch the next wave; returns the PREVIOUS in-flight wave
        (taken, not yet applied) so the caller harvests it after the
        new launch."""
        prev = self.decode.take()
        if not any(s is not None for s in self.slots):
            return prev
        snapshot = list(self.slots)
        # .copy(): device_put of a host array may alias its buffer
        # zero-copy, and pos/_steps are mutated below while the wave is
        # still in flight — the wave must read the launch-time values
        toks, self.caches = self.decode.step(
            self.params, self._tok_feed, self.caches,
            jnp.asarray(self.pos.copy()), jnp.asarray(self._ids.copy()),
            jnp.asarray(self._steps.copy()))
        self._tok_feed = toks
        self.stats["decode_steps"] += 1
        for slot, req in enumerate(snapshot):
            if req is not None:
                # pos/_steps count the LAUNCHED wave: pos = rows written
                # including in flight, _steps = the stream index of the
                # next token to be picked
                self.pos[slot] += 1
                self._steps[slot] += 1
        self.decode.put(Wave(toks=toks, reqs=snapshot))
        return prev

    def _apply_wave(self, wave: Optional[Wave]):
        """Harvest one wave: block on its tokens, record them against
        the LAUNCH-time snapshot. Slots that retired or turned over
        since launch discard their speculative token."""
        if wave is None:
            return
        toks_np = np.asarray(wave.toks)       # blocks on the device
        for slot, req in enumerate(wave.reqs):
            if req is None or req.done or self.slots[slot] is not req:
                continue
            self._record_token(req, self._to_py(toks_np[slot]))
            if req.done:
                self._finish(req)
                self.slots[slot] = None

    def _retire(self, slot: int, req: Request):
        spec_mod.rollback_slot(self, slot, 0)   # dense: rewind only
        self.slots[slot] = None
        self._finish(req)

    # ------------------------------------------------------------------
    # speculative rounds (self.spec set; round fn built by
    # plane.dense_decode_worker, math in serving/speculative.py)
    # ------------------------------------------------------------------
    def _launch_spec_round(self) -> Optional[spec_mod.SpecWave]:
        """Dense twin of the paged spec launch. Settle the in-flight
        round IN PLACE first: the wall check below needs the SETTLED
        positions (on stale launch-time mirrors a slot already at the
        wall would launch a round with no writable row and commit a
        garbage token), and unlike plain waves pos only advances at
        settle — by the acceptance count. Coverage is the slab itself:
        every slot owns max_len rows, so cov just encodes the wall."""
        if self.decode.inflight is not None:
            self._settle_spec(self.decode.inflight)
        wall = self.max_len + self.meta
        for slot, req in enumerate(self.slots):
            if req is not None and self.pos[slot] >= wall:
                self._drain()                  # land in-flight tokens
                if self.slots[slot] is not req:
                    continue                   # retired at drain
                self._finish(req, truncated=True)
                self.slots[slot] = None
        prev = self.decode.take()
        if not any(s is not None for s in self.slots):
            return prev
        snapshot = list(self.slots)
        pos0 = self.pos.copy()
        steps0 = self._steps.copy()
        cov = np.minimum(pos0 + self.spec.depth + 1,
                         wall).astype(np.int32)
        feed, targets, acc, self.caches = self.decode.step(
            self.params, self._tok_feed, self.caches,
            jnp.asarray(pos0), jnp.asarray(self._ids.copy()),
            jnp.asarray(steps0), jnp.asarray(cov))
        self._tok_feed = feed
        self.stats["decode_steps"] += 1
        self.decode.put(spec_mod.SpecWave(
            toks=targets, acc=acc, reqs=snapshot,
            pos0=pos0, steps0=steps0))
        return prev

    # ------------------------------------------------------------------
    def _advance(self):
        """Truncate out-of-cache slots, then run one decode wave
        (async: launch wave n+1 before harvesting wave n)."""
        if self.spec is not None:
            # the wall check lives INSIDE the spec launch — it must run
            # on settled positions, which only exist after the in-flight
            # round is settled there
            prev = self._launch_spec_round()
            self._apply_spec_wave(prev)    # round n (async overlap)
            if not self.async_waves:
                self._apply_spec_wave(self.decode.take())
            return
        # out-of-cache: a slot whose next decode would write at or past
        # max_len is terminated NOW with an explicit ``truncated`` flag
        # and its slot freed — decoding on would clamp the cache append
        # onto the last row and emit garbage tokens. pos counts the
        # in-flight wave, so the victim's last token is still in flight:
        # drain first (the drain rule), then truncate whoever is left.
        for slot, req in enumerate(self.slots):
            if req is not None and \
                    self.pos[slot] >= self.max_len + self.meta:
                self._drain()
                if self.slots[slot] is not req:
                    continue                   # retired at drain
                self._finish(req, truncated=True)
                self.slots[slot] = None
        prev = self._launch_wave()
        self._apply_wave(prev)             # wave n (None in sync steady
        if not self.async_waves:           # state: applied last tick)
            self._apply_wave(self.decode.take())
