"""Continuous-batching serving engine with HATA decode (dense slab).

Slot model (static shapes, jit-friendly — the TPU serving pattern):
  * one batched KV+code cache of ``max_batch`` slots x ``max_len`` rows
    (list layout: per-layer buffers, in-place row appends);
  * admission: a new request is prefilled with B=1 (computing its own
    KV + hash codes, Alg. 1), then its cache rows are *inserted* into a
    free slot (one DUS per layer on dim 0);
  * decode: ONE jit'd step advances every active slot together with
    per-slot positions (slots sit at different depths — per-row RoPE,
    per-row validity masks, per-row cache appends). The HATA layers of
    that step bottom out in the batched score->select->gather pipeline
    of ``core.hash_attention.hata_decode_batched``: the (B,) position
    vector flows into per-row score masks, and the whole wave is served
    by one batched Hamming dispatch plus one batched fused-gather
    dispatch per layer — no per-slot or per-head kernel launches;
  * inactive slots decode garbage into their own rows (masked out of
    results, overwritten at next admission) — the standard price of
    static shapes.

The engine is model-agnostic: any family with a decode path works
(GQA/MLA/hybrid; HATA on or off per config). Queue, sampling and the
unified retirement path live in :class:`~repro.serving.base.EngineBase`;
only the slab admission + the max_len wall are local here.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.serving.base import EngineBase


class ServingEngine(EngineBase):
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_len: int = 256, sample: str = "greedy",
                 seed: int = 0, budget_table=None):
        super().__init__(model, params, max_batch=max_batch,
                         sample=sample, seed=seed,
                         budget_table=budget_table)
        self.max_len = max_len
        cfg = model.cfg
        self.meta = cfg.meta_tokens
        self.caches = model.init_caches(max_batch, max_len,
                                        layout="list")
        self.last_tok = np.zeros(
            (max_batch, cfg.audio.n_codebooks) if cfg.family == "audio"
            else (max_batch,), np.int32)

        # pos is the per-slot (B,) depth vector, not one shared scalar:
        # decode_step threads it through to hata_decode_batched's
        # per-row validity masks so ragged slots stay exact.
        self._decode = self._with_table(jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos)))
        self._prefill = self._with_table(jax.jit(
            lambda p, b, c: model.prefill(p, b, c, jnp.int32(0))))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _insert_impl(self, caches, single, slot):
        """Copy a B=1 cache tree into slot ``slot`` of the engine cache."""
        def ins(dst, src):
            idx = (slot,) + (0,) * (dst.ndim - 1)
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), idx)
        return jax.tree.map(ins, caches, single)

    def _admit(self):
        while self.queue and None in self.slots:
            req = self.queue.popleft()
            if req.prompt_len > self.max_len:
                # the prompt alone overflows the cache — truncate at
                # admission (prefilling it would be a shape error)
                self._finish(req, truncated=True)
                continue
            slot = self.slots.index(None)
            req.slot = slot
            single = self.model.init_caches(1, self.max_len,
                                            layout="list")
            batch = {"tokens": jnp.asarray(req.prompt[None])}
            logits, single = self._prefill(self.params, batch, single)
            self.caches = self._insert(self.caches, single,
                                       jnp.int32(slot))
            tok = self._pick(logits, [req])[0]
            req.output.append(self._to_py(tok))
            req.t_first_token = time.monotonic()
            self.stats["prefills"] += 1
            self.stats["tokens_out"] += 1
            if req.done:
                # a zero/one-new-token request retires at admission —
                # same rule as the paged engine's _finish_prefill
                self._finish(req)
                continue
            self.last_tok[slot] = np.asarray(tok)
            self.pos[slot] = req.prompt_len + self.meta
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def _advance(self):
        """Truncate out-of-cache slots, then run one decode wave."""
        # out-of-cache: a slot whose next decode would write at or past
        # max_len is terminated NOW with an explicit ``truncated`` flag
        # and its slot freed — decoding on would clamp the cache append
        # onto the last row and emit garbage tokens.
        for slot, req in enumerate(self.slots):
            if req is not None and \
                    self.pos[slot] >= self.max_len + self.meta:
                self._finish(req, truncated=True)
                self.slots[slot] = None
        active = [s is not None for s in self.slots]
        if not any(active):
            return
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.last_tok), self.caches,
            jnp.asarray(self.pos))
        toks = self._pick(logits, self.slots)
        self.stats["decode_steps"] += 1
        toks_np = np.asarray(toks)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[slot] += 1
            req.output.append(self._to_py(toks_np[slot]))
            self.last_tok[slot] = toks_np[slot]
            self.stats["tokens_out"] += 1
            if req.done:
                self._finish(req)
                self.slots[slot] = None
