from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import PagedServingEngine

__all__ = ["ServingEngine", "Request", "PagedServingEngine"]
