from repro.serving.base import EngineBase
from repro.serving.engine import ServingEngine
from repro.serving.plane import (ADMIT, DEFER, TRUNCATE,
                                 AdmissionController, DecodeWorker,
                                 PageShipper, PoolGroup, PrefillTask,
                                 PrefillWorker, Transfer, Wave,
                                 donation_overlaps, make_pool_group)
from repro.serving.request import Request
from repro.serving.scheduler import PagedServingEngine
from repro.serving.speculative import (BudgetDraft, ConstantDraft,
                                       DraftSource, LayerSubsetDraft,
                                       SpeculationController, SpecWave,
                                       rollback_slot)

__all__ = ["EngineBase", "ServingEngine", "Request",
           "PagedServingEngine", "AdmissionController", "DecodeWorker",
           "PrefillWorker", "PrefillTask", "PoolGroup", "Transfer",
           "PageShipper", "Wave", "make_pool_group",
           "donation_overlaps",
           "DraftSource", "BudgetDraft", "LayerSubsetDraft",
           "ConstantDraft", "SpeculationController", "SpecWave",
           "rollback_slot",
           "ADMIT", "DEFER", "TRUNCATE"]
