from repro.serving.base import EngineBase
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import PagedServingEngine

__all__ = ["EngineBase", "ServingEngine", "Request",
           "PagedServingEngine"]
