from repro.serving.engine import ServingEngine
from repro.serving.request import Request

__all__ = ["ServingEngine", "Request"]
