"""Request lifecycle for the serving engine."""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) or (S, n_codebooks) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # lifecycle
    output: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    t_submit: float = dataclasses.field(default_factory=time.monotonic)
    # stamped by AdmissionController.select the moment the request
    # leaves the queue (admitted OR truncated) — queueing delay is
    # t_admitted - t_submit, measured in exactly one place
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    # one wall-clock stamp per emitted token, appended by
    # EngineBase._record_token (the one token-emission path) — TTFT is
    # t_tokens[0] - t_submit, ITL percentiles come from np.diff(t_tokens)
    t_tokens: List[float] = dataclasses.field(default_factory=list)
    t_done: Optional[float] = None
    # terminated early because the engine ran out of cache capacity
    # (dense engine: the max_len wall; paged engine: the pool itself
    # can't fit the request even after eviction + preemption)
    truncated: bool = False
    # times this request was evicted mid-flight by the paged scheduler
    # (resume replays its tokens identically — greedy trivially, sampled
    # via the engine's per-request (id, step) RNG streams)
    preemptions: int = 0
    # per-request speculative telemetry, filled by
    # EngineBase._apply_spec_wave ({} on non-speculative engines):
    #   spec_rounds   — verify waves this request rode
    #   spec_drafted  — draft tokens proposed for it (depth per round)
    #   spec_accepted — tokens it emitted from those waves (accepted
    #                   draft prefix + the verify wave's own pick)
    # invariant: len(output) == stats["spec_accepted"] + 1 — every
    # output token except the admission-prefill pick came from a
    # speculative wave (resume prefills replay, they never re-record)
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def done(self) -> bool:
        if self.t_done is not None:
            return True
        if len(self.output) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.output
                and self.output[-1] == self.eos_id)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])
