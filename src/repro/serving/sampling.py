"""Next-token picking with per-request RNG streams.

Shared by the dense ``ServingEngine`` and the ``PagedServingEngine`` so
the sampled-replay contract lives in exactly one place: a sampled row
draws from ``fold_in(fold_in(base_key, req.id), step)`` with
``step = tokens already emitted``. Consequences:

  * no randomness is ever consumed for empty/inactive slots, so a
    request's tokens are a pure function of (seed, id, step) —
    independent of co-scheduled traffic and engine history;
  * a preempted request's replay regenerates the exact keys at the
    exact steps, so sampled preemption replay is bit-exact
    (tests/test_prefill_kernels.py);
  * the pick can be FUSED into the decode-step jit
    (:func:`pick_tokens_device`): ids/steps enter as arrays, so the
    wave's next tokens never leave the device between waves — the
    serving plane's async tick feeds wave *n*'s device token handle
    straight into wave *n+1* without a host round-trip.

The eager entry point (:func:`pick_tokens`, used for prefill logits at
admission) is one jitted call per wave — deriving keys eagerly per slot
would put O(B) host dispatches on the decode hot path.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.serving.request import Request


def _categorical_rows_impl(base_key, ids, steps, logits):
    def one(req_id, step, row):
        key = jax.random.fold_in(jax.random.fold_in(base_key, req_id),
                                 step)
        return jax.random.categorical(key, row, axis=-1)
    return jax.vmap(one)(ids, steps, logits).astype(jnp.int32)


_categorical_rows = jax.jit(_categorical_rows_impl)


def pick_tokens_device(base_key, logits, ids, steps,
                       sample: str) -> jax.Array:
    """Jit-safe pick: ``ids``/``steps`` are (B,) int32 arrays.

    Called *inside* the workers' decode-step jits (plane.py) so wave
    tokens stay device-resident; identical math to :func:`pick_tokens`
    — greedy argmax or the per-row (id, step) categorical streams.
    """
    if sample == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return _categorical_rows_impl(base_key, ids, steps, logits)


def pick_tokens(base_key, logits, reqs: List[Optional[Request]],
                sample: str) -> jax.Array:
    """Pick one token per logits row; ``reqs`` aligns a Request (or
    None for inactive/garbage rows) with every row. Greedy is RNG-free;
    inactive rows reuse the (0, 0) stream — their draw is discarded and
    never shifts a live row's stream."""
    if sample == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    ids = jnp.asarray([0 if r is None else r.id for r in reqs],
                      jnp.int32)
    steps = jnp.asarray([0 if r is None else len(r.output)
                         for r in reqs], jnp.int32)
    return _categorical_rows(base_key, ids, steps, logits)
