"""Paged serving scheduler: continuous batching on pages.

The dense :class:`~repro.serving.engine.ServingEngine` allocates one
``max_batch x max_len`` cache, prefills each admitted prompt in a single
blocking B=1 call, and kills requests at the ``max_len`` wall. This
engine replaces all three with the paged subsystem
(``core/paged_cache.py`` + the block-table kernels behind
``core.cache_view.PagedView``):

  * **one shared page pool per layer** — a request holds exactly
    ``ceil(rows / page_size)`` pages, so memory scales with live tokens,
    not with ``max_batch * max_len``;
  * **chunked prefill** — prompts prefill in fixed-size chunks
    interleaved with decode waves, so a long prompt never blocks the
    running requests for more than one chunk; ``ctx`` is traced, so one
    compiled chunk shape serves every prompt;
  * **prefix sharing** — full prompt-prefix pages are published to a
    hash-of-prefix cache (refcounted, immutable by construction); a hit
    adopts the donor's pages and skips their prefill compute;
  * **admission by free-page watermark** — a prompt is admitted only
    when its prefill fits above the watermark, keeping slack for the
    running requests' decode growth; ``lookahead > 0`` lets a small
    admissible prompt bypass an oversized head-of-line one (first-fit
    within the window, FCFS otherwise — serving/plane.py);
  * **preemption by eviction** — when the pool runs dry mid-flight the
    youngest running request is evicted (pages freed, request requeued)
    after the prefix cache has been squeezed first; replay is exact for
    greedy *and* sampled decoding (every request draws from its own
    persisted (id, step) RNG stream — see ``EngineBase._pick``);
  * **growth past max_len** — decode appends pages on demand; a request
    is only ``truncated`` when the *pool itself* can't be made to fit
    it, or when it outgrows the per-request logical capacity
    ``max_len_pages`` (the block-table width — defaults to the whole
    pool; pass ``max_len // page_size`` to reproduce the dense engine's
    budget semantics exactly, since the static HATA budget derives from
    ``table_pages * page_size`` the way the dense one derives from
    ``max_len``).

Serving-plane configurations (serving/plane.py, DESIGN.md §8) — all of
them drive the SAME admission/preemption policy above:

  * **colocated synchronous** (default): one :class:`PoolGroup`, the
    identity :class:`~repro.serving.plane.Transfer`, one wave per tick
    — bit-exact with the pre-plane engine;
  * **async double-buffered waves** (``async_waves=True``): each tick
    launches wave *n+1* (fed wave *n*'s device-resident fused-pick
    tokens) before blocking on wave *n*; host work overlaps device
    execution, and the drain rule (harvest the in-flight wave before
    any preemption/eviction of a live slot or wall truncation) plus
    per-request RNG streams keep outputs bit-exact vs synchronous;
  * **disaggregated** (``disaggregate=True``): prefill and decode own
    separate pools/allocators (optionally separate devices + their own
    params replica); a finished prefill's pages cross the
    :class:`~repro.serving.plane.PageShipper` boundary — decode-side
    ids allocated through the decode allocator, bytes copied
    pool-to-pool — and prefill-side pages are released (the prefill
    side's prefix cache keeps its refs, so sharing still skips
    prefill compute);
  * **sharded-pool** (``mesh=``): page axis + block-table columns
    sharded together over the mesh's sequence axis,
    :class:`~repro.core.paged_cache.ShardedPageAllocator` keeping
    column c's page on c's shard, decode waves routed through
    ``SPDecode(global_page_ids=True)`` sequence-parallel attention.

The model is driven through the serving-plane workers: each worker jit
wraps the per-layer pools + the shared block table in
``core.cache_view.paged_view`` and calls the same ``Model.decode_step``
/ ``Model.prefill_chunk`` the dense stack uses — there is no paged
twin of the model surface, and the workers are the ONLY call sites
(CI-guarded). Queue, sampling, token emission and the unified
retirement path come from :class:`~repro.serving.base.EngineBase`;
everything local here is page accounting (admission watermark, prefix
adoption, preemption, truncation walls).

Differential guarantee (tests/test_paged.py, tests/test_serving_plane.py):
greedy outputs equal the offline/dense engine's per request;
prefix-shared prefills produce the same logits as cold ones; every
plane configuration above emits byte-identical outputs to colocated
synchronous.
"""
from __future__ import annotations

import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import runtime
from repro.models import Model
from repro.serving import plane
from repro.serving import speculative as spec_mod
from repro.serving.base import EngineBase
from repro.serving.plane import (ADMIT, DEFER, TRUNCATE, PoolGroup,
                                 PrefillTask, Wave)
from repro.serving.request import Request


class PagedServingEngine(EngineBase):
    """Continuous batching over a paged KV+code cache."""

    def __init__(self, model: Model, params, *, num_pages: int = 64,
                 page_size: Optional[int] = None, max_batch: int = 4,
                 max_len_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 watermark_pages: int = 0, prefix_sharing: bool = True,
                 sample: str = "greedy", seed: int = 0,
                 strict_moe_capacity: bool = False,
                 offload: bool = False,
                 hbm_budget_bytes: Optional[int] = None,
                 budget_table=None, lookahead: int = 0,
                 async_waves: bool = False, on_token=None,
                 disaggregate: bool = False,
                 prefill_pages: Optional[int] = None,
                 prefill_device=None, decode_device=None,
                 mesh=None, seq_axis: str = "model",
                 sp_mode: str = "two_stage",
                 speculate: Optional[
                     spec_mod.SpeculationController] = None):
        assert model.supports_paged, (
            f"{model.cfg.name}: family {model.cfg.family!r} has no paged "
            "decode path (attention-KV families only)")
        # offload waves are eager and host-mediated; they are neither
        # shippable across pools nor shardable over a mesh
        assert not (offload and disaggregate), \
            "offload engines are colocated (host tier IS the far pool)"
        assert not (offload and mesh is not None), \
            "offload + sharded pools is not supported"
        assert not (disaggregate and mesh is not None), \
            "disaggregate a replicated engine or shard a colocated one"
        # sharded pools localize ids inside shard_map around the
        # single-row decode append; the verify chunk's per-row scatter
        # has no sharded lowering yet
        assert not (speculate is not None and mesh is not None), \
            "speculative rounds are not supported over sharded pools"
        e = model.cfg.moe
        if e is not None and e.capacity_factor * e.top_k < e.n_experts:
            # Chunked prefill routes experts per chunk-sized group while
            # monolithic prefill groups over the whole prompt; when
            # expert capacity binds the two drop *different* tokens, so
            # paged logits silently diverge from the dense engine's.
            # Dropless capacity (capacity_factor >= E / top_k, the
            # serving setting) makes capacity a no-op and restores
            # chunked == monolithic.
            msg = (f"{model.cfg.name}: MoE capacity_factor="
                   f"{e.capacity_factor} < n_experts/top_k="
                   f"{e.n_experts / e.top_k:.2f} — expert capacity can "
                   "bind, and chunked prefill then drops different "
                   "tokens than monolithic prefill (logits diverge "
                   "from the dense engine). Serve with "
                   "capacity_factor >= n_experts/top_k; "
                   "strict_moe_capacity=True turns this into an error.")
            if strict_moe_capacity:
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=2)
        super().__init__(model, params, max_batch=max_batch,
                         sample=sample, seed=seed,
                         budget_table=budget_table, lookahead=lookahead,
                         async_waves=async_waves, on_token=on_token,
                         speculate=speculate)
        # page_size=None consults the tuning table (REPRO_PAGE_SIZE /
        # REPRO_TUNING_TABLE win): every paged kernel tiles kv at the
        # pool page size, so pool construction is their block-size
        # decision — the tpu table entry carries the >=128-row pages
        # the MXU wants, CPU keeps 8-row test-scale pages.
        page_size = runtime.pool_page_size(page_size)
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk or 2 * page_size
        self.watermark = watermark_pages
        self.offload = offload
        self.mesh = mesh
        self.num_pages = num_pages

        # Per-request logical capacity = block-table width, decoupled
        # from the pool: the paged score grid, the dense-path logical
        # view and the (static) HATA budget all scale with
        # table_pages * page_size. Default: the whole pool. Sharded
        # pools round the width UP to a multiple of the shard count
        # (columns are sharded with the page axis).
        table_pages = min(max_len_pages or num_pages, num_pages)
        if mesh is not None:
            n_sh = int(mesh.shape[seq_axis])
            table_pages = min(-(-table_pages // n_sh) * n_sh, num_pages)
        self.table_pages = table_pages

        # --- pool groups + transfer boundary -------------------------
        strat = None
        if mesh is not None:
            from repro.distributed.decode import SPDecode
            strat = SPDecode(mesh, seq_axes=(seq_axis,), mode=sp_mode,
                             global_page_ids=True)
        self.decode_group = plane.make_pool_group(
            model, num_pages=num_pages, page_size=page_size,
            table_pages=table_pages, offload=offload,
            prefix_sharing=prefix_sharing and not disaggregate,
            mesh=mesh, seq_axis=seq_axis, device=decode_device)
        if disaggregate:
            self.prefill_group = plane.make_pool_group(
                model, num_pages=prefill_pages or num_pages,
                page_size=page_size, table_pages=table_pages,
                prefix_sharing=prefix_sharing, device=prefill_device)
            self.transfer = plane.PageShipper(self.prefill_group,
                                              self.decode_group)
            # each side holds its own params replica when split across
            # devices (that's the point of disaggregation: prefill
            # compute never contends with decode compute or memory)
            self._prefill_params = (
                jax.device_put(params, prefill_device)
                if prefill_device is not None else params)
            self._decode_params = (
                jax.device_put(params, decode_device)
                if decode_device is not None else params)
        else:
            self.prefill_group = self.decode_group
            self.transfer = plane.Transfer()
            self._prefill_params = self._decode_params = params
        self._groups = ([self.decode_group] if not disaggregate
                        else [self.prefill_group, self.decode_group])

        # compat views (tests/benchmarks reach for these)
        self.alloc = self.decode_group.alloc
        self.prefix = self.prefill_group.prefix
        self.pipeline = self.decode_group.pipeline
        self.scratch = int(self.decode_group.scratch_cols[0])

        # a prompt that can never fit — per-request width, or either
        # pool minus its scratch reservation — is truncated AT ADMISSION
        self._hard_cap = min(
            [table_pages] +
            [g.alloc.num_pages - len(np.unique(g.scratch_cols))
             for g in self._groups])

        if offload:
            # Offload mode: HATA layers keep only hash codes in HBM; K/V
            # rows live in host page pools under the SAME allocator/
            # page-id space. Admission is watermarked against the
            # HBM-RESIDENT budget: a page's host rows are cheap but its
            # device codes are not, so the number of pages whose
            # resident share fits the budget caps the usable pool.
            self.stats.update({"bytes_pcie": 0,
                               "hbm_resident_bytes":
                               self.hbm_resident_bytes()})
            if hbm_budget_bytes is not None:
                per_page = max(1, self.hbm_resident_bytes() // num_pages)
                hbm_pages = int(hbm_budget_bytes // per_page)
                self.watermark = max(self.watermark,
                                     num_pages - min(hbm_pages,
                                                     num_pages))

        # --- workers -------------------------------------------------
        # Some PJRT clients block dispatch when a donated input is
        # still pending, which would serialize async wave n+1 behind
        # wave n — keep donation (in-place pool scatters) everywhere
        # except async waves on a client the measured probe
        # (plane.donation_overlaps) says blocks; there a pool copy per
        # wave is the price of real overlap. The probe replaces the old
        # backend-NAME check, which misclassified any client the list
        # didn't know about.
        donate = (not async_waves) or plane.donation_overlaps()
        self.decode = plane.paged_decode_worker(
            model, self.decode_group, sample=sample,
            base_key=self._base_key, wrap=self._with_table,
            offload=offload, strat=strat, donate=donate,
            speculate=speculate)
        self.prefill = plane.paged_prefill_worker(
            model, self.prefill_group, chunk_size=self.prefill_chunk,
            wrap=self._with_table, offload=offload,
            strat=None if mesh is None else strat)
        # compat aliases (compile-cache assertions poke these)
        self._decode = self.decode.step
        self._chunk = self.prefill.chunk

        self.bt = np.tile(self.decode_group.scratch_cols[None],
                          (max_batch, 1))
        self._slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self._slot_order: List[int] = []      # admission order (slot ids)
        # device-resident token feed: wave n's fused-pick output is
        # wave n+1's input (no host round-trip); _finish_prefill patches
        # its slot in at the handle level
        self._tok_feed = jnp.zeros(max_batch, jnp.int32)
        self.stats.update({"prefill_chunks": 0, "preemptions": 0,
                           "prefix_hit_tokens": 0, "peak_pages": 1})
        if self.transfer.remote:
            self.stats["pages_shipped"] = 0

    # ------------------------------------------------------------------
    @property
    def pools(self):
        return self.decode_group.pools

    def hbm_resident_bytes(self) -> int:
        """Device bytes pinned by the cache tier right now: full pools
        for resident layers, codes + staged waves for offloaded ones."""
        total = 0
        for pool in self.pools:
            if hasattr(pool, "hbm_resident_bytes"):
                total += pool.hbm_resident_bytes()
            else:
                total += sum(leaf.nbytes
                             for leaf in jax.tree.leaves(pool))
        if self.pipeline is not None:
            total += self.pipeline.device_staged_bytes()
        return total

    # ------------------------------------------------------------------
    def _note_usage(self):
        used = sum(g.used_count() for g in self._groups)
        self.stats["peak_pages"] = max(self.stats["peak_pages"], used)

    # ------------------------------------------------------------------
    # page acquisition: evict prefix cache, drain, preempt, give up
    # ------------------------------------------------------------------
    def _acquire(self, group: PoolGroup, cols: List[int],
                 protect_slot: int = -1) -> Optional[List[int]]:
        """Allocate one page per block-table column from ``group``
        (shard-routed when its pool is sharded). Pressure ladder:
        squeeze the group's prefix cache, then — decode side only —
        drain the in-flight wave (retirement may free pages) and
        preempt the youngest running request. A disaggregated prefill
        group has no victims to preempt: exhaustion there means
        truncation, same as a pool that can't fit a prompt alone."""
        drained = False
        while True:
            pages = group.alloc_cols(cols)
            if pages is not None:
                self._note_usage()
                return pages
            short = len(cols) - group.free_count()
            if group.prefix is not None and \
                    group.prefix.evict(max(short, 1)):
                continue
            if group is self.decode_group:
                if not drained and self.decode.busy:
                    drained = True
                    self._drain()        # retirements may free pages
                    continue
                if self._preempt_one(protect_slot):
                    continue
            return None

    def _acquire_gentle(self, group: PoolGroup,
                        cols: List[int]) -> Optional[List[int]]:
        """Best-effort allocation for speculative LOOKAHEAD coverage:
        squeeze the prefix cache, nothing else — draining a wave or
        preempting a live request to fund rows a rejected draft may
        never commit would trade real work for a gamble. Callers fall
        back to the bare next-row need through the full ladder."""
        while True:
            pages = group.alloc_cols(cols)
            if pages is not None:
                self._note_usage()
                return pages
            short = len(cols) - group.free_count()
            if group.prefix is not None and \
                    group.prefix.evict(max(short, 1)):
                continue
            return None

    def _preempt_one(self, protect_slot: int) -> bool:
        """Evict the youngest running request (LIFO keeps the oldest
        requests' latency bounds intact) and requeue it for a resumed
        prefill. Replay emits the identical tokens under greedy and
        sampled decoding alike (per-request RNG streams); the caller
        has already drained any in-flight wave, so the victim's last
        token has landed and resume replay matches synchronous."""
        assert not self.decode.busy, "preempting with a wave in flight"
        victims = [s for s in reversed(self._slot_order)
                   if s != protect_slot and self.slots[s] is not None]
        if not victims:
            return False
        slot = victims[0]
        req = self.slots[slot]
        self._free_slot(slot)
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.admission.requeue(req)
        return True

    def _free_slot(self, slot: int):
        """Tear a slot down: page release + block-table parking +
        position rewind go through the ONE rollback helper
        (``speculative.rollback_slot`` at rows=0 IS the full teardown —
        CI grep-guards the raw idioms); ordering/identity state is
        cleared here."""
        spec_mod.rollback_slot(self, slot, 0)
        self._ids[slot] = 0
        self._steps[slot] = 0
        self.slots[slot] = None
        if slot in self._slot_order:
            self._slot_order.remove(slot)

    # ------------------------------------------------------------------
    # admission + chunked prefill
    # ------------------------------------------------------------------
    def _pages_for(self, rows: int) -> int:
        return -(-rows // self.page_size)

    def _resume_tokens(self, req: Request) -> np.ndarray:
        """Prefill token stream: resumed requests replay prompt +
        emitted tokens (minus the last, which becomes the feed of the
        next decode step)."""
        if req.output:
            return np.concatenate([
                np.asarray(req.prompt, np.int32),
                np.asarray(req.output[:-1], np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _probe(self, req: Request) -> str:
        """Admission verdict — side-effect free (a DEFERred request is
        re-probed every tick and must not churn refcounts / LRU / hit
        stats, hence ``peek``)."""
        if self._pages_for(req.prompt_len) > self._hard_cap:
            # prefilling it to the wall first would burn chunks across
            # all layers and possibly preempt live requests for nothing
            return TRUNCATE
        tokens = self._resume_tokens(req)
        n_hit = (self.prefill_group.prefix.peek(tokens)
                 if self.prefill_group.prefix is not None else 0)
        need = self._pages_for(len(tokens)) - n_hit
        if self.prefill_group.free_count() - need < self.watermark \
                and len(self.slots) - self.slots.count(None) > 0:
            return DEFER               # pool too tight while others run
        return ADMIT

    def _admit(self):
        """Start prefilling the next admissible queued request (within
        the lookahead window) if a slot is free."""
        if self.prefill.busy or None not in self.slots:
            return
        sel = self.admission.select(self._probe)
        if sel is None:
            return
        req, verdict = sel
        if verdict == TRUNCATE:
            self._finish(req, truncated=True)
            return
        tokens = self._resume_tokens(req)
        prefix_pages: List[int] = []
        if self.prefill_group.prefix is not None:
            prefix_pages = self.prefill_group.prefix.lookup(tokens)
        ctx = len(prefix_pages) * self.page_size
        self.stats["prefix_hit_tokens"] += ctx
        self.prefill.inflight = PrefillTask(
            req=req, tokens=tokens, ctx=ctx, pages=prefix_pages,
            resume=len(req.output) > 0)

    def _prefill_step(self):
        """Run one chunk of the in-flight prefill (if any)."""
        st = self.prefill.inflight
        if st is None:
            return
        n_tok = len(st.tokens)
        end = min(st.ctx + self.prefill_chunk, n_tok)
        if self._pages_for(end) > self.table_pages:
            # past the per-request logical capacity (block-table width)
            self._finish_truncated(st.req, st.pages, self.prefill_group)
            self.prefill.inflight = None
            return
        need = self._pages_for(end) - len(st.pages)
        if need > 0:
            cols = list(range(len(st.pages), len(st.pages) + need))
            got = self._acquire(self.prefill_group, cols)
            if got is None:
                # the pool can't hold even this prompt alone: truncate
                self._finish_truncated(st.req, st.pages,
                                       self.prefill_group)
                self.prefill.inflight = None
                return
            st.pages.extend(got)
        bt_row = self.prefill_group.scratch_cols[None].copy()
        bt_row[0, :len(st.pages)] = st.pages
        chunk = np.zeros(self.prefill_chunk, np.int32)
        chunk[:end - st.ctx] = st.tokens[st.ctx:end]
        logits, self.prefill_group.pools = self.prefill.chunk(
            self._prefill_params, jnp.asarray(chunk[None]),
            self.prefill_group.pools, jnp.asarray(bt_row),
            jnp.int32(st.ctx), jnp.int32(end - st.ctx - 1))
        self.stats["prefill_chunks"] += 1
        st.ctx = end
        if end == n_tok:
            self._finish_prefill(st, logits)
            self.prefill.inflight = None

    def _finish_prefill(self, st: PrefillTask, logits):
        req = st.req
        slot = self.slots.index(None)
        req.slot = slot
        if st.resume:
            # the re-run's "first token" repeats an already-emitted one
            tok = int(req.output[-1])
        else:
            tok = self._to_py(self._pick(logits, [req])[0])
            self._record_token(req, tok)
        self.stats["prefills"] += 1
        # register with the PREFILL side's prefix cache before the
        # pages cross the transfer boundary: disaggregated prefix hits
        # must keep skipping prefill compute
        if self.prefill_group.prefix is not None:
            self.prefill_group.prefix.register(
                np.asarray(req.prompt, np.int32), st.pages)
        pages = self.transfer.ship(self, st.pages)
        if self.transfer.remote:
            # decode side now owns its copies; the prefix cache keeps
            # its own refs on the prefill side
            self.prefill_group.alloc.release(st.pages)
        if pages is None:
            # decode pool can't take the request even after eviction +
            # preemption — same terminal rule as an unfittable prompt
            self._finish(req, truncated=True)
            return
        # the slot's table row is guaranteed fully parked here (initial
        # tile, or the teardown that freed it) — only the owned columns
        # need patching
        self.pos[slot] = len(st.tokens)
        self.bt[slot, :len(pages)] = pages
        self._slot_pages[slot] = list(pages)
        self._ids[slot] = req.id
        self._steps[slot] = len(req.output)
        self._tok_feed = self._tok_feed.at[slot].set(tok)
        self.slots[slot] = req
        self._slot_order.append(slot)
        # a zero-new-token request is already done
        if req.done:
            self._retire(slot, req)

    def _finish_truncated(self, req: Request, pages: List[int],
                          group: Optional[PoolGroup] = None):
        (group or self.decode_group).alloc.release(pages)
        self._finish(req, truncated=True)

    # ------------------------------------------------------------------
    # decode waves
    # ------------------------------------------------------------------
    def _ensure_decode_pages(self) -> List[int]:
        """Grow each active slot's block table to cover the next row the
        wave ABOUT TO LAUNCH will write; slots the pool cannot serve are
        truncated (in-flight wave drained first — the drain rule).
        Returns live slots."""
        live = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            rows = int(self.pos[slot]) + 1
            if self._pages_for(rows) > self.table_pages:
                self._drain()              # land the in-flight token
                if self.slots[slot] is not req:
                    continue               # retired at drain
                self._free_slot(slot)      # logical-capacity wall
                self._finish(req, truncated=True)
                continue
            base = len(self._slot_pages[slot])
            need = self._pages_for(rows) - base
            if need > 0:
                cols = list(range(base, base + need))
                got = self._acquire(self.decode_group, cols,
                                    protect_slot=slot)
                if self.slots[slot] is not req:
                    # the drain inside _acquire retired it; put any
                    # pages straight back
                    if got is not None:
                        self.decode_group.alloc.release(got)
                    continue
                if got is None:
                    self._free_slot(slot)
                    self._finish(req, truncated=True)
                    continue
                self.bt[slot, base:base + len(got)] = got
                self._slot_pages[slot].extend(got)
            live.append(slot)
        # _acquire may have preempted/retired a slot collected above
        return [s for s in live if self.slots[s] is not None]

    def _drain(self):
        if self.spec is not None:
            self._apply_spec_wave(self.decode.take())
        else:
            self._apply_wave(self.decode.take())

    def _launch_wave(self) -> Optional[Wave]:
        """Grow tables, then launch the next wave; returns the PREVIOUS
        in-flight wave (taken but not yet applied) so the caller
        harvests it AFTER the new launch. The take happens after
        ``_ensure_decode_pages`` — drains triggered by walls/preemption
        in there must still see the wave in the worker."""
        live = self._ensure_decode_pages()
        prev = self.decode.take()
        if not live:
            return prev
        snapshot = list(self.slots)
        # .copy(): device_put of a host array may alias its buffer
        # zero-copy, and bt/pos/_steps are mutated (growth, walls,
        # admission) while the wave is still in flight — the wave must
        # read the launch-time values
        toks, self.decode_group.pools = self.decode.step(
            self._decode_params, self._tok_feed,
            self.decode_group.pools, jnp.asarray(self.bt.copy()),
            jnp.asarray(self.pos.copy()), jnp.asarray(self._ids.copy()),
            jnp.asarray(self._steps.copy()))
        self._tok_feed = toks
        self.stats["decode_steps"] += 1
        for slot, req in enumerate(snapshot):
            if req is not None:
                # pos/_steps count the LAUNCHED wave: pos = rows
                # written including in flight, _steps = stream index
                # of the next token to pick
                self.pos[slot] += 1
                self._steps[slot] += 1
        self.decode.put(Wave(toks=toks, reqs=snapshot))
        return prev

    def _apply_wave(self, wave: Optional[Wave]):
        """Harvest one wave against its launch-time snapshot; slots
        that retired or turned over since launch (preemption, wall)
        discard their speculative token."""
        if wave is None:
            return
        toks_np = np.asarray(wave.toks)       # blocks on the device
        for slot, req in enumerate(wave.reqs):
            if req is None or req.done or self.slots[slot] is not req:
                continue
            self._record_token(req, self._to_py(toks_np[slot]))
            if req.done:
                self._retire(slot, req)

    def _retire(self, slot: int, req: Request):
        self._free_slot(slot)
        self._finish(req)

    # ------------------------------------------------------------------
    # speculative rounds (self.spec set; round fn built by
    # plane.paged_decode_worker, math in serving/speculative.py)
    # ------------------------------------------------------------------
    def _ensure_spec_pages(self):
        """Settled-position wall checks + page coverage for the round
        about to launch. A slot at its logical-capacity wall is
        truncated (drain rule applies, as in ``_ensure_decode_pages``).
        Coverage is acquired in two tiers: the full lookahead
        (pos + depth + 1 rows) GENTLY — prefix-cache eviction only,
        never preemption — then, if that fails, the bare next row
        (pos + 1) through the full ladder. Acceptance clamps to the
        coverage actually obtained (rows past it resolve to parked
        scratch columns that several slots share, so their verify
        logits are garbage — the clamp is what keeps partial coverage
        EXACT rather than approximate). Returns (live slots, per-slot
        covered rows)."""
        depth = self.spec.depth
        cap_rows = self.table_pages * self.page_size
        cov = self.pos.astype(np.int32) + 1    # benign for empty slots
        live = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            rows = int(self.pos[slot]) + 1
            if self._pages_for(rows) > self.table_pages:
                self._drain()              # land the in-flight tokens
                if self.slots[slot] is not req:
                    continue               # retired at drain
                self._free_slot(slot)      # logical-capacity wall
                self._finish(req, truncated=True)
                continue
            want = min(rows + depth, cap_rows)
            base = len(self._slot_pages[slot])
            need = self._pages_for(want) - base
            got: Optional[List[int]] = []
            if need > 0:
                got = self._acquire_gentle(
                    self.decode_group,
                    list(range(base, base + need)))
                if got is None:
                    # bare minimum via the full ladder (may drain /
                    # preempt — same rules as a plain decode wave)
                    need = self._pages_for(rows) - base
                    got = [] if need <= 0 else self._acquire(
                        self.decode_group,
                        list(range(base, base + need)),
                        protect_slot=slot)
                    if self.slots[slot] is not req:
                        if got:
                            self.decode_group.alloc.release(got)
                        continue           # retired by a drain inside
                    if got is None:
                        self._free_slot(slot)
                        self._finish(req, truncated=True)
                        continue
            if got:
                self.bt[slot, base:base + len(got)] = got
                self._slot_pages[slot].extend(got)
            cov[slot] = min(len(self._slot_pages[slot]) * self.page_size,
                            cap_rows)
            live.append(slot)
        # _acquire may have preempted/retired a slot collected above
        live = [s for s in live if self.slots[s] is not None]
        return live, cov

    def _launch_spec_round(self) -> Optional[spec_mod.SpecWave]:
        """Speculative twin of ``_launch_wave``. Ordering is the crux:
        (1) settle the in-flight round IN PLACE — commit its acceptance
        into pos/_steps/pages WITHOUT taking it from the worker, so the
        wall checks and page planning in (2) see the truth while drains
        triggered inside the planning ladder can still find the wave to
        harvest; (3) take the previous round, launch the next against
        the settled mirrors, hand the taken round back for harvesting
        under the new round's device time. Unlike plain waves the
        mirrors do NOT advance at launch — how far a round moves each
        slot is its acceptance count, known only at settle."""
        if self.decode.inflight is not None:
            self._settle_spec(self.decode.inflight)
        live, cov = self._ensure_spec_pages()
        prev = self.decode.take()
        if not live:
            return prev
        snapshot = list(self.slots)
        pos0 = self.pos.copy()
        steps0 = self._steps.copy()
        feed, targets, acc, self.decode_group.pools = self.decode.step(
            self._decode_params, self._tok_feed,
            self.decode_group.pools, jnp.asarray(self.bt.copy()),
            jnp.asarray(pos0), jnp.asarray(self._ids.copy()),
            jnp.asarray(steps0), jnp.asarray(cov))
        self._tok_feed = feed
        self.stats["decode_steps"] += 1
        self.decode.put(spec_mod.SpecWave(
            toks=targets, acc=acc, reqs=snapshot,
            pos0=pos0, steps0=steps0))
        return prev

    # ------------------------------------------------------------------
    def _advance(self):
        """One engine tick: advance the in-flight prefill by a chunk,
        then one decode wave (async: launch wave n+1 before harvesting
        wave n, so the harvest's host work overlaps the device)."""
        self._prefill_step()
        if self.spec is not None:
            prev = self._launch_spec_round()
            self._apply_spec_wave(prev)    # round n (async overlap)
            if not self.async_waves:
                self._apply_spec_wave(self.decode.take())
        else:
            prev = self._launch_wave()
            self._apply_wave(prev)         # wave n (None in sync steady
            if not self.async_waves:       # state: applied last tick)
                self._apply_wave(self.decode.take())
        if self.transfer.remote:
            self.stats["pages_shipped"] = \
                self.transfer.stats["pages_shipped"]
        if self.pipeline is not None:
            self.stats["bytes_pcie"] = self.pipeline.bytes_pcie
            self.stats["hbm_resident_bytes"] = self.hbm_resident_bytes()
