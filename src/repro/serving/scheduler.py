"""Paged serving scheduler: continuous batching on pages.

The dense :class:`~repro.serving.engine.ServingEngine` allocates one
``max_batch x max_len`` cache, prefills each admitted prompt in a single
blocking B=1 call, and kills requests at the ``max_len`` wall. This
engine replaces all three with the paged subsystem
(``core/paged_cache.py`` + the block-table kernels behind
``core.cache_view.PagedView``):

  * **one shared page pool per layer** — a request holds exactly
    ``ceil(rows / page_size)`` pages, so memory scales with live tokens,
    not with ``max_batch * max_len``;
  * **chunked prefill** — prompts prefill in fixed-size chunks
    interleaved with decode waves, so a long prompt never blocks the
    running requests for more than one chunk; ``ctx`` is traced, so one
    compiled chunk shape serves every prompt;
  * **prefix sharing** — full prompt-prefix pages are published to a
    hash-of-prefix cache (refcounted, immutable by construction); a hit
    adopts the donor's pages and skips their prefill compute;
  * **admission by free-page watermark** — a prompt is admitted only
    when its prefill fits above the watermark, keeping slack for the
    running requests' decode growth;
  * **preemption by eviction** — when the pool runs dry mid-flight the
    youngest running request is evicted (pages freed, request requeued)
    after the prefix cache has been squeezed first; replay is exact for
    greedy *and* sampled decoding (every request draws from its own
    persisted (id, step) RNG stream — see ``EngineBase._pick``);
  * **growth past max_len** — decode appends pages on demand; a request
    is only ``truncated`` when the *pool itself* can't be made to fit
    it (dense engines truncate at a static wall), or when it outgrows
    the per-request logical capacity ``max_len_pages`` (the block-table
    width — defaults to the whole pool; pass
    ``max_len // page_size`` to reproduce the dense engine's budget
    semantics exactly, since the static HATA budget derives from
    ``table_pages * page_size`` the way the dense one derives from
    ``max_len``).

Slot model: decode waves still run at a static ``max_batch`` width (the
jit-friendly TPU pattern); inactive slots decode garbage into the
reserved *scratch page* (page 0), which no request ever owns, so they
can't corrupt live pages.

The model is driven through the view API: each jit'd wave wraps the
per-layer pools + the block table in ``core.cache_view.paged_view`` and
calls the same ``Model.decode_step`` / ``Model.prefill_chunk`` the
dense stack uses — there is no paged twin of the model surface. Queue,
sampling and the unified retirement path come from
:class:`~repro.serving.base.EngineBase`; everything local here is page
accounting (admission watermark, prefix adoption, preemption,
truncation walls).

Differential guarantee (tests/test_paged.py): greedy outputs equal the
offline/dense engine's per request; prefix-shared prefills produce the
same logits as cold ones.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache_view as cache_view_mod
from repro.core.paged_cache import PageAllocator, PrefixCache
from repro.kernels import runtime
from repro.models import Model
from repro.serving.base import EngineBase
from repro.serving.request import Request


@dataclasses.dataclass
class _PrefillState:
    """A request mid-prefill (chunked; possibly resumed after
    preemption)."""
    req: Request
    tokens: np.ndarray              # prompt (+ replayed output on resume)
    ctx: int                        # rows already in the cache
    pages: List[int]                # pages owned (incl. adopted prefix)
    resume: bool                    # True -> suppress the emitted token


class PagedServingEngine(EngineBase):
    """Continuous batching over a paged KV+code cache."""

    def __init__(self, model: Model, params, *, num_pages: int = 64,
                 page_size: Optional[int] = None, max_batch: int = 4,
                 max_len_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 watermark_pages: int = 0, prefix_sharing: bool = True,
                 sample: str = "greedy", seed: int = 0,
                 strict_moe_capacity: bool = False,
                 offload: bool = False,
                 hbm_budget_bytes: Optional[int] = None,
                 budget_table=None):
        assert model.supports_paged, (
            f"{model.cfg.name}: family {model.cfg.family!r} has no paged "
            "decode path (attention-KV families only)")
        e = model.cfg.moe
        if e is not None and e.capacity_factor * e.top_k < e.n_experts:
            # Chunked prefill routes experts per chunk-sized group while
            # monolithic prefill groups over the whole prompt; when
            # expert capacity binds the two drop *different* tokens, so
            # paged logits silently diverge from the dense engine's.
            # Dropless capacity (capacity_factor >= E / top_k, the
            # serving setting) makes capacity a no-op and restores
            # chunked == monolithic.
            msg = (f"{model.cfg.name}: MoE capacity_factor="
                   f"{e.capacity_factor} < n_experts/top_k="
                   f"{e.n_experts / e.top_k:.2f} — expert capacity can "
                   "bind, and chunked prefill then drops different "
                   "tokens than monolithic prefill (logits diverge "
                   "from the dense engine). Serve with "
                   "capacity_factor >= n_experts/top_k; "
                   "strict_moe_capacity=True turns this into an error.")
            if strict_moe_capacity:
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=2)
        super().__init__(model, params, max_batch=max_batch,
                         sample=sample, seed=seed,
                         budget_table=budget_table)
        # page_size=None consults the tuning table (REPRO_PAGE_SIZE /
        # REPRO_TUNING_TABLE win): every paged kernel tiles kv at the
        # pool page size, so pool construction is their block-size
        # decision — the tpu table entry carries the >=128-row pages
        # the MXU wants, CPU keeps 8-row test-scale pages.
        page_size = runtime.pool_page_size(page_size)
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk or 2 * page_size

        self.watermark = watermark_pages

        # Offload mode: HATA layers keep only hash codes in HBM; K/V
        # rows live in host page pools under the SAME allocator/page-id
        # space (prefix sharing, preemption and the scratch page apply
        # to host rows unchanged). The pool arithmetic below is
        # identical — only what a page *costs in HBM* changes, which is
        # what the watermark translation handles.
        self.offload = offload
        if offload:
            self.pools, self.pipeline = model.init_offloaded_pools(
                num_pages, page_size)
        else:
            self.pools = model.init_paged_pools(num_pages, page_size)
            self.pipeline = None
        self.alloc = PageAllocator(num_pages)
        # the scratch page: inactive decode slots write their garbage
        # rows here; never owned by a request, never scored as valid
        self.scratch = self.alloc.alloc(1)[0]
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(self.alloc, page_size) if prefix_sharing else None)

        self.num_pages = num_pages
        # Per-request logical capacity = block-table width, decoupled
        # from the pool: the paged score grid, the dense-path logical
        # view and the (static) HATA budget all scale with
        # table_pages * page_size, and the contiguous engine's budget
        # semantics are recovered by passing max_len_pages =
        # max_len // page_size. Default: the whole pool (one request
        # may grow into every free page).
        self.table_pages = min(max_len_pages or num_pages, num_pages)
        self.bt = np.full((max_batch, self.table_pages), self.scratch,
                          np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self._slot_order: List[int] = []      # admission order (slot ids)
        self.last_tok = np.zeros(max_batch, np.int32)
        self.prefilling: Optional[_PrefillState] = None
        self.stats.update({"prefill_chunks": 0, "preemptions": 0,
                           "prefix_hit_tokens": 0, "peak_pages": 1})
        if offload:
            self.stats.update({"bytes_pcie": 0,
                               "hbm_resident_bytes":
                               self.hbm_resident_bytes()})
            if hbm_budget_bytes is not None:
                # Admission is watermarked against the HBM-RESIDENT
                # budget: in offload mode a page's host rows are cheap
                # but its device codes are not, so the number of pages
                # whose resident share fits the budget caps the usable
                # pool — pages past that line are treated as below the
                # watermark and never admitted into.
                per_page = max(1, self.hbm_resident_bytes() // num_pages)
                hbm_pages = int(hbm_budget_bytes // per_page)
                self.watermark = max(self.watermark,
                                     num_pages - min(hbm_pages,
                                                     num_pages))

        # pools are donated: row scatters stay in place instead of
        # copying every pool per wave (a no-op warning on backends
        # without donation support, e.g. CPU tests). The views are
        # built inside the jit'd fn — one PagedView per layer around
        # the donated pool + the shared block table — and unwrapped on
        # the way out, so the engine's host state stays (pools, bt).
        def _decode_fn(p, t, pools, bt, pos):
            views = [cache_view_mod.paged_view(pool, bt)
                     for pool in pools]
            logits, views = model.decode_step(p, t, views, pos)
            return logits, [v.unwrap() for v in views]

        def _chunk_fn(p, t, pools, bt, ctx, last):
            views = [cache_view_mod.paged_view(pool, bt)
                     for pool in pools]
            logits, views = model.prefill_chunk(p, t, views, ctx, last)
            return logits, [v.unwrap() for v in views]

        if offload:
            # Offloaded waves cross the host boundary (numpy gathers,
            # the mutable PCIe ledger), so the SAME bodies run eagerly
            # — paged_view dispatches per pool type, resident dense
            # layers and offloaded HATA layers share one wave loop and
            # the per-op kernels still compile under their own jit.
            self._decode = self._with_table(_decode_fn)
            self._chunk = self._with_table(_chunk_fn)
        else:
            self._decode = self._with_table(
                jax.jit(_decode_fn, donate_argnums=(2,)))
            self._chunk = self._with_table(
                jax.jit(_chunk_fn, donate_argnums=(2,)))

    # ------------------------------------------------------------------
    def hbm_resident_bytes(self) -> int:
        """Device bytes pinned by the cache tier right now: full pools
        for resident layers, codes + staged waves for offloaded ones."""
        total = 0
        for pool in self.pools:
            if hasattr(pool, "hbm_resident_bytes"):
                total += pool.hbm_resident_bytes()
            else:
                total += sum(leaf.nbytes
                             for leaf in jax.tree.leaves(pool))
        if self.pipeline is not None:
            total += self.pipeline.device_staged_bytes()
        return total

    # ------------------------------------------------------------------
    def _note_usage(self):
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.alloc.used_count())

    # ------------------------------------------------------------------
    # page acquisition: evict prefix cache, then preempt, then give up
    # ------------------------------------------------------------------
    def _acquire(self, n: int, protect_slot: int = -1
                 ) -> Optional[List[int]]:
        while True:
            pages = self.alloc.alloc(n)
            if pages is not None:
                self._note_usage()
                return pages
            short = n - self.alloc.free_count()
            if self.prefix is not None and self.prefix.evict(short):
                continue
            if not self._preempt_one(protect_slot):
                return None

    def _preempt_one(self, protect_slot: int) -> bool:
        """Evict the youngest running request (LIFO keeps the oldest
        requests' latency bounds intact) and requeue it for a resumed
        prefill. Replay emits the identical tokens under greedy and
        sampled decoding alike (per-request RNG streams)."""
        victims = [s for s in reversed(self._slot_order)
                   if s != protect_slot and self.slots[s] is not None]
        if not victims:
            return False
        slot = victims[0]
        req = self.slots[slot]
        self._free_slot(slot)
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.queue.appendleft(req)
        return True

    def _free_slot(self, slot: int):
        """Tear a slot down: release its pages, park its block table on
        the scratch page, clear ordering state."""
        self.alloc.release(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self.bt[slot] = self.scratch
        self.pos[slot] = 0
        self.slots[slot] = None
        if slot in self._slot_order:
            self._slot_order.remove(slot)

    # ------------------------------------------------------------------
    # admission + chunked prefill
    # ------------------------------------------------------------------
    def _pages_for(self, rows: int) -> int:
        return -(-rows // self.page_size)

    def _admit(self):
        """Start prefilling the next queued request if a slot is free
        and its prompt fits above the free-page watermark."""
        if self.prefilling is not None or not self.queue:
            return
        if None not in self.slots:
            return
        req = self.queue[0]
        # a prompt that can never fit the per-request logical capacity
        # (block-table width) or the pool is truncated AT ADMISSION —
        # prefilling it to the wall first would burn chunks across all
        # layers and possibly preempt live requests for nothing
        if self._pages_for(req.prompt_len) > min(self.table_pages,
                                                 self.num_pages - 1):
            self.queue.popleft()
            self._finish_truncated(req, [])
            return
        resume = len(req.output) > 0
        # resumed requests replay prompt + emitted tokens (minus the
        # last, which becomes last_tok of the next decode step)
        tokens = np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.output[:-1], np.int32)]) if resume \
            else np.asarray(req.prompt, np.int32)
        # watermark check with a side-effect-free probe: a request that
        # keeps waiting here must not churn refcounts / LRU / hit stats
        n_hit = self.prefix.peek(tokens) if self.prefix is not None else 0
        need = self._pages_for(len(tokens)) - n_hit
        if self.alloc.free_count() - need < self.watermark \
                and len(self.slots) - self.slots.count(None) > 0:
            return                     # pool too tight while others run
        prefix_pages: List[int] = []
        if self.prefix is not None:
            prefix_pages = self.prefix.lookup(tokens)
        ctx = len(prefix_pages) * self.page_size
        self.queue.popleft()
        self.stats["prefix_hit_tokens"] += ctx
        self.prefilling = _PrefillState(req=req, tokens=tokens, ctx=ctx,
                                        pages=prefix_pages, resume=resume)

    def _prefill_step(self):
        """Run one chunk of the in-flight prefill (if any)."""
        st = self.prefilling
        if st is None:
            return
        n_tok = len(st.tokens)
        end = min(st.ctx + self.prefill_chunk, n_tok)
        need = self._pages_for(end) - len(st.pages)
        if self._pages_for(end) > self.table_pages:
            # past the per-request logical capacity (block-table width)
            self._finish_truncated(st.req, st.pages)
            self.prefilling = None
            return
        if need > 0:
            got = self._acquire(need)
            if got is None:
                # the pool can't hold even this prompt alone: truncate
                self._finish_truncated(st.req, st.pages)
                self.prefilling = None
                return
            st.pages.extend(got)
        bt_row = np.full((1, self.table_pages), self.scratch, np.int32)
        bt_row[0, :len(st.pages)] = st.pages
        chunk = np.zeros(self.prefill_chunk, np.int32)
        chunk[:end - st.ctx] = st.tokens[st.ctx:end]
        logits, self.pools = self._chunk(
            self.params, jnp.asarray(chunk[None]), self.pools,
            jnp.asarray(bt_row), jnp.int32(st.ctx),
            jnp.int32(end - st.ctx - 1))
        self.stats["prefill_chunks"] += 1
        st.ctx = end
        if end == n_tok:
            self._finish_prefill(st, logits)
            self.prefilling = None

    def _finish_prefill(self, st: _PrefillState, logits):
        req = st.req
        slot = self.slots.index(None)
        req.slot = slot
        if st.resume:
            # the re-run's "first token" repeats an already-emitted one
            tok = int(req.output[-1])
        else:
            tok = self._to_py(self._pick(logits, [req])[0])
            req.output.append(tok)
            req.t_first_token = time.monotonic()
            self.stats["tokens_out"] += 1
        self.last_tok[slot] = tok
        self.pos[slot] = len(st.tokens)
        self.bt[slot] = self.scratch
        self.bt[slot, :len(st.pages)] = st.pages
        self._slot_pages[slot] = st.pages
        self.slots[slot] = req
        self._slot_order.append(slot)
        self.stats["prefills"] += 1
        if self.prefix is not None:
            self.prefix.register(np.asarray(req.prompt, np.int32),
                                 st.pages)
        # a zero-new-token request is already done
        if req.done:
            self._retire(slot, req)

    def _finish_truncated(self, req: Request, pages: List[int]):
        self.alloc.release(pages)
        self._finish(req, truncated=True)

    # ------------------------------------------------------------------
    # decode wave
    # ------------------------------------------------------------------
    def _ensure_decode_pages(self) -> List[int]:
        """Grow each active slot's block table to cover its next row;
        slots the pool cannot serve are truncated. Returns live slots."""
        live = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            rows = int(self.pos[slot]) + 1
            need = self._pages_for(rows) - len(self._slot_pages[slot])
            if self._pages_for(rows) > self.table_pages:
                self._free_slot(slot)              # logical-capacity wall
                self._finish_truncated(req, [])
                continue
            if need > 0:
                got = self._acquire(need, protect_slot=slot)
                if got is None:
                    self._free_slot(slot)
                    self._finish_truncated(req, [])
                    continue
                base = len(self._slot_pages[slot])
                self.bt[slot, base:base + len(got)] = got
                self._slot_pages[slot].extend(got)
            live.append(slot)
        # _acquire may have preempted a slot already collected above
        return [s for s in live if self.slots[s] is not None]

    def _decode_wave(self):
        live = self._ensure_decode_pages()
        if not live:
            return
        logits, self.pools = self._decode(
            self.params, jnp.asarray(self.last_tok), self.pools,
            jnp.asarray(self.bt), jnp.asarray(self.pos))
        toks = np.asarray(self._pick(logits, self.slots))
        self.stats["decode_steps"] += 1
        for slot in live:
            req = self.slots[slot]
            self.pos[slot] += 1
            req.output.append(self._to_py(toks[slot]))
            self.last_tok[slot] = toks[slot]
            self.stats["tokens_out"] += 1
            if req.t_first_token is None:
                req.t_first_token = time.monotonic()
            if req.done:
                self._retire(slot, req)

    def _retire(self, slot: int, req: Request):
        self._free_slot(slot)
        self._finish(req)

    # ------------------------------------------------------------------
    def _advance(self):
        """One engine tick: advance the in-flight prefill by a chunk,
        then run one decode wave."""
        self._prefill_step()
        self._decode_wave()
        if self.pipeline is not None:
            self.stats["bytes_pcie"] = self.pipeline.bytes_pcie
            self.stats["hbm_resident_bytes"] = self.hbm_resident_bytes()
