"""Hash-aware speculative decoding: the draft -> verify plane role.

Speculative decoding turns d+1 sequential decode waves into one round:
a cheap *draft* proposes a depth-d token run per slot, a single
*verify* wave scores all d+1 positions at once, and the engine commits
the longest prefix the target model agrees with. The whole point of
running it HERE is that HATA makes the draft nearly free without a
second model: the same weights decode under a tiny hash budget (the
``core/budgets.py`` resolver, installed at trace time) or under a
layer-subset cut, and the verify wave is just a chunked-prefill-shaped
pass over the live cache views — no new kernels, no draft cache.

Round shape (one jitted function per engine; built by the worker
factories in ``serving/plane.py``, the ONE place model entry points
are called from serving code):

  * committed rows = p, feed token t (picked last round, not yet in
    the cache). Draft wave j appends row p+j-1 and proposes d_j — the
    greedy argmax of the *draft* logits regardless of engine sample
    mode (the draft only proposes; the target's RNG stream decides).
  * verify scores the (B, d+1) block [t, d_1..d_d] in ONE
    ``Model.verify_chunk`` pass at per-row ctx = p: position j's
    logits see exactly the context the sequential decode would after
    committing j more tokens, and the chunk's exact K/V overwrites
    whatever the draft appended before any query reads it.
  * the target picks g_j from position j's logits on the request's own
    (id, step) RNG stream (``sampling.pick_tokens_device`` with
    step = steps0 + j) — greedy argmax or the per-request categorical.
  * accept = 1 + length of the matching prefix (d_j == g_{j-1}):
    token g_j is emitted iff every draft token before it matched, so
    the emitted stream is BIT-EXACT with the non-speculative engine in
    both greedy and sampled modes — acceptance is coupled to the
    target's own pick streams, a strictly stronger guarantee than
    distribution-level rejection sampling, and at least one token
    lands every round (an all-rejected draft still commits g_0).

Rows past the accepted prefix hold garbage; nothing ever reads them
(validity masks / causality), the next round's draft+verify rewrite
them, and :func:`rollback_slot` — the ONE sanctioned block-table
truncate + position rewind, CI grep-guarded — returns the pages.

Draft sources (all self-drafting — one set of weights):

  * :class:`BudgetDraft`    — full depth, HATA top-k clamped to a tiny
                              uniform per-layer budget table.
  * :class:`LayerSubsetDraft` — only the first N layers run, straight
                              into the head (deep views pass through).
  * :class:`ConstantDraft`  — a fixed token, no model call: the
                              adversarial always-disagreeing draft the
                              livelock regression test drives.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import budgets as budgets_mod
from repro.serving.request import Request
from repro.serving.sampling import pick_tokens_device


# ---------------------------------------------------------------------------
# Draft sources
# ---------------------------------------------------------------------------
class DraftSource:
    """What proposes the depth-d run. Subclasses set at most one of
    ``layer_limit`` (run only the first N layers), ``fixed_token``
    (skip the model entirely) or a ``trace_context`` (install a draft
    budget table while the draft decode traces)."""

    layer_limit: Optional[int] = None
    fixed_token: Optional[int] = None

    def trace_context(self, model):
        return contextlib.nullcontext()

    def describe(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class BudgetDraft(DraftSource):
    """Self-draft under a tiny uniform hash budget: every layer's
    HATA top-k is clamped to ``budget`` rows through the ONE budget
    resolver (``core/budgets.py`` — installed at trace time around the
    draft decode steps only; the verify wave traces under the engine's
    own table). Dense layers are unaffected, so on a config without
    HATA this degenerates to the target model (acceptance 1.0)."""

    budget: int = 8

    def table(self, n_layers: int) -> budgets_mod.BudgetTable:
        b = int(self.budget)
        assert b > 0, f"draft budget must be positive, got {b}"
        return budgets_mod.BudgetTable(
            n_layers=n_layers,
            entries=tuple((li, 1.0, b, b) for li in range(n_layers)))

    def trace_context(self, model):
        return budgets_mod.use_budget_table(self.table(model.cfg.n_layers))

    def describe(self) -> str:
        return f"budget[{self.budget}]"


@dataclasses.dataclass(frozen=True)
class LayerSubsetDraft(DraftSource):
    """Self-draft through only the first ``n_layers`` layers (the
    dense prefix is the natural cut on HATA configs), straight into
    the head. Skipped layers' cache views pass through untouched —
    their stale rows are rewritten by the verify chunk before any
    query reads them."""

    n_layers: int = 1

    @property
    def layer_limit(self) -> int:       # type: ignore[override]
        assert self.n_layers > 0, self.n_layers
        return int(self.n_layers)

    def describe(self) -> str:
        return f"layers[{self.n_layers}]"


@dataclasses.dataclass(frozen=True)
class ConstantDraft(DraftSource):
    """A fixed-token draft with NO model call and NO cache writes —
    the verify chunk appends every row itself. Acceptance is whatever
    it happens to be (usually ~0); outputs stay exact regardless. This
    is the adversarial source: a draft that never agrees must still
    make progress (the verify wave's own pick lands every round)."""

    token: int = 0

    @property
    def fixed_token(self) -> int:       # type: ignore[override]
        return int(self.token)

    def describe(self) -> str:
        return f"const[{self.token}]"


@dataclasses.dataclass(frozen=True)
class SpeculationController:
    """Depth + draft choice for a speculative engine; carried by the
    :class:`~repro.serving.plane.DecodeWorker` so the round step and
    the engine tick agree on the wave shape."""

    depth: int = 3
    draft: DraftSource = dataclasses.field(default_factory=BudgetDraft)

    def __post_init__(self):
        assert self.depth >= 1, f"speculate depth must be >= 1, " \
                                f"got {self.depth}"

    def describe(self) -> str:
        return f"spec(d={self.depth}, draft={self.draft.describe()})"


# ---------------------------------------------------------------------------
# Acceptance math (pure, device-side — traced into the round jit)
# ---------------------------------------------------------------------------
def pick_targets(base_key, vlogits, ids, steps, sample: str):
    """Target picks for every verify position: token j of row b drawn
    from the request's own (id, steps0 + j) RNG stream — the EXACT
    stream the non-speculative engine would use for its j-th future
    wave, which is what makes acceptance output-exact in sampled mode
    too. vlogits (B, C, V) -> (B, C) int32."""
    cols = [pick_tokens_device(base_key, vlogits[:, j], ids, steps + j,
                               sample)
            for j in range(vlogits.shape[1])]
    return jnp.stack(cols, axis=1)


def accept_counts(vtoks, targets, pos, cov):
    """Accepted-token count per row: 1 + the length of the matching
    draft prefix (draft token j+1 vs target pick j), clamped to the
    rows the slot's cache actually covers (``cov`` — capacity walls
    and partial page coverage; positions past it attended unwritten
    rows and their logits are garbage). Always >= 1: an all-rejected
    round still commits the verify wave's own first pick, so a
    speculative engine can never stall. vtoks/targets (B, d+1);
    pos/cov (B,) -> (B,) int32 in [1, d+1]."""
    match = (vtoks[:, 1:] == targets[:, :-1]).astype(jnp.int32)
    acc = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    room = jnp.maximum(cov - pos, 1)
    return jnp.minimum(acc, room).astype(jnp.int32)


# ---------------------------------------------------------------------------
# The in-flight speculative wave
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SpecWave:
    """One in-flight speculative round: device handles + the
    launch-time snapshot. ``toks`` holds the TARGET picks (the only
    tokens that can be emitted); ``acc`` how many lead tokens each row
    committed. Settling (blocking on ``acc`` and committing
    pos/steps/pages through :func:`rollback_slot`) is split from
    harvesting (recording tokens) so the async tick can launch round
    n+1 as soon as round n's acceptance is known, and hide round n's
    host-side token work under round n+1's device time."""

    toks: Any                          # (B, d+1) device — target picks
    acc: Any                           # (B,) device — accepted counts
    reqs: List[Optional[Request]]      # slot -> request at launch
    pos0: np.ndarray                   # committed rows at launch
    steps0: np.ndarray                 # RNG stream indices at launch
    acc_np: Optional[np.ndarray] = None   # set once settled


# ---------------------------------------------------------------------------
# THE rollback: block-table truncate + position rewind, one helper
# ---------------------------------------------------------------------------
def rollback_slot(engine, slot: int, rows: int) -> None:
    """Commit ``rows`` as ``slot``'s true length: rewind the position
    mirror past any speculative advance and, on paged engines,
    truncate the block table to ``ceil(rows / page_size)`` pages —
    surplus pages released, their columns re-parked on the scratch
    page. ``rows=0`` is the full teardown (slot free / preemption).

    This is the ONE sanctioned truncate+rewind (CI grep-guards the
    idioms): rollback that forgot to release pages, or released a page
    still holding committed rows, is exactly the class of drift a
    second implementation would eventually grow.
    """
    assert rows >= 0, rows
    engine.pos[slot] = rows
    pages = getattr(engine, "_slot_pages", None)
    if pages is None:
        return                          # dense slab: nothing paged
    keep = engine._pages_for(rows)
    surplus = pages[slot][keep:]
    if surplus:
        engine.decode_group.alloc.release(surplus)
        pages[slot] = pages[slot][:keep]
        engine.bt[slot, keep:] = \
            engine.decode_group.scratch_cols[keep:]
