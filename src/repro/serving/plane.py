"""The serving plane: explicit roles behind both engines (DESIGN.md §8).

The engines used to be two monoliths: one Python loop owning the
queue, the compiled step fns, the page pool AND the device wave, all
synchronous. This module splits that into composable roles so the
colocated synchronous configuration, the async double-buffered tick,
and the disaggregated prefill/decode split are *configurations* of one
machine rather than three engines:

``AdmissionController``
    The queue + bounded-lookahead admission. Policy-free: the engine
    supplies a ``probe(req) -> ADMIT | DEFER | TRUNCATE`` closure
    (watermarks, capacity walls); the controller scans the first
    ``lookahead + 1`` entries and pops the first non-DEFER — so
    ``lookahead=0`` is exactly the old strict-FCFS "only queue[0]"
    behavior, and ``lookahead>0`` is first-fit within the window
    (FCFS otherwise), which unblocks small admissible prompts stuck
    behind one oversized head-of-line prompt.

``PrefillWorker`` / ``DecodeWorker``
    Each owns its compiled step fns, its device/mesh placement (via
    the :class:`PoolGroup` it is bound to) and its in-flight work:
    the worker layer is the ONLY place ``Model.decode_step`` /
    ``Model.prefill_chunk`` / ``Model.prefill`` are called from
    serving code (CI grep-guards this), so a future remote worker is
    a drop-in. The decode step fns FUSE the next-token pick
    (``sampling.pick_tokens_device``): a wave's tokens never leave
    the device between waves.

``Transfer``
    The prefill->decode page boundary. Colocated: both workers share
    one :class:`PoolGroup` and ``ship`` is the identity (bit-exact
    with the pre-plane engines by construction). Disaggregated
    (:class:`PageShipper`): decode-side pages are allocated through
    the decode group's allocator (page-id remapping), the page bytes
    are copied pool-to-pool (``paged_cache.copy_pages``, optionally
    crossing devices), and the prefill-side pages are released — the
    prefix cache keeps its own refs on the prefill side, so sharing
    keeps skipping prefill compute.

``Wave``
    One in-flight decode wave: the device token handle plus the slot
    snapshot taken at launch. The async tick (engines' ``_advance``)
    launches wave *n+1* — feeding wave *n*'s device token handle
    straight back in — BEFORE blocking on wave *n*'s tokens, so host
    work (retirement, timing stamps, detokenize callbacks) overlaps
    device execution. Per-request RNG streams make the reordering
    invisible in the outputs (a token is a pure function of
    (seed, id, step)); the engines drain the in-flight wave before
    any preemption/eviction-of-a-live-slot or wall truncation, which
    keeps replay exactly as synchronous. Speculative tokens for slots
    that retire at harvest are discarded against the snapshot.

Page-id convention: tables at this layer always carry GLOBAL page ids
— for sharded pool groups (page axis + block-table columns sharded
together over the mesh's sequence axis) the per-shard
``ShardedPageAllocator`` guarantees column c's page is owned by c's
shard, and ``SPDecode(global_page_ids=True)`` localizes ids inside
shard_map. Appends/prefill therefore run unmodified on the GSPMD path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Callable, Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache_view as cache_view_mod
from repro.core import paged_cache as paged
from repro.core.kvcache import MLACache
from repro.core.paged_cache import (PageAllocator, PrefixCache,
                                    ShardedPageAllocator)
from repro.distributed import strategy as strategy_mod
from repro.serving import speculative as spec_mod
from repro.serving.request import Request
from repro.serving.sampling import pick_tokens_device

# admission verdicts
ADMIT = "admit"
DEFER = "defer"
TRUNCATE = "truncate"


class AdmissionController:
    """Queue + watermark-probed admission with bounded lookahead."""

    def __init__(self, lookahead: int = 0):
        assert lookahead >= 0, lookahead
        self.queue: Deque[Request] = deque()
        self.lookahead = int(lookahead)

    def __len__(self) -> int:
        return len(self.queue)

    def __bool__(self) -> bool:
        return bool(self.queue)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def requeue(self, req: Request) -> None:
        """Preempted requests go back to the FRONT (LIFO victims keep
        the oldest requests' latency bounds)."""
        self.queue.appendleft(req)

    def select(self, probe: Callable[[Request], str]
               ) -> Optional[Tuple[Request, str]]:
        """Pop and return the first non-DEFER request within the
        lookahead window (first-fit in window, FCFS otherwise).

        ``probe`` must be side-effect free — a DEFERred request is
        re-probed every tick and must not churn caches/refcounts.
        ``t_admitted`` is stamped here, the one place requests leave
        the queue (TRUNCATE verdicts count as leaving too: the engine
        retires them immediately).
        """
        window = min(len(self.queue), self.lookahead + 1)
        for i in range(window):
            req = self.queue[i]
            verdict = probe(req)
            if verdict == DEFER:
                continue
            assert verdict in (ADMIT, TRUNCATE), verdict
            del self.queue[i]
            req.t_admitted = time.monotonic()
            return req, verdict
        return None


# ---------------------------------------------------------------------------
# Pool groups: pools + allocator + scratch + prefix cache, per side
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PoolGroup:
    """Everything one worker side owns about its paged cache tier."""
    pools: List[Any]
    alloc: Any                        # PageAllocator | ShardedPageAllocator
    scratch_cols: np.ndarray          # (table_pages,) column -> parking page
    prefix: Optional[PrefixCache]
    pipeline: Optional[Any] = None    # offload PCIe pipeline, if tiered
    col_shard: Optional[np.ndarray] = None   # (T,) column -> shard, or None
    device: Optional[Any] = None      # explicit placement (disaggregation)

    def alloc_cols(self, cols) -> Optional[List[int]]:
        """Allocate one page per block-table column — shard-routed when
        the pool's page axis is sharded (column c's page must be owned
        by c's shard), plain otherwise."""
        if self.col_shard is None:
            return self.alloc.alloc(len(list(cols)))
        return self.alloc.alloc_shards(
            [int(self.col_shard[c]) for c in cols])

    def free_count(self) -> int:
        return self.alloc.free_count()

    def used_count(self) -> int:
        return self.alloc.used_count()


def make_pool_group(model, *, num_pages: int, page_size: int,
                    table_pages: int, offload: bool = False,
                    prefix_sharing: bool = True, mesh=None,
                    seq_axis: str = "model", device=None) -> PoolGroup:
    """Build one side's pools + allocator + scratch reservation.

    ``mesh`` switches the group to the sharded-pool layout: page axis
    and block-table columns sharded together over ``seq_axis``, one
    scratch page per shard (a parked column must point at a page its
    OWN shard holds), per-shard free lists in the allocator.
    """
    if offload:
        pools, pipeline = model.init_offloaded_pools(num_pages, page_size)
    else:
        pools = model.init_paged_pools(num_pages, page_size)
        pipeline = None
    col_shard = None
    if mesh is not None:
        from repro.distributed.sharding import shard_paged_pools
        n_shards = int(mesh.shape[seq_axis])
        assert num_pages % n_shards == 0, \
            f"num_pages={num_pages} must divide over {n_shards} shards"
        assert table_pages % n_shards == 0, \
            f"table_pages={table_pages} must divide over {n_shards} shards"
        pools = shard_paged_pools(mesh, pools, seq_axis)
        alloc = ShardedPageAllocator(num_pages, n_shards)
        scratch = alloc.alloc_shards(list(range(n_shards)))
        cps = table_pages // n_shards
        col_shard = np.arange(table_pages) // cps
        scratch_cols = np.asarray([scratch[s] for s in col_shard],
                                  np.int32)
    else:
        if device is not None:
            pools = jax.device_put(pools, device)
        alloc = PageAllocator(num_pages)
        scratch_cols = np.full(table_pages, alloc.alloc(1)[0], np.int32)
    prefix = PrefixCache(alloc, page_size) if prefix_sharing else None
    return PoolGroup(pools=pools, alloc=alloc, scratch_cols=scratch_cols,
                     prefix=prefix, pipeline=pipeline,
                     col_shard=col_shard, device=device)


# ---------------------------------------------------------------------------
# Workers: own the compiled step fns + in-flight work
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Wave:
    """An in-flight decode wave: device tokens + launch-time snapshot."""
    toks: Any                          # (B,) [audio: (B, nb)] device handle
    reqs: List[Optional[Request]]      # slot -> request at launch


@dataclasses.dataclass
class PrefillTask:
    """A request mid-prefill (chunked; possibly resumed after
    preemption)."""
    req: Request
    tokens: np.ndarray              # prompt (+ replayed output on resume)
    ctx: int                        # rows already in the cache
    pages: List[int]                # pages owned (incl. adopted prefix)
    resume: bool                    # True -> suppress the emitted token


class DecodeWorker:
    """Owns the decode-side step fn, its pool group and the in-flight
    wave. ``step`` is ``(params, toks, <cache state...>, pos, ids,
    steps) -> (next_toks, new cache state)`` with the pick fused."""

    def __init__(self, step: Callable, group: Optional[PoolGroup] = None,
                 step_jit=None,
                 speculate: Optional[spec_mod.SpeculationController] = None):
        self.step = step
        self.group = group
        self.step_jit = step_jit       # unwrapped jit, for HLO guards
        self.speculate = speculate     # set -> step is the spec ROUND fn
        self.inflight: Optional[Wave] = None

    @property
    def busy(self) -> bool:
        return self.inflight is not None

    def put(self, wave: Wave) -> None:
        assert self.inflight is None, "double-buffered depth is 1"
        self.inflight = wave

    def take(self) -> Optional[Wave]:
        wave, self.inflight = self.inflight, None
        return wave


class PrefillWorker:
    """Owns the prefill-side step fn(s), its pool group and the
    in-flight :class:`PrefillTask` (paged engines prefill one request
    at a time, chunked)."""

    def __init__(self, chunk: Callable, group: Optional[PoolGroup] = None,
                 chunk_size: int = 0, step_jit=None, extra=None):
        self.chunk = chunk
        self.group = group
        self.chunk_size = chunk_size
        self.step_jit = step_jit
        self.extra = extra or {}       # dense: {"prefill":, "insert":}
        self.inflight: Optional[PrefillTask] = None

    @property
    def busy(self) -> bool:
        return self.inflight is not None


def _with_strategy(fn, strat):
    """Per-call strategy install (read at trace time — and on every
    call for the eager offload path)."""
    if strat is None:
        return fn

    def wrapped(*a, **k):
        prev = strategy_mod.get_decode_strategy()
        strategy_mod.set_decode_strategy(strat)
        try:
            return fn(*a, **k)
        finally:
            strategy_mod.set_decode_strategy(prev)
    return wrapped


def _spec_round_views(model, spec, p, t, views, pos, ids, steps, cov, *,
                      sample: str, base_key):
    """One speculative round over live cache views — the shared body
    both decode-worker spec branches trace (serving/speculative.py has
    the round math; this is its ONLY model-call site, per the CI
    serving guard).

    Draft wave j appends at row pos+j (clamped to cov-1: past the
    slot's covered rows a write would clamp/park onto rows other data
    owns, and everything at/after the clamp is acceptance-masked
    garbage anyway) and proposes the draft argmax. The verify chunk
    then rewrites rows [pos, pos+d] with exact K/V before reading
    them and scores all d+1 positions at per-row ctx=pos. Returns
    (feed, targets, acc, views): ``feed`` is the next round's input
    token — target pick acc-1, the first one the draft did NOT
    anticipate — kept on device so tokens never leave between rounds.
    """
    depth = spec.depth
    draft = spec.draft
    t = t.astype(jnp.int32)
    if draft.fixed_token is not None:
        drafts = [jnp.full_like(t, draft.fixed_token)] * depth
    else:
        drafts = []
        cur = t
        with draft.trace_context(model):
            for j in range(depth):
                dpos = jnp.minimum(pos + j, cov - 1)
                logits, views = model.decode_step(
                    p, cur, views, dpos, layer_limit=draft.layer_limit)
                cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                drafts.append(cur)
    vtoks = jnp.concatenate([t[:, None]] + [d[:, None] for d in drafts],
                            axis=1)
    vlogits, views = model.verify_chunk(p, vtoks, views, pos)
    targets = spec_mod.pick_targets(base_key, vlogits, ids, steps, sample)
    acc = spec_mod.accept_counts(vtoks, targets, pos, cov)
    feed = jnp.take_along_axis(targets, (acc - 1)[:, None], axis=1)[:, 0]
    return feed, targets, acc, views


def _dense_views(caches):
    """Dense-slab cache dict -> flat per-layer view list (pre then
    stack, the ``_flat_layer_params`` order) + the inverse rebuild."""
    pre = list(caches.get("pre", []))
    stack = list(caches["stack"])
    views = [cache_view_mod.as_mla_view(c) if isinstance(c, MLACache)
             else cache_view_mod.as_gqa_view(c)
             for c in pre + stack]

    def rebuild(new_views):
        flat = [v.unwrap() for v in new_views]
        out = dict(caches)
        if pre:
            out["pre"] = flat[:len(pre)]
        out["stack"] = flat[len(pre):]
        return out
    return views, rebuild


def paged_decode_worker(model, group: PoolGroup, *, sample: str,
                        base_key, wrap, offload: bool = False,
                        strat=None, donate: bool = True,
                        speculate: Optional[
                            spec_mod.SpeculationController] = None
                        ) -> DecodeWorker:
    """Build the paged decode step: per-layer views around the shared
    block table, ``Model.decode_step``, fused pick. Pools are donated
    (row scatters stay in place); offload drops the jit (host gathers
    + the mutable PCIe ledger cross the jit boundary).

    ``donate=False`` is for async double-buffered waves on the CPU
    PJRT client: dispatching with a donated input whose buffer is still
    pending BLOCKS the calling thread until the producer finishes, so a
    donated pools chain serializes launch *n+1* behind wave *n* and the
    async tick degenerates to synchronous. Undonated pools keep the
    dispatch async at the cost of a pool copy per wave.

    ``speculate`` swaps the step for the speculative ROUND fn
    ``(p, feed, pools, bt, pos, ids, steps, cov) ->
    (next_feed, targets, acc, pools)`` — same pools-donation rules,
    one dispatch per d+1 candidate tokens."""

    if speculate is not None:
        def _round(p, t, pools, bt, pos, ids, steps, cov):
            views = cache_view_mod.paged_views(pools, bt)
            feed, targets, acc, views = _spec_round_views(
                model, speculate, p, t, views, pos, ids, steps, cov,
                sample=sample, base_key=base_key)
            return feed, targets, acc, [v.unwrap() for v in views]

        if offload:
            return DecodeWorker(wrap(_with_strategy(_round, strat)),
                                group, speculate=speculate)
        jitted = jax.jit(_with_strategy(_round, strat),
                         donate_argnums=(2,) if donate else ())
        return DecodeWorker(wrap(jitted), group, step_jit=jitted,
                            speculate=speculate)

    def _step(p, t, pools, bt, pos, ids, steps):
        views = cache_view_mod.paged_views(pools, bt)
        logits, views = model.decode_step(p, t, views, pos)
        toks = pick_tokens_device(base_key, logits, ids, steps, sample)
        return toks, [v.unwrap() for v in views]

    if offload:
        return DecodeWorker(wrap(_with_strategy(_step, strat)), group)
    jitted = jax.jit(_with_strategy(_step, strat),
                     donate_argnums=(2,) if donate else ())
    return DecodeWorker(wrap(jitted), group, step_jit=jitted)


def paged_prefill_worker(model, group: PoolGroup, *, chunk_size: int,
                         wrap, offload: bool = False,
                         strat=None) -> PrefillWorker:
    def _chunk(p, t, pools, bt, ctx, last):
        views = cache_view_mod.paged_views(pools, bt)
        logits, views = model.prefill_chunk(p, t, views, ctx, last)
        return logits, [v.unwrap() for v in views]

    if offload:
        return PrefillWorker(wrap(_with_strategy(_chunk, strat)), group,
                             chunk_size)
    jitted = jax.jit(_with_strategy(_chunk, strat), donate_argnums=(2,))
    return PrefillWorker(wrap(jitted), group, chunk_size,
                         step_jit=jitted)


def dense_decode_worker(model, *, sample: str, base_key, wrap,
                        speculate: Optional[
                            spec_mod.SpeculationController] = None
                        ) -> DecodeWorker:
    """Dense-slab decode step with the fused pick (caches stay
    undonated, matching the pre-plane engine). ``speculate`` swaps in
    the speculative round fn: the slab caches are coerced to
    contiguous views for the draft/verify body, unwrapped back to the
    same dict shape after."""

    if speculate is not None:
        def _round(p, t, caches, pos, ids, steps, cov):
            views, rebuild = _dense_views(caches)
            feed, targets, acc, views = _spec_round_views(
                model, speculate, p, t, views, pos, ids, steps, cov,
                sample=sample, base_key=base_key)
            return feed, targets, acc, rebuild(views)

        jitted = jax.jit(_round)
        return DecodeWorker(wrap(jitted), step_jit=jitted,
                            speculate=speculate)

    def _step(p, t, caches, pos, ids, steps):
        logits, caches = model.decode_step(p, t, caches, pos)
        toks = pick_tokens_device(base_key, logits, ids, steps, sample)
        return toks, caches

    jitted = jax.jit(_step)
    return DecodeWorker(wrap(jitted), step_jit=jitted)


def dense_prefill_worker(model, *, wrap) -> PrefillWorker:
    """Dense admission path: monolithic B=1 prefill + slot insert."""

    def _insert(caches, single, slot):
        def ins(dst, src):
            idx = (slot,) + (0,) * (dst.ndim - 1)
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), idx)
        return jax.tree.map(ins, caches, single)

    prefill = wrap(jax.jit(
        lambda p, b, c: model.prefill(p, b, c, jnp.int32(0))))
    insert = jax.jit(_insert, donate_argnums=(0,))
    return PrefillWorker(chunk=None, extra={"prefill": prefill,
                                            "insert": insert})


# ---------------------------------------------------------------------------
# Donation dispatch probe (async wave tuning)
# ---------------------------------------------------------------------------
_DONATION_OVERLAPS: Optional[bool] = None


def donation_overlaps(force: Optional[bool] = None) -> bool:
    """Measured, process-cached answer to "can a jitted call DISPATCH
    while a donated input's producer is still running?".

    The async double-buffered tick needs launch n+1 to return before
    wave n finishes. The probed shape matters: on the CPU PJRT client
    a SINGLE donated dispatch against a pending (non-donated) producer
    returns immediately, but a CHAIN of donated dispatches — each
    donating the previous call's still-pending donated output, which
    is exactly the engine's pools chain — blocks the dispatching
    thread for the producer's full runtime, silently degrading the
    tick to synchronous. The engines used to special-case this on the
    backend NAME, which misclassifies any client the list doesn't
    know about (new plugins, donation-blocking accelerators).
    Instead: run a self-chaining donated step twice back-to-back and
    call donation overlap-safe iff the second dispatch returned well
    before the step's measured wall time (< 0.5x). One probe per
    process (~100ms on hosts that need it); a wrong call costs only
    an extra pool copy or a serialized launch, never correctness.

    ``force`` pins the cached verdict (tests / explicit override).
    """
    global _DONATION_OVERLAPS
    if force is not None:
        _DONATION_OVERLAPS = bool(force)
    if _DONATION_OVERLAPS is not None:
        return _DONATION_OVERLAPS

    n, iters = 384, 12

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(x):
        for _ in range(iters):
            x = jnp.tanh(x @ x)
        return x

    x = step(jnp.ones((n, n), jnp.float32))             # compile
    x.block_until_ready()

    t0 = time.monotonic()
    x = step(x)
    x.block_until_ready()
    t_prod = time.monotonic() - t0

    x = step(x)                                         # pending chain
    t0 = time.monotonic()
    x = step(x)                         # donates a pending donated out
    t_disp = time.monotonic() - t0
    x.block_until_ready()

    _DONATION_OVERLAPS = bool(t_disp < 0.5 * t_prod)
    return _DONATION_OVERLAPS


# ---------------------------------------------------------------------------
# Transfer boundary: prefill pages -> decode pages
# ---------------------------------------------------------------------------
class Transfer:
    """Colocated: prefill and decode share one :class:`PoolGroup`, a
    finished prefill's pages ARE the decode pages — identity ship,
    bit-exact with the pre-plane engines by construction."""

    remote = False

    def __init__(self):
        self.stats = {"pages_shipped": 0}

    def ship(self, engine, pages: List[int]) -> Optional[List[int]]:
        return pages


class PageShipper(Transfer):
    """Disaggregated: remap page ids through the decode group's
    allocator and copy the page bytes pool-to-pool (optionally across
    devices). Ship failure (decode pool can't fit the prompt even
    after eviction/preemption) returns None — the engine truncates,
    same rule as a colocated pool that can't fit a prompt."""

    remote = True

    def __init__(self, src: PoolGroup, dst: PoolGroup):
        super().__init__()
        self.src = src
        self.dst = dst

    def ship(self, engine, pages: List[int]) -> Optional[List[int]]:
        if not pages:
            return []
        # decode-side pages for columns 0..n-1 — through the engine's
        # acquire path so eviction/drain/preemption policy applies
        dst_pages = engine._acquire(self.dst, list(range(len(pages))))
        if dst_pages is None:
            return None
        for li in range(len(self.dst.pools)):
            self.dst.pools[li] = paged.copy_pages(
                self.src.pools[li], self.dst.pools[li], pages, dst_pages,
                device=self.dst.device)
        self.stats["pages_shipped"] += len(pages)
        return dst_pages
