"""Atomic, async, elastic checkpointing.

Fault-tolerance contract (DESIGN.md §4):
  * **Atomic**: a checkpoint is written to ``step_XXXX.tmp-<nonce>`` and
    renamed into place only after every array + the manifest have been
    fsync'd; a crash mid-save can never corrupt the latest checkpoint.
    ``latest()`` only considers directories with a valid manifest.
  * **Async**: ``save()`` snapshots arrays to host memory synchronously
    (cheap) and writes to disk on a background thread so the train loop
    is not blocked; ``wait()`` joins before the next save or exit.
  * **Elastic**: arrays are saved *unsharded* (gathered), with the tree
    structure and logical sharding names in the manifest. ``restore()``
    re-``device_put``s onto whatever mesh/sharding the new job passes —
    restart on a different topology (e.g. 256 -> 512 chips) just works.
    On a real multi-host pod the gather becomes per-host shard files;
    the manifest format already carries what that needs.
  * **Self-describing**: the manifest stores a config fingerprint; a
    mismatched restore fails loudly rather than silently reinterpreting
    weights.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def config_fingerprint(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 fingerprint: str = ""):
        self.dir = directory
        self.keep = keep
        self.fingerprint = fingerprint
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, state: Any, *, blocking: bool = False):
        """Snapshot ``state`` (any pytree of arrays) at ``step``."""
        self.wait()
        paths, leaves, _ = _flatten_with_paths(state)
        # synchronous host snapshot (device -> host copy)
        host = [np.asarray(x) for x in leaves]

        def _write():
            try:
                final = self._step_dir(step)
                tmp = tempfile.mkdtemp(prefix=os.path.basename(final)
                                       + ".tmp-", dir=self.dir)
                manifest = {"step": step, "time": time.time(),
                            "fingerprint": self.fingerprint,
                            "arrays": {}}
                for i, (p, a) in enumerate(zip(paths, host)):
                    fn = f"arr_{i:05d}.npy"
                    logical = str(a.dtype)
                    if not a.dtype.isbuiltin:
                        # ml_dtypes (bfloat16, f8...) don't survive the
                        # npy format: store raw bits + logical dtype
                        a = a.view(f"u{a.dtype.itemsize}")
                    np.save(os.path.join(tmp, fn), a)
                    manifest["arrays"][p] = {
                        "file": fn, "shape": list(a.shape),
                        "dtype": logical}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            self.wait()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and ".tmp-" not in name
                    and os.path.exists(os.path.join(full,
                                                    "manifest.json"))):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally place each
        leaf with the matching entry of ``shardings`` (elastic restore
        onto any mesh)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if self.fingerprint and manifest["fingerprint"] and \
                manifest["fingerprint"] != self.fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']} does "
                f"not match config {self.fingerprint}")
        paths, leaves, treedef = _flatten_with_paths(like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for p, leaf, sh in zip(paths, leaves, shard_leaves):
            meta = manifest["arrays"].get(p)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            a = np.load(os.path.join(d, meta["file"]))
            if str(a.dtype) != meta["dtype"]:
                # stored as raw bits (ml_dtypes): view back
                import ml_dtypes  # noqa: F401 (registers dtypes)
                a = a.view(np.dtype(meta["dtype"]))
            if list(a.shape) != list(leaf.shape):
                raise ValueError(f"{p}: shape {a.shape} != {leaf.shape}")
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out)
