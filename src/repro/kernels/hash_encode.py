"""Fused HashEncode Pallas kernel (paper Alg. 2 + §4 "kernel fusion").

One ``pl.pallas_call`` fuses projection (MXU), sign (VPU) and bit-pack
(VPU shifts) so the (s, rbit) ±1 intermediate never round-trips to HBM.
On GPU the paper's motivation for fusion is kernel-launch latency; on TPU
XLA already fuses launches, but the HBM-traffic win is real: the naive
graph writes sign(xW) (s*rbit bytes) and re-reads it for packing, the
fused kernel writes only the packed (s * rbit/8) bytes.

Grid/tiling: grid over sequence blocks; each step loads an
(block_s, d) x-tile and the full (d, rbit) hash weight into VMEM, does one
MXU matmul (d and rbit are 128-multiples for every production config) and
packs to (block_s, rbit/32) uint32. VMEM footprint at defaults
(block_s=512, d=128, rbit=128): 512*128*4 + 128*128*4 + 512*4*4 ≈ 330 KiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime
from repro.kernels.ref import WORD_BITS


def _hash_encode_kernel(x_ref, w_ref, out_ref, *, rbit: int):
    x = x_ref[...].astype(jnp.float32)            # (block_s, d)
    w = w_ref[...].astype(jnp.float32)            # (d, rbit)
    proj = jnp.dot(x, w, preferred_element_type=jnp.float32)  # MXU
    bits = (proj >= 0).astype(jnp.uint32)         # sign, VPU
    # Pack: (block_s, rbit) -> (block_s, W, 32) -> shifted-sum over the
    # minor 32 lane group. The reshape only splits the minor-most dim,
    # which Mosaic lowers to sublane regrouping.
    blk = bits.shape[0]
    w_words = rbit // WORD_BITS
    bits = bits.reshape(blk, w_words, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _pack_bits(proj: jax.Array) -> jax.Array:
    """sign + bit-pack a (rows, rbit) f32 projection to (rows, rbit//32)."""
    bits = (proj >= 0).astype(jnp.uint32)
    rows, rbit = bits.shape
    bits = bits.reshape(rows, rbit // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _hash_encode_mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, out_ref):
    # Non-linear variant (Spotlight-style 2-layer MLP before sign): the
    # hidden activation lives only in VMEM — exactly the fusion argument
    # of the linear kernel, one extra MXU matmul.
    x = x_ref[...].astype(jnp.float32)            # (block_s, d)
    w1 = w1_ref[...].astype(jnp.float32)          # (d, hidden)
    b1 = b1_ref[...].astype(jnp.float32)          # (1, hidden)
    w2 = w2_ref[...].astype(jnp.float32)          # (hidden, rbit)
    hid = jnp.maximum(
        jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1, 0.0)
    proj = jnp.dot(hid, w2, preferred_element_type=jnp.float32)
    out_ref[...] = _pack_bits(proj)


def _hash_encode_heads_mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, out_ref):
    x = x_ref[...]                                # (B, block_s, 1, d)
    w1 = w1_ref[0].astype(jnp.float32)            # (d, hidden)
    b1 = b1_ref[...].reshape(1, -1).astype(jnp.float32)   # (1, hidden)
    w2 = w2_ref[0].astype(jnp.float32)            # (hidden, rbit)
    b, blk = x.shape[0], x.shape[1]
    xf = x[:, :, 0, :].reshape(b * blk, -1).astype(jnp.float32)
    hid = jnp.maximum(
        jnp.dot(xf, w1, preferred_element_type=jnp.float32) + b1, 0.0)
    proj = jnp.dot(hid, w2, preferred_element_type=jnp.float32)
    packed = _pack_bits(proj)
    out_ref[...] = packed.reshape(b, blk, 1, packed.shape[-1])


def _hash_encode_heads_kernel(x_ref, w_ref, out_ref, *, rbit: int):
    x = x_ref[...]                                # (B, block_s, 1, d)
    w = w_ref[0]                                  # (d, rbit)
    b, blk = x.shape[0], x.shape[1]
    xf = x[:, :, 0, :].reshape(b * blk, -1).astype(jnp.float32)
    proj = jnp.dot(xf, w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    bits = (proj >= 0).astype(jnp.uint32)
    w_words = rbit // WORD_BITS
    bits = bits.reshape(b * blk, w_words, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    packed = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    out_ref[...] = packed.reshape(b, blk, 1, w_words)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hash_encode_heads(x: jax.Array, w_h: jax.Array, *,
                      block_s: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Per-head fused hash encode in ONE grid dispatch.

    x: (B, S, H, d) float, w_h: (H, d, rbit) -> (B, S, H, rbit//32)
    uint32. Grid is (H, S-blocks): each step loads one head's (d, rbit)
    weight and a (B, block_s, 1, d) slab of that head's keys — the
    batch is folded into the tile like the latent encode flattening —
    so the per-(batch, head) vmap this replaces (one kernel launch per
    lane, ~B*H dispatches) collapses to a single ``pallas_call``. Same
    f32 projection / sign / bit-pack as :func:`hash_encode`, so codes
    are bit-identical to the vmapped path and the XLA oracle.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, s, h, d = x.shape
    block_s = runtime.encode_block_s(block_s, size=s, dtype=x.dtype)
    h2, d2, rbit = w_h.shape
    assert (h, d) == (h2, d2), (x.shape, w_h.shape)
    assert rbit % WORD_BITS == 0
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        functools.partial(_hash_encode_heads_kernel, rbit=rbit),
        grid=(h, n_blocks),
        in_specs=[
            pl.BlockSpec((b, block_s, 1, d), lambda hi, si: (0, si, hi, 0)),
            pl.BlockSpec((1, d, rbit), lambda hi, si: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_s, 1, rbit // WORD_BITS),
                               lambda hi, si: (0, si, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, rbit // WORD_BITS),
                                       jnp.uint32),
        interpret=interpret,
    )(x, w_h)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hash_encode(x: jax.Array, w_h: jax.Array, *,
                block_s: Optional[int] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """Encode vectors into bit-packed hash codes.

    x: (s, d) float, w_h: (d, rbit) float -> (s, rbit//32) uint32.
    Batched/multi-head shapes are handled by ``ops.hash_encode`` via vmap.
    """
    interpret = runtime.resolve_interpret(interpret)
    s, d = x.shape
    block_s = runtime.encode_block_s(block_s, size=s, dtype=x.dtype)
    d2, rbit = w_h.shape
    assert d == d2, (x.shape, w_h.shape)
    assert rbit % WORD_BITS == 0
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        functools.partial(_hash_encode_kernel, rbit=rbit),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((d, rbit), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, rbit // WORD_BITS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, rbit // WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x, w_h)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hash_encode_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array,
                    w2: jax.Array, *, block_s: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Fused non-linear hash encode (2-layer MLP before sign).

    x: (s, d), w1: (d, hidden), b1: (hidden,), w2: (hidden, rbit)
    -> (s, rbit//32) uint32. Same grid/tiling as :func:`hash_encode`
    with the full MLP weights resident in VMEM; the (block_s, hidden)
    activation never round-trips to HBM.
    """
    interpret = runtime.resolve_interpret(interpret)
    s, d = x.shape
    block_s = runtime.encode_block_s(block_s, size=s, dtype=x.dtype)
    d2, hidden = w1.shape
    hidden2, rbit = w2.shape
    assert d == d2 and hidden == hidden2 and b1.shape == (hidden,), (
        x.shape, w1.shape, b1.shape, w2.shape)
    assert rbit % WORD_BITS == 0
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        _hash_encode_mlp_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((d, hidden), lambda i: (0, 0)),
            pl.BlockSpec((1, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, rbit), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, rbit // WORD_BITS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, rbit // WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x, w1, b1.reshape(1, hidden), w2)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hash_encode_heads_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array,
                          w2: jax.Array, *, block_s: Optional[int] = None,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Per-head fused MLP hash encode in ONE grid dispatch.

    x: (B, S, H, d); w1: (H, d, hidden), b1: (H, hidden),
    w2: (H, hidden, rbit) -> (B, S, H, rbit//32) uint32. Grid and batch
    folding mirror :func:`hash_encode_heads`.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, s, h, d = x.shape
    block_s = runtime.encode_block_s(block_s, size=s, dtype=x.dtype)
    h2, d2, hidden = w1.shape
    h3, hidden2, rbit = w2.shape
    assert (h, d) == (h2, d2) and (h, hidden) == (h3, hidden2), (
        x.shape, w1.shape, w2.shape)
    assert b1.shape == (h, hidden), b1.shape
    assert rbit % WORD_BITS == 0
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        _hash_encode_heads_mlp_kernel,
        grid=(h, n_blocks),
        in_specs=[
            pl.BlockSpec((b, block_s, 1, d), lambda hi, si: (0, si, hi, 0)),
            pl.BlockSpec((1, d, hidden), lambda hi, si: (hi, 0, 0)),
            pl.BlockSpec((1, hidden), lambda hi, si: (hi, 0)),
            pl.BlockSpec((1, hidden, rbit), lambda hi, si: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_s, 1, rbit // WORD_BITS),
                               lambda hi, si: (0, si, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, rbit // WORD_BITS),
                                       jnp.uint32),
        interpret=interpret,
    )(x, w1, b1, w2)
