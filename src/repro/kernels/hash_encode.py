"""Fused HashEncode Pallas kernel (paper Alg. 2 + §4 "kernel fusion").

One ``pl.pallas_call`` fuses projection (MXU), sign (VPU) and bit-pack
(VPU shifts) so the (s, rbit) ±1 intermediate never round-trips to HBM.
On GPU the paper's motivation for fusion is kernel-launch latency; on TPU
XLA already fuses launches, but the HBM-traffic win is real: the naive
graph writes sign(xW) (s*rbit bytes) and re-reads it for packing, the
fused kernel writes only the packed (s * rbit/8) bytes.

Grid/tiling: grid over sequence blocks; each step loads an
(block_s, d) x-tile and the full (d, rbit) hash weight into VMEM, does one
MXU matmul (d and rbit are 128-multiples for every production config) and
packs to (block_s, rbit/32) uint32. VMEM footprint at defaults
(block_s=512, d=128, rbit=128): 512*128*4 + 128*128*4 + 512*4*4 ≈ 330 KiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime
from repro.kernels.ref import WORD_BITS


def _hash_encode_kernel(x_ref, w_ref, out_ref, *, rbit: int):
    x = x_ref[...].astype(jnp.float32)            # (block_s, d)
    w = w_ref[...].astype(jnp.float32)            # (d, rbit)
    proj = jnp.dot(x, w, preferred_element_type=jnp.float32)  # MXU
    bits = (proj >= 0).astype(jnp.uint32)         # sign, VPU
    # Pack: (block_s, rbit) -> (block_s, W, 32) -> shifted-sum over the
    # minor 32 lane group. The reshape only splits the minor-most dim,
    # which Mosaic lowers to sublane regrouping.
    blk = bits.shape[0]
    w_words = rbit // WORD_BITS
    bits = bits.reshape(blk, w_words, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _hash_encode_heads_kernel(x_ref, w_ref, out_ref, *, rbit: int):
    x = x_ref[...]                                # (B, block_s, 1, d)
    w = w_ref[0]                                  # (d, rbit)
    b, blk = x.shape[0], x.shape[1]
    xf = x[:, :, 0, :].reshape(b * blk, -1).astype(jnp.float32)
    proj = jnp.dot(xf, w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    bits = (proj >= 0).astype(jnp.uint32)
    w_words = rbit // WORD_BITS
    bits = bits.reshape(b * blk, w_words, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    packed = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    out_ref[...] = packed.reshape(b, blk, 1, w_words)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hash_encode_heads(x: jax.Array, w_h: jax.Array, *,
                      block_s: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Per-head fused hash encode in ONE grid dispatch.

    x: (B, S, H, d) float, w_h: (H, d, rbit) -> (B, S, H, rbit//32)
    uint32. Grid is (H, S-blocks): each step loads one head's (d, rbit)
    weight and a (B, block_s, 1, d) slab of that head's keys — the
    batch is folded into the tile like the latent encode flattening —
    so the per-(batch, head) vmap this replaces (one kernel launch per
    lane, ~B*H dispatches) collapses to a single ``pallas_call``. Same
    f32 projection / sign / bit-pack as :func:`hash_encode`, so codes
    are bit-identical to the vmapped path and the XLA oracle.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, s, h, d = x.shape
    block_s = runtime.encode_block_s(block_s, size=s, dtype=x.dtype)
    h2, d2, rbit = w_h.shape
    assert (h, d) == (h2, d2), (x.shape, w_h.shape)
    assert rbit % WORD_BITS == 0
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        functools.partial(_hash_encode_heads_kernel, rbit=rbit),
        grid=(h, n_blocks),
        in_specs=[
            pl.BlockSpec((b, block_s, 1, d), lambda hi, si: (0, si, hi, 0)),
            pl.BlockSpec((1, d, rbit), lambda hi, si: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_s, 1, rbit // WORD_BITS),
                               lambda hi, si: (0, si, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, rbit // WORD_BITS),
                                       jnp.uint32),
        interpret=interpret,
    )(x, w_h)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hash_encode(x: jax.Array, w_h: jax.Array, *,
                block_s: Optional[int] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """Encode vectors into bit-packed hash codes.

    x: (s, d) float, w_h: (d, rbit) float -> (s, rbit//32) uint32.
    Batched/multi-head shapes are handled by ``ops.hash_encode`` via vmap.
    """
    interpret = runtime.resolve_interpret(interpret)
    s, d = x.shape
    block_s = runtime.encode_block_s(block_s, size=s, dtype=x.dtype)
    d2, rbit = w_h.shape
    assert d == d2, (x.shape, w_h.shape)
    assert rbit % WORD_BITS == 0
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        functools.partial(_hash_encode_kernel, rbit=rbit),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((d, rbit), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, rbit // WORD_BITS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, rbit // WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x, w_h)
