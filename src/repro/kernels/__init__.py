"""Pallas TPU kernels for HATA's compute hot-spots (paper §4).

<name>.py   pl.pallas_call + BlockSpec kernels (validated interpret=True)
ops.py      batched jit wrappers with pallas/xla dispatch
ref.py      pure-jnp oracles (ground truth + dry-run execution path)
"""
from repro.kernels import ops, ref, runtime
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import (flash_decode,
                                        flash_decode_gathered,
                                        flash_decode_gathered_batched,
                                        flash_decode_gathered_stats_batched,
                                        mla_decode_gathered_batched)
from repro.kernels.hamming_score import (hamming_score,
                                         hamming_score_batched,
                                         hamming_score_latent)
from repro.kernels.hash_encode import hash_encode

__all__ = ["ops", "ref", "runtime", "flash_attention", "flash_decode",
           "flash_decode_gathered", "flash_decode_gathered_batched",
           "flash_decode_gathered_stats_batched",
           "mla_decode_gathered_batched", "hamming_score",
           "hamming_score_batched", "hamming_score_latent",
           "hash_encode"]
