"""Batched, jit-friendly wrappers over the Pallas kernels.

Every op has two execution paths selected by :func:`set_impl` /
:func:`get_impl`:

``pallas``  — the TPU kernels (interpret=True on CPU). Used by kernel
              tests and by real-TPU deployments.
``xla``     — pure-jnp implementations that compute the *same math*
              (chunked flash-style attention, einsum hash encode). Used
              for the 512-device dry-runs — Pallas interpret would inline
              the grid loop into the HLO and distort cost analysis — and
              everywhere gradients are needed.

The xla attention is the numerical oracle family from ``ref.py`` made
batched + memory-safe (chunked online softmax, never materializing an
(S, S) score matrix).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import hamming_score as _hs
from repro.kernels import hash_encode as _he
from repro.kernels import ref, runtime

WORD_BITS = ref.WORD_BITS

_IMPL = "xla" if jax.default_backend() == "cpu" else "pallas"
# interpret-mode selection and block sizes live in kernels/runtime.py:
# auto (interpret iff not on TPU), overridable via REPRO_PALLAS_INTERPRET
# and REPRO_*_BLOCK_* env knobs. The kernel entry points resolve their
# ``None`` defaults there, so the wrappers below simply omit the args.


def get_impl() -> str:
    return _IMPL


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("pallas", "xla"), impl
    _IMPL = impl


@contextlib.contextmanager
def use_impl(impl: str):
    prev = get_impl()
    set_impl(impl)
    try:
        yield
    finally:
        set_impl(prev)


# ---------------------------------------------------------------------------
# HashEncode
# ---------------------------------------------------------------------------
# Hash weights come in two forms everywhere in the repo: a plain array
# (linear projection, paper Eq. 9) or a dict {"w1", "b1", "w2"} (the
# trained non-linear variant — a 2-layer MLP before sign). The wrappers
# below dispatch on the form so every caller (dense, paged, offloaded,
# MLA, SP) carries either transparently.

def hash_encode(x: jax.Array, w_h) -> jax.Array:
    """x: (..., s, d) -> (..., s, rbit//32) uint32.

    w_h: (d, rbit) linear weights, or {"w1": (d, hidden),
    "b1": (hidden,), "w2": (hidden, rbit)} MLP weights.
    The encode is row-independent under one shared weight, so batch
    dims fold into rows: one Pallas dispatch regardless of rank, where
    a vmap would emit a kernel call per leading-dim lane.
    """
    if isinstance(w_h, dict):
        if get_impl() == "xla":
            return ref.hash_encode_mlp_ref(x, w_h["w1"], w_h["b1"],
                                           w_h["w2"])
        lead = x.shape[:-1]
        out = _he.hash_encode_mlp(x.reshape(-1, x.shape[-1]),
                                  w_h["w1"], w_h["b1"], w_h["w2"])
        return out.reshape(*lead, out.shape[-1])
    if get_impl() == "xla":
        return ref.hash_encode_ref(x, w_h)
    lead = x.shape[:-1]
    out = _he.hash_encode(x.reshape(-1, x.shape[-1]), w_h)
    return out.reshape(*lead, out.shape[-1])


def hash_encode_heads(x: jax.Array, w_h) -> jax.Array:
    """Per-head weights. x: (B, S, H, d), w_h: (H, d, rbit) or
    {"w1": (H, d, hidden), "b1": (H, hidden), "w2": (H, hidden, rbit)}
    -> (B, S, H, rbit//32).

    Pallas impl: one (H, S-blocks) grid dispatch with the batch folded
    into the tile (``hash_encode.hash_encode_heads``) — the former
    per-(batch, head) vmap launched B*H kernels. The MLP form adds one
    fused MXU matmul per grid step (``hash_encode_heads_mlp``).
    """
    if isinstance(w_h, dict):
        if get_impl() == "xla":
            hid = jax.nn.relu(
                jnp.einsum("bshd,hdm->bshm", x.astype(jnp.float32),
                           w_h["w1"].astype(jnp.float32))
                + w_h["b1"].astype(jnp.float32)[None, None])
            proj = jnp.einsum("bshm,hmr->bshr", hid,
                              w_h["w2"].astype(jnp.float32))
            return ref.bitpack_ref((proj >= 0).astype(jnp.uint32))
        return _he.hash_encode_heads_mlp(x, w_h["w1"], w_h["b1"],
                                         w_h["w2"])
    if get_impl() == "xla":
        proj = jnp.einsum("bshd,hdr->bshr", x.astype(jnp.float32),
                          w_h.astype(jnp.float32))
        return ref.bitpack_ref((proj >= 0).astype(jnp.uint32))
    return _he.hash_encode_heads(x, w_h)


# ---------------------------------------------------------------------------
# Hamming score
# ---------------------------------------------------------------------------
def hamming_scores(q_codes: jax.Array, k_codes: jax.Array, *,
                   rbit: int, block_s: Optional[int] = None) -> jax.Array:
    """q_codes: (B, H_kv, G, W), k_codes: (B, S, H_kv, W) -> (B, H_kv, S).

    Pallas impl: one batched dispatch with a (B, H_kv, S-blocks) grid
    streaming the code cache in its native layout.
    """
    if get_impl() == "xla":
        return ref.hamming_score_batched_ref(q_codes, k_codes, rbit)
    return _hs.hamming_score_batched(q_codes, k_codes, rbit=rbit,
                                     block_s=block_s)


def hamming_scores_latent(q_codes: jax.Array, k_codes: jax.Array, *,
                          rbit: int,
                          block_s: Optional[int] = None) -> jax.Array:
    """Single-stream (MLA latent) match scores.

    q_codes: (B, H, W), k_codes: (B, S, W) -> (B, S). Pallas impl: the
    same batched Hamming dispatch, with the shared latent stream cast as
    one kv head whose group is all H query heads.
    """
    if get_impl() == "xla":
        return ref.hamming_score_latent_ref(q_codes, k_codes, rbit)
    return _hs.hamming_score_latent(q_codes, k_codes, rbit=rbit,
                                    block_s=block_s)


def _pool_logical_view(pool: jax.Array,
                       block_table: jax.Array) -> jax.Array:
    """XLA reference paths only — the pallas paged kernels read pages
    in place. One address-math implementation for the whole repo: this
    defers to ``core.paged_cache.logical_view`` (function-level import;
    the top-level core -> kernels dependency runs the other way)."""
    from repro.core.paged_cache import logical_view
    return logical_view(pool, block_table)


def hamming_scores_paged(q_codes: jax.Array, codes_pool: jax.Array,
                         block_table: jax.Array, n_valid: jax.Array, *,
                         rbit: int) -> jax.Array:
    """Match scores over a paged code pool, invalid rows at -1.

    q_codes: (B, H_kv, G, W); codes_pool: (P, page, H_kv, W);
    block_table: (B, T) int32; n_valid: scalar or (B,). Returns
    (B, H_kv, T*page) int32 — bit-identical to
    ``mask_scores(hamming_scores(...), n_valid)`` over the contiguous
    cache holding the same rows. Pallas impl: the block-table-indirect
    kernel (garbage pages masked in-kernel); xla impl: gather the
    logical view, score, mask.
    """
    b = q_codes.shape[0]
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    if get_impl() == "xla":
        view = _pool_logical_view(codes_pool, block_table)
        scores = ref.hamming_score_batched_ref(q_codes, view, rbit)
        s = scores.shape[-1]
        valid = jnp.arange(s)[None, None] < nv[:, None, None]
        return jnp.where(valid, scores, -1)
    return _hs.hamming_score_paged(q_codes, codes_pool,
                                   block_table, nv, rbit=rbit)


def hamming_scores_latent_paged(q_codes: jax.Array, codes_pool: jax.Array,
                                block_table: jax.Array,
                                n_valid: jax.Array, *,
                                rbit: int) -> jax.Array:
    """Latent-stream paged match scores, invalid rows at -1.

    q_codes: (B, H, W); codes_pool: (P, page, W); block_table: (B, T);
    n_valid: scalar or (B,). Returns (B, T*page) int32.
    """
    b = q_codes.shape[0]
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    if get_impl() == "xla":
        view = _pool_logical_view(codes_pool, block_table)
        scores = ref.hamming_score_latent_ref(q_codes, view, rbit)
        valid = jnp.arange(scores.shape[-1])[None] < nv[:, None]
        return jnp.where(valid, scores, -1)
    return _hs.hamming_score_latent_paged(q_codes, codes_pool,
                                          block_table, nv, rbit=rbit)


def hamming_scores_vmapped(q_codes: jax.Array, k_codes: jax.Array, *,
                           rbit: int) -> jax.Array:
    """Legacy per-(B, H_kv) vmap dispatch of the single-head kernel.

    Kept as the baseline for benchmarks/decode_efficiency.py and the
    differential tests; the vmap forces a transposed copy of the code
    cache, which is exactly what ``hamming_scores`` now avoids.
    """
    if get_impl() == "xla":
        return ref.hamming_score_batched_ref(q_codes, k_codes, rbit)
    fn = functools.partial(_hs.hamming_score, rbit=rbit)
    fn = jax.vmap(fn, in_axes=(0, 1), out_axes=0)   # kv heads
    fn = jax.vmap(fn, in_axes=(0, 0))               # batch
    return fn(q_codes, k_codes)


# ---------------------------------------------------------------------------
# Attention (prefill / training)
# ---------------------------------------------------------------------------
def _xla_flash_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: Optional[int], q_offset,
                   chunk_q: int = 1024, chunk_k: int = 1024) -> jax.Array:
    """Chunked online-softmax GQA attention, O(chunk_q*chunk_k) memory.

    q: (B, Sq, H, d), k/v: (B, Sk, H_kv, d) -> (B, Sq, H, d).
    ``q_offset``: traced scalar or (B,) absolute position of q[:, 0].
    Differentiable (plain lax.scan); the dry-run path and the
    differential oracle for the batched Pallas prefill kernels.
    """
    b, sq, h, d = q.shape
    sk, h_kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // h_kv
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    sk_valid = sk
    pad_q = (-sq) % cq
    pad_k = (-sk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        sk += pad_k
    nq, nk = sq // cq, sk // ck

    qf = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, nq, cq, h_kv, g, d)
    qf = jnp.moveaxis(qf, 1, 0)                     # (nq, b, cq, h_kv, g, d)
    kf = jnp.moveaxis(k.reshape(b, nk, ck, h_kv, d), 1, 0)
    vf = jnp.moveaxis(v.reshape(b, nk, ck, h_kv, dv), 1, 0)

    def q_chunk(qi, qc):
        # (1|B, cq): per-row offsets serve slots at different depths
        qpos = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (-1, 1)) \
            + qi * cq + jnp.arange(cq)[None]

        def kv_step(carry, xs):
            m, l, acc = carry
            ki, kc, vc = xs
            kpos = ki * ck + jnp.arange(ck)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qc,
                                kc.astype(jnp.float32))
            mask = jnp.broadcast_to((kpos < sk_valid)[None, None, :],
                                    (qpos.shape[0], cq, ck))
            if causal:
                mask = mask & (kpos[None, None, :] <= qpos[:, :, None])
            if window is not None:
                mask = mask & (kpos[None, None, :]
                               > qpos[:, :, None] - window)
            logits = jnp.where(mask[:, None, None], logits, _fa.NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, -1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, -1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h_kv, g, cq), _fa.NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h_kv, g, cq), jnp.float32)
        acc0 = jnp.zeros((b, h_kv, g, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0), (jnp.arange(nk), kf, vf))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (b, h_kv, g, cq, dv)
        return jnp.moveaxis(out, 3, 1)                 # (b, cq, h_kv, g, dv)

    outs = jax.lax.map(lambda args: q_chunk(*args), (jnp.arange(nq), qf))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dv)
    if pad_q:
        out = out[:, :sq - pad_q]
    return out.astype(q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset=0) -> jax.Array:
    """Batched GQA attention. q: (B, Sq, H, d), k/v: (B, Sk, H_kv, d).

    ``q_offset`` (scalar or (B,)) is *traced* on both impls. Pallas
    impl: one batched flash-prefill dispatch with the GQA group folded
    into the q tile and K/V streamed in their native layout — the
    former per-(B, H) vmap of the single-head kernel made XLA
    ``jnp.repeat`` the whole K/V cache ``g`` times before dispatch.
    """
    if get_impl() == "xla":
        return _xla_flash_gqa(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    return _fa.flash_prefill_batched(q, k, v,
                                     jnp.asarray(q_offset, jnp.int32),
                                     causal=causal, window=window)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: Optional[jax.Array] = None) -> jax.Array:
    """One-token dense decode. q: (B, H, d), k/v: (B, S, H_kv, d)."""
    b, h, d = q.shape
    s, h_kv = k.shape[1], k.shape[2]
    g = h // h_kv
    if get_impl() == "xla":
        qg = q.reshape(b, h_kv, g, d)
        logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(k.dtype), k,
                            preferred_element_type=jnp.float32) \
            * (d ** -0.5)
        if valid_len is not None:
            pos = jnp.arange(s)
            vl = jnp.asarray(valid_len).reshape(-1, 1, 1, 1)
            logits = jnp.where(pos[None, None, None] < vl, logits,
                               _fa.NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, h, d).astype(q.dtype)
    vl = (jnp.full((b,), s, jnp.int32) if valid_len is None
          else jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,)))
    fn = _fd.flash_decode
    qg = q.reshape(b, h_kv, g, d)
    kh = jnp.moveaxis(k, 2, 1)                       # (B, H_kv, S, d)
    vh = jnp.moveaxis(v, 2, 1)
    out = jax.vmap(jax.vmap(fn, in_axes=(0, 0, 0, None)),
                   in_axes=(0, 0, 0, 0))(qg, kh, vh, vl)
    return out.reshape(b, h, d)


def gather_decode_attention(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, idx: jax.Array, *,
                            sel_valid: Optional[jax.Array] = None,
                            fused: bool = False,
                            block_k: Optional[int] = None) -> jax.Array:
    """HATA sparse decode: attend over selected rows only.

    q: (B, H, d), caches: (B, S, H_kv, d), idx: (B, H_kv, k) int32,
    sel_valid: optional (B, H_kv, k) bool — invalid selections are
    masked out of the softmax (HATA short-cache exactness).
    ``fused=True`` uses the batched scalar-prefetch fused-gather kernel
    (pallas impl only); otherwise gather-then-flash-decode
    ("gather_dense").

    On the pallas impl (both paths) ``sel_valid`` must be a *prefix*
    mask (invalid entries sorted last), which lax.top_k guarantees
    under the match-score convention: invalid rows carry score -1,
    below the floor of 0 for valid rows. The xla impl accepts any mask.
    """
    b, h, d = q.shape
    h_kv = k_cache.shape[2]
    g = h // h_kv
    if fused and get_impl() == "pallas":
        qg = q.reshape(b, h_kv, g, d)
        nv = (None if sel_valid is None
              else jnp.sum(sel_valid.astype(jnp.int32), axis=-1))
        out = _fd.flash_decode_gathered_batched(qg, k_cache, v_cache,
                                                idx, nv, block_k=block_k)
        return out.reshape(b, h, d)
    if get_impl() == "xla":
        return ref.masked_gather_decode_ref(q, k_cache, v_cache, idx,
                                            sel_valid)
    # gather_dense: one fused XLA gather to a (k, d) compacted buffer.
    kg = jnp.take_along_axis(jnp.moveaxis(k_cache, 2, 1),
                             idx[..., None], axis=2)  # (B, H_kv, k, d)
    vg = jnp.take_along_axis(jnp.moveaxis(v_cache, 2, 1),
                             idx[..., None], axis=2)
    fn = _fd.flash_decode
    qg = q.reshape(b, h_kv, g, d)
    if sel_valid is None:
        out = jax.vmap(jax.vmap(fn, in_axes=(0, 0, 0, None)),
                       in_axes=(0, 0, 0, None))(qg, kg, vg, None)
    else:
        n_valid = jnp.sum(sel_valid.astype(jnp.int32), axis=-1)
        out = jax.vmap(jax.vmap(fn))(qg, kg, vg, n_valid)
    return out.reshape(b, h, d)


def gather_decode_attention_paged(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, phys_idx: jax.Array,
                                  *, sel_valid: Optional[jax.Array] = None,
                                  block_k: Optional[int] = None,
                                  ) -> jax.Array:
    """HATA sparse decode over a shared page pool (block-table serving).

    q: (B, H, d); k_pool/v_pool: (P, page, H_kv, d) per-layer pools;
    phys_idx: (B, H_kv, k) int32 *physical* rows — the caller translated
    selected logical rows through its block table; sel_valid: optional
    prefix-validity mask as in :func:`gather_decode_attention`.
    Bit-identical to the contiguous fused path given equal rows.
    """
    b, h, d = q.shape
    h_kv = k_pool.shape[2]
    g = h // h_kv
    kf = k_pool.reshape((-1,) + k_pool.shape[2:])      # (N_phys, H_kv, d)
    vf = v_pool.reshape((-1,) + v_pool.shape[2:])
    if get_impl() == "xla":
        return ref.masked_gather_decode_pool_ref(q, kf, vf, phys_idx,
                                                 sel_valid)
    qg = q.reshape(b, h_kv, g, d)
    nv = (None if sel_valid is None
          else jnp.sum(sel_valid.astype(jnp.int32), axis=-1))
    out = _fd.flash_decode_gathered_paged(qg, kf, vf, phys_idx, nv,
                                          block_k=block_k)
    return out.reshape(b, h, d)


def mla_gather_decode_paged(q_lat: jax.Array, ckv_pool: jax.Array,
                            krope_pool: jax.Array, phys_idx: jax.Array,
                            *, lora_rank: int, scale: float,
                            n_valid: Optional[jax.Array] = None,
                            sel_mask: Optional[jax.Array] = None,
                            return_stats: bool = False,
                            block_k: Optional[int] = None):
    """Split-latent MLA gathered decode over shared latent page pools.

    ckv_pool: (P, page, r), krope_pool: (P, page, rd); phys_idx: (B, k)
    int32 physical rows. Exactly one of ``n_valid`` (B,) prefix count /
    ``sel_mask`` (B, k) arbitrary mask (or neither). Returns o_lat
    (B, H, r) f32 (caller applies W_uv), or the unnormalized flash
    partials (m, l, o~) when ``return_stats`` (paged SP shards merge
    them across shards first).
    """
    assert n_valid is None or sel_mask is None, \
        "pass n_valid or sel_mask, not both"
    cf = ckv_pool.reshape((-1,) + ckv_pool.shape[2:])  # (N_phys, r)
    rf = krope_pool.reshape((-1,) + krope_pool.shape[2:])
    if get_impl() == "xla":
        mask = sel_mask
        if mask is None and n_valid is not None:
            k = phys_idx.shape[-1]
            mask = jnp.arange(k)[None, :] < jnp.reshape(
                jnp.asarray(n_valid), (-1, 1))
        return ref.mla_gather_decode_pool_ref(
            q_lat, cf, rf, phys_idx, mask, lora_rank=lora_rank,
            scale=scale, return_stats=return_stats)
    return _fd.mla_decode_gathered_paged(
        q_lat, cf, rf, phys_idx, n_valid, sel_mask,
        lora_rank=lora_rank, scale=scale, block_k=block_k,
        return_stats=return_stats)


def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_offset: jax.Array,
                    window: Optional[int] = None) -> jax.Array:
    """Chunked-prefill context attention: a chunk of fresh queries over
    a contiguous (or pre-gathered) KV view, causal at absolute positions.

    q: (B, C, H, d) the prefill chunk; k/v: (B, S_log, H_kv, d) (garbage
    rows sit at positions > the chunk's last row, so causality masks
    them); q_offset: *traced* scalar or (B,) — the tokens already in
    the cache. The pallas impl reads it through scalar prefetch, so one
    compiled chunk shape serves every chunk position; paged serving
    should prefer :func:`chunk_attention_paged`, which skips the
    gathered view entirely.
    """
    if get_impl() == "xla":
        return _xla_flash_gqa(q, k, v, causal=True, window=window,
                              q_offset=q_offset)
    return _fa.flash_prefill_batched(q, k, v,
                                     jnp.asarray(q_offset, jnp.int32),
                                     causal=True, window=window)


def chunk_attention_paged(q: jax.Array, k_pool: jax.Array,
                          v_pool: jax.Array, block_table: jax.Array,
                          q_offset: jax.Array, *,
                          window: Optional[int] = None) -> jax.Array:
    """Chunked-prefill context attention over a paged KV pool.

    q: (B, C, H, d); k_pool/v_pool: (P, page, H_kv, d) shared per-layer
    page pools; block_table: (B, T) int32; q_offset: traced scalar or
    (B,). Pallas impl: the block-table flash-prefill kernel fetches
    pages in place through the scalar-prefetched index_map — no
    gathered dense logical view exists anywhere on the path. xla impl:
    gather the logical view, then the online-softmax reference (the
    differential oracle). Causality at absolute positions masks every
    garbage row the table can name, so both impls equal the contiguous
    :func:`chunk_attention` over the same logical view.
    """
    if get_impl() == "xla":
        k_view = _pool_logical_view(k_pool, block_table)
        v_view = _pool_logical_view(v_pool, block_table)
        return _xla_flash_gqa(q, k_view, v_view, causal=True,
                              window=window, q_offset=q_offset)
    return _fa.flash_prefill_paged(q, k_pool, v_pool, block_table,
                                   jnp.asarray(q_offset, jnp.int32),
                                   window=window)


def mla_chunk_attention(q_lat: jax.Array, ckv: jax.Array,
                        krope: jax.Array, q_offset: jax.Array, *,
                        lora_rank: int, scale: float) -> jax.Array:
    """Split-latent MLA chunked-prefill attention (contiguous caches).

    q_lat: (B, C, H, r+rd) absorbed queries; ckv: (B, S, r); krope:
    (B, S, rd); q_offset: traced scalar or (B,). Returns o_lat
    (B, C, H, r) f32 — the caller applies W_uv. Logits are computed in
    latent space (q_c·c + q_r·k_r), so no per-head K/V is materialized
    from the latent stream on either impl.
    """
    if get_impl() == "xla":
        return ref.mla_chunk_attention_ref(q_lat, ckv, krope, q_offset,
                                           lora_rank=lora_rank,
                                           scale=scale)
    return _fa.mla_prefill_batched(q_lat, ckv, krope,
                                   jnp.asarray(q_offset, jnp.int32),
                                   lora_rank=lora_rank, scale=scale)


def mla_chunk_attention_paged(q_lat: jax.Array, ckv_pool: jax.Array,
                              krope_pool: jax.Array,
                              block_table: jax.Array,
                              q_offset: jax.Array, *, lora_rank: int,
                              scale: float) -> jax.Array:
    """Split-latent MLA chunked-prefill attention over paged latent
    pools — the MLA twin of :func:`chunk_attention_paged`.

    ckv_pool: (P, page, r), krope_pool: (P, page, rd); block_table:
    (B, T) int32; q_offset: traced scalar or (B,). Returns o_lat
    (B, C, H, r) f32.
    """
    if get_impl() == "xla":
        ckv_view = _pool_logical_view(ckv_pool, block_table)
        kr_view = _pool_logical_view(krope_pool, block_table)
        return ref.mla_chunk_attention_ref(q_lat, ckv_view, kr_view,
                                           q_offset,
                                           lora_rank=lora_rank,
                                           scale=scale)
    return _fa.mla_prefill_paged(q_lat, ckv_pool, krope_pool,
                                 block_table,
                                 jnp.asarray(q_offset, jnp.int32),
                                 lora_rank=lora_rank, scale=scale)


def gather_decode_attention_vmapped(q: jax.Array, k_cache: jax.Array,
                                    v_cache: jax.Array,
                                    idx: jax.Array) -> jax.Array:
    """Legacy per-(B, H_kv) vmap dispatch of the fused-gather kernel.

    No validity masking (callers had to clamp idx and recompute an
    exact correction on the side — the seed's double-compute). Kept as
    the benchmark baseline for the batched pipeline.
    """
    b, h, d = q.shape
    h_kv = k_cache.shape[2]
    g = h // h_kv
    if get_impl() != "pallas":
        return ref.masked_gather_decode_ref(q, k_cache, v_cache, idx)
    fn = _fd.flash_decode_gathered
    qg = q.reshape(b, h_kv, g, d)
    kh = jnp.moveaxis(k_cache, 2, 1)
    vh = jnp.moveaxis(v_cache, 2, 1)
    out = jax.vmap(jax.vmap(fn))(qg, kh, vh, idx)
    return out.reshape(b, h, d)


def gather_decode_stats(q: jax.Array, k_cache: jax.Array,
                        v_cache: jax.Array, idx: jax.Array,
                        sel_mask: Optional[jax.Array] = None, *,
                        block_k: Optional[int] = None,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gathered flash partials for the sequence-parallel HATA shards.

    q: (B, H, d), caches: (B, S, H_kv, d) — the *local* shard in native
    layout — idx: (B, H_kv, R) int32 in-range local rows, sel_mask:
    optional (B, H_kv, R) bool (arbitrary, not necessarily a prefix:
    the two_stage mode keeps only the global winners this shard owns).
    Returns unnormalized (m, l, o~) with m/l: (B, H_kv, G) and
    o~: (B, H_kv, G, d), ready for ``merge_partial_softmax``.
    """
    b, h, d = q.shape
    h_kv = k_cache.shape[2]
    g = h // h_kv
    if get_impl() == "xla":
        return ref.gather_decode_stats_ref(q, k_cache, v_cache, idx,
                                           sel_mask)
    qg = q.reshape(b, h_kv, g, d)
    return _fd.flash_decode_gathered_stats_batched(
        qg, k_cache, v_cache, idx, None, sel_mask, block_k=block_k)


def gather_decode_stats_paged(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, phys_idx: jax.Array,
                              sel_mask: Optional[jax.Array] = None, *,
                              block_k: Optional[int] = None,
                              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gathered flash partials over a shared page pool — the paged twin
    of :func:`gather_decode_stats` for sequence-parallel shards whose
    local slice lives in pages.

    q: (B, H, d); k_pool/v_pool: (P, page, H_kv, d) per-layer pools;
    phys_idx: (B, H_kv, R) int32 *physical* rows (local logical winners
    translated through the shard's block table before the call);
    sel_mask: optional arbitrary (B, H_kv, R) ownership mask. Returns
    unnormalized (m, l, o~) ready for ``merge_partial_softmax`` —
    bit-identical to :func:`gather_decode_stats` over a contiguous
    slice holding the same rows.
    """
    b, h, d = q.shape
    h_kv = k_pool.shape[2]
    g = h // h_kv
    kf = k_pool.reshape((-1,) + k_pool.shape[2:])      # (N_phys, H_kv, d)
    vf = v_pool.reshape((-1,) + v_pool.shape[2:])
    if get_impl() == "xla":
        return ref.gather_decode_stats_pool_ref(q, kf, vf, phys_idx,
                                                sel_mask)
    qg = q.reshape(b, h_kv, g, d)
    return _fd.flash_decode_gathered_stats_paged(
        qg, kf, vf, phys_idx, None, sel_mask, block_k=block_k)


def mla_gather_decode(q_lat: jax.Array, ckv: jax.Array, krope: jax.Array,
                      idx: jax.Array, *, lora_rank: int, scale: float,
                      n_valid: Optional[jax.Array] = None,
                      sel_mask: Optional[jax.Array] = None,
                      return_stats: bool = False,
                      block_k: Optional[int] = None):
    """Split-latent MLA gathered decode over the shared latent stream.

    q_lat: (B, H, r+rd) absorbed queries, ckv: (B, S, r), krope:
    (B, S, rd), idx: (B, k) int32 selected rows. Exactly one of
    ``n_valid`` (B,) prefix count / ``sel_mask`` (B, k) arbitrary mask
    (or neither: all selections valid). Returns o_lat (B, H, r) f32 —
    the caller applies W_uv — or the unnormalized flash partials
    (m, l, o~) when ``return_stats`` (SP shards merge them first).
    """
    # "exactly one" is load-bearing: the xla branch lowers n_valid to a
    # mask, so passing both would AND on pallas but drop n_valid on xla
    assert n_valid is None or sel_mask is None, \
        "pass n_valid or sel_mask, not both"
    if get_impl() == "xla":
        mask = sel_mask
        if mask is None and n_valid is not None:
            k = idx.shape[-1]
            mask = jnp.arange(k)[None, :] < jnp.reshape(
                jnp.asarray(n_valid), (-1, 1))
        return ref.mla_gather_decode_ref(q_lat, ckv, krope, idx, mask,
                                         lora_rank=lora_rank, scale=scale,
                                         return_stats=return_stats)
    return _fd.mla_decode_gathered_batched(
        q_lat, ckv, krope, idx, n_valid, sel_mask, lora_rank=lora_rank,
        scale=scale, block_k=block_k, return_stats=return_stats)


# ---------------------------------------------------------------------------
# Offload tier: the host-gather boundary + PCIe accounting hooks
# ---------------------------------------------------------------------------
# The tiered OffloadedView (core/cache_view.py) resolves its top-k
# winners to HOST pages, gathers the compact rows there, and uploads
# only those. The device-side boundary is the *_staged trio below: the
# gather already happened on the host, so the index map is the
# identity over the staging buffer and the same fused kernels run
# unchanged — bit-identical to the contiguous/paged paths given equal
# rows. Transfers funnel through device_put_accounted so benchmarks
# and serving stats can meter PCIe traffic without threading a ledger
# through every call site.
_PCIE_LISTENER = None


def set_pcie_listener(fn):
    """Install a callback ``fn(nbytes, direction)`` fired on every
    accounted host<->device transfer (direction: "up" | "down").
    Returns the previous listener; pass None to uninstall."""
    global _PCIE_LISTENER
    prev = _PCIE_LISTENER
    _PCIE_LISTENER = fn
    return prev


def account_pcie(nbytes: int, direction: str = "up") -> None:
    if _PCIE_LISTENER is not None:
        _PCIE_LISTENER(int(nbytes), direction)


def device_put_accounted(host_array, direction: str = "up") -> jax.Array:
    """Host -> device upload, metered. The one place offload-tier rows
    cross PCIe upward, so byte accounting can't drift from the data
    movement it claims to describe."""
    account_pcie(host_array.nbytes, direction)
    return jnp.asarray(host_array)


def _identity_idx(b: int, h_kv: int, k: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, None],
                            (b, h_kv, k))


def gather_decode_attention_staged(q: jax.Array, k_stage: jax.Array,
                                   v_stage: jax.Array, *,
                                   sel_valid: Optional[jax.Array] = None,
                                   block_k: Optional[int] = None
                                   ) -> jax.Array:
    """Sparse decode over host-gathered, PCIe-staged rows.

    q: (B, H, d); k_stage/v_stage: (B, k, H_kv, d) — slot j of head h
    holds that head's j-th selected row (per-head host gather), so the
    identity index map recovers exactly the contiguous fused-gather
    semantics; sel_valid: optional (B, H_kv, k) prefix mask.
    """
    b = q.shape[0]
    h_kv, k = k_stage.shape[2], k_stage.shape[1]
    return gather_decode_attention(q, k_stage, v_stage,
                                   _identity_idx(b, h_kv, k),
                                   sel_valid=sel_valid, fused=True,
                                   block_k=block_k)


def gather_decode_stats_staged(q: jax.Array, k_stage: jax.Array,
                               v_stage: jax.Array,
                               sel_mask: Optional[jax.Array] = None, *,
                               block_k: Optional[jax.Array] = None
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gathered flash partials over staged rows (arbitrary sel_mask —
    the SP ownership filter), identity index map."""
    b = q.shape[0]
    h_kv, k = k_stage.shape[2], k_stage.shape[1]
    return gather_decode_stats(q, k_stage, v_stage,
                               _identity_idx(b, h_kv, k), sel_mask,
                               block_k=block_k)


def mla_gather_decode_staged(q_lat: jax.Array, ckv_stage: jax.Array,
                             krope_stage: jax.Array, *, lora_rank: int,
                             scale: float,
                             n_valid: Optional[jax.Array] = None,
                             sel_mask: Optional[jax.Array] = None,
                             return_stats: bool = False,
                             block_k: Optional[int] = None):
    """Split-latent MLA decode over staged latent rows.

    ckv_stage: (B, k, r), krope_stage: (B, k, rd) — the host gathered
    the selected latent rows; the identity index map feeds the same
    contiguous fused kernel.
    """
    b, k = ckv_stage.shape[:2]
    idx = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None], (b, k))
    return mla_gather_decode(q_lat, ckv_stage, krope_stage, idx,
                             lora_rank=lora_rank, scale=scale,
                             n_valid=n_valid, sel_mask=sel_mask,
                             return_stats=return_stats, block_k=block_k)


def mla_gather_decode_multilayer(q_lat: jax.Array, ckv: jax.Array,
                                 krope: jax.Array, idx: jax.Array, *,
                                 lora_rank: int, scale: float,
                                 n_valid: Optional[jax.Array] = None,
                                 sel_mask: Optional[jax.Array] = None,
                                 return_stats: bool = False,
                                 block_k: Optional[int] = None):
    """Multi-layer split-latent gathered decode in ONE dispatch.

    q_lat: (L, B, H, r+rd) absorbed queries, ckv: (L, B, S, r) /
    krope: (L, B, S, rd) layer-stacked latent caches, idx: (L, B, k)
    per-layer selected rows; n_valid (L, B) / sel_mask (L, B, k) as in
    :func:`mla_gather_decode`. Returns o_lat (L, B, H, r) f32, or the
    (m, l, o~) flash partials with a leading L when ``return_stats``.

    The gather grid is embarrassingly parallel over (request, layer) —
    nothing in one lane's chunk walk reads another's — so L per-layer
    dispatches of grid (B,) fold into ONE dispatch of grid (L·B,) by
    reshaping the layer axis into the batch (a view on stacked
    storage). Bit-exact vs the per-layer loop: each folded lane runs
    the identical chunk walk over the identical rows.

    The serving decode wave can't use this *today* — selection at
    layer l needs layer l-1's residual output, so its per-layer
    gathers are inherently sequential (see DESIGN.md §3). It serves
    the callers whose selections coexist: speculative-verification
    waves, teacher top-k label extraction over a whole model, and the
    offload tier's batched multi-layer staging
    (``mla_gather_decode_staged`` folds the same way — stack the
    staged (B, k, r) rows over L and fold L into B).
    """
    assert n_valid is None or sel_mask is None, \
        "pass n_valid or sel_mask, not both"
    l, b, h, qdim = q_lat.shape
    l2, b2, s, r = ckv.shape
    assert (l, b) == (l2, b2) and (l, b) == idx.shape[:2], (
        q_lat.shape, ckv.shape, idx.shape)
    rd = krope.shape[-1]
    out = mla_gather_decode(
        q_lat.reshape(l * b, h, qdim),
        ckv.reshape(l * b, s, r),
        krope.reshape(l * b, s, rd),
        idx.reshape(l * b, -1),
        lora_rank=lora_rank, scale=scale,
        n_valid=(None if n_valid is None
                 else jnp.reshape(jnp.asarray(n_valid), (l * b,))),
        sel_mask=(None if sel_mask is None
                  else sel_mask.reshape(l * b, -1)),
        return_stats=return_stats, block_k=block_k)
    if return_stats:
        m, lsum, acc = out
        return (m.reshape((l, b) + m.shape[1:]),
                lsum.reshape((l, b) + lsum.shape[1:]),
                acc.reshape((l, b) + acc.shape[1:]))
    return out.reshape((l, b) + out.shape[1:])
