"""Measured block-size search for the Pallas kernel stack.

``search_all()`` sweeps each registered kernel family's tunable tile
parameters (block_s / block_q / block_k — the gather kernels' block_k
IS their double-buffered DMA chunk — and the pool page size) over a
per-axis candidate ladder, times every candidate, and **asserts
bit-exactness against the untuned baseline at every candidate**:

  * Row-partition knobs (hash_encode/hamming block_s, prefill/attn
    block_q) only re-tile independent output rows — every candidate is
    bit-identical to the baseline and competes on wallclock.
  * KV-axis knobs (all block_k, page_size) change the online-softmax
    accumulation *order*. Unless a candidate collapses to the
    baseline's effective chunking (``min(block_k, size)`` equal), its
    output differs in the last ulp — such candidates are REJECTED:
    measured and reported, but never emitted into a tuning table, so
    switching tables can never change model outputs.

``emit_table()`` turns the surviving winners into a
:mod:`repro.kernels.runtime` tuning-table object (bucket = pow-2
ceiling of the searched size, backend = the machine that measured it)
ready to serialize to ``REPRO_TUNING_TABLE`` or merge into
``tuning/default.json``. The benchmark harness front-end is
``benchmarks/autotune_sweep.py``; the per-kernel achieved-vs-peak HBM
bandwidth derived from these measurements lands in
``benchmarks/roofline.py``.

The search runs wherever it's invoked (interpret mode off-TPU —
wallclock then prices the grid walk, not the memory system, which
still ranks row-partition tilings usefully; compiled on TPU). Inputs
are seeded and shapes deliberately moderate so a full CPU sweep stays
in CI budget.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import runtime

# package __init__ re-exports kernel *functions* under the submodule
# names, so attribute imports resolve to PjitFunctions — go through
# importlib for the modules themselves
_hash_encode = importlib.import_module("repro.kernels.hash_encode")
_hamming = importlib.import_module("repro.kernels.hamming_score")
_fdec = importlib.import_module("repro.kernels.flash_decode")
_fattn = importlib.import_module("repro.kernels.flash_attention")

Config = Dict[str, int]


def _time_us(fn: Callable[[], jax.Array], reps: int = 3) -> float:
    """Median wall-clock per call in µs; one warmup call compiles."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _bit_exact(a, b) -> Tuple[bool, float]:
    """(exactly equal, max abs diff) over a pytree of arrays."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    exact, maxdiff = True, 0.0
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype != y.dtype or x.shape != y.shape:
            return False, float("inf")
        if not np.array_equal(x, y):
            exact = False
            maxdiff = max(maxdiff,
                          float(np.max(np.abs(x.astype(np.float64)
                                              - y.astype(np.float64)))))
    return exact, maxdiff


@dataclasses.dataclass
class CandidateResult:
    config: Config
    us: float
    exact: bool
    maxdiff: float


@dataclasses.dataclass
class SearchResult:
    kernel: str
    backend: str
    dtype: str
    size: int                    # the registry's bucket-axis value
    bytes_moved: int             # HBM bytes one call must move
    baseline: Config
    baseline_us: float
    candidates: List[CandidateResult]

    @property
    def accepted(self) -> List[CandidateResult]:
        return [c for c in self.candidates if c.exact]

    @property
    def rejected(self) -> List[CandidateResult]:
        return [c for c in self.candidates if not c.exact]

    @property
    def best(self) -> CandidateResult:
        """Fastest *bit-exact* candidate (baseline always qualifies)."""
        base = CandidateResult(dict(self.baseline), self.baseline_us,
                               True, 0.0)
        return min(self.accepted + [base], key=lambda c: c.us)

    def gbps(self, us: float) -> float:
        return self.bytes_moved / (us * 1e-6) / 1e9


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One searchable kernel: seeded inputs + a config-parameterized
    runner. ``sweep`` maps param -> candidate ladder; each axis is
    swept independently around the registry baseline (single-pass
    coordinate search — the axes are independent grid dims)."""
    kernel: str
    sweep: Dict[str, Sequence[int]]
    build: Callable[[], Tuple[Callable[[Config], jax.Array], int, int,
                              str]]
    # build() -> (run(config), size, bytes_moved, dtype_name)


def _baseline_config(kernel: str) -> Config:
    return {p: spec.default
            for p, spec in runtime.KERNELS[kernel].params.items()}


def _axis_candidates(kernel: str, sweep: Dict[str, Sequence[int]]
                     ) -> List[Config]:
    base = _baseline_config(kernel)
    out: List[Config] = []
    for param, ladder in sweep.items():
        for v in ladder:
            cfg = dict(base)
            cfg[param] = v
            if cfg != base and cfg not in out:
                out.append(cfg)
    return out


# ---------------------------------------------------------------------------
# Cases
# ---------------------------------------------------------------------------
def _case_hash_encode() -> Tuple[Callable, int, int, str]:
    s, d, rbit = 4096, 128, 128
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (s, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, rbit),
                          jnp.float32)

    def run(cfg: Config) -> jax.Array:
        return _hash_encode.hash_encode(x, w, block_s=cfg["block_s"])

    bytes_moved = x.nbytes + w.nbytes + s * (rbit // 32) * 4
    return run, s, bytes_moved, "float32"


def _case_hamming() -> Tuple[Callable, int, int, str]:
    b, h_kv, g, s, w = 4, 8, 4, 4096, 4   # rbit = 32 * w
    key = jax.random.PRNGKey(1)
    qc = jax.random.bits(key, (b, h_kv, g, w), jnp.uint32)
    kc = jax.random.bits(jax.random.fold_in(key, 1), (b, s, h_kv, w),
                         jnp.uint32)

    def run(cfg: Config) -> jax.Array:
        return _hamming.hamming_score_batched(qc, kc, rbit=32 * w,
                                              block_s=cfg["block_s"])

    bytes_moved = qc.nbytes + kc.nbytes + b * h_kv * s * 4
    return run, s, bytes_moved, "uint32"


def _case_gather() -> Tuple[Callable, int, int, str]:
    b, h_kv, g, s, d, k = 4, 8, 4, 4096, 64, 256
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, h_kv, g, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h_kv, d),
                           jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h_kv, d),
                           jnp.float32)
    idx = jnp.argsort(
        jax.random.uniform(jax.random.fold_in(key, 3), (b, h_kv, s)),
        axis=-1)[..., :k].astype(jnp.int32)

    def run(cfg: Config) -> jax.Array:
        return _fdec.flash_decode_gathered_batched(
            q, kc, vc, idx, block_k=cfg["block_k"])

    # the point of the fused gather: HBM traffic is the k selected
    # row-pairs plus q/idx, not the caches
    bytes_moved = q.nbytes + idx.nbytes + 2 * b * h_kv * k * d * 4
    return run, k, bytes_moved, "float32"


def _case_flash_decode() -> Tuple[Callable, int, int, str]:
    g, s, d = 8, 4096, 64
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (g, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (s, d),
                           jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (s, d),
                           jnp.float32)

    def run(cfg: Config) -> jax.Array:
        return _fdec.flash_decode(q, kc, vc, block_k=cfg["block_k"])

    bytes_moved = q.nbytes + kc.nbytes + vc.nbytes + g * d * 4
    return run, s, bytes_moved, "float32"


def _case_prefill() -> Tuple[Callable, int, int, str]:
    b, sq, sk, h, h_kv, d = 2, 512, 2048, 8, 2, 64
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (b, sq, h, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1),
                           (b, sk, h_kv, d), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2),
                           (b, sk, h_kv, d), jnp.float32)
    off = jnp.full((b,), sk - sq, jnp.int32)

    def run(cfg: Config) -> jax.Array:
        return _fattn.flash_prefill_batched(
            q, kc, vc, off, block_q=cfg["block_q"],
            block_k=cfg["block_k"])

    bytes_moved = q.nbytes + kc.nbytes + vc.nbytes + b * sq * h * d * 4
    return run, sk, bytes_moved, "float32"


def _case_attn() -> Tuple[Callable, int, int, str]:
    s, d = 2048, 64
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (s, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (s, d),
                           jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (s, d),
                           jnp.float32)

    def run(cfg: Config) -> jax.Array:
        return _fattn.flash_attention(q, kc, vc,
                                      block_q=cfg["block_q"],
                                      block_k=cfg["block_k"])

    bytes_moved = 2 * (q.nbytes + kc.nbytes + vc.nbytes)  # q-loop reuse
    return run, s, bytes_moved, "float32"


def _case_paged_pool() -> Tuple[Callable, int, int, str]:
    # pool page size IS the paged-prefill kernel's kv tile: rebuild the
    # pool per candidate and run one chunk of paged prefill over it
    b, chunk, s_log, h, h_kv, d = 1, 128, 1024, 8, 2, 64
    key = jax.random.PRNGKey(6)
    q = jax.random.normal(key, (b, chunk, h, d), jnp.float32)
    k_rows = jax.random.normal(jax.random.fold_in(key, 1),
                               (s_log, h_kv, d), jnp.float32)
    v_rows = jax.random.normal(jax.random.fold_in(key, 2),
                               (s_log, h_kv, d), jnp.float32)

    def run(cfg: Config) -> jax.Array:
        page = cfg["page_size"]
        assert s_log % page == 0, (s_log, page)
        n_pages = s_log // page
        k_pool = k_rows.reshape(n_pages, page, h_kv, d)
        v_pool = v_rows.reshape(n_pages, page, h_kv, d)
        table = jnp.arange(n_pages, dtype=jnp.int32)[None, :]
        return _fattn.flash_prefill_paged(
            q, k_pool, v_pool, table,
            jnp.full((b,), s_log - chunk, jnp.int32))

    bytes_moved = (q.nbytes + k_rows.nbytes + v_rows.nbytes
                   + b * chunk * h * d * 4)
    return run, 1024, bytes_moved, "float32"


CASES: List[KernelCase] = [
    KernelCase("hash_encode",
               {"block_s": (128, 256, 1024, 2048, 4096)},
               _case_hash_encode),
    KernelCase("hamming_score",
               {"block_s": (256, 512, 1024, 4096)},
               _case_hamming),
    KernelCase("gather_decode",
               {"block_k": (32, 64, 256)},
               _case_gather),
    KernelCase("flash_decode",
               {"block_k": (256, 512, 2048, 4096)},
               _case_flash_decode),
    KernelCase("flash_prefill",
               {"block_q": (64, 128, 512),
                "block_k": (256, 1024, 2048)},
               _case_prefill),
    KernelCase("flash_attention",
               {"block_q": (256, 1024, 2048),
                "block_k": (256, 1024)},
               _case_attn),
    KernelCase("paged_pool",
               {"page_size": (64, 128, 256)},
               _case_paged_pool),
]


def search(case: KernelCase, reps: int = 3) -> SearchResult:
    """Sweep one kernel. Every candidate is checked bit-exact against
    the baseline; failures are kept in the report but excluded from
    ``accepted``/``best`` (and the exclusion is *asserted* below)."""
    run, size, bytes_moved, dtype = case.build()
    baseline = _baseline_config(case.kernel)
    base_out = run(baseline)
    base_us = _time_us(lambda: run(baseline), reps)
    results: List[CandidateResult] = []
    for cfg in _axis_candidates(case.kernel, case.sweep):
        out = run(cfg)
        exact, maxdiff = _bit_exact(out, base_out)
        us = _time_us(lambda: run(cfg), reps)
        results.append(CandidateResult(cfg, us, exact, maxdiff))
    res = SearchResult(case.kernel, jax.default_backend(), dtype, size,
                       bytes_moved, baseline, base_us, results)
    # the contract the tuning table rests on: nothing that changes
    # numerics is ever emitted
    assert all(c.exact for c in res.accepted), res
    assert res.best.exact, res
    return res


def search_all(reps: int = 3,
               kernels: Optional[Sequence[str]] = None
               ) -> List[SearchResult]:
    return [search(c, reps) for c in CASES
            if kernels is None or c.kernel in kernels]


def _pow2_ceil(n: int) -> int:
    return 1 << (n - 1).bit_length()


def emit_table(results: Sequence[SearchResult],
               min_speedup: float = 1.05) -> Dict:
    """Winners -> a runtime tuning-table object. Only emits an entry
    when the best bit-exact candidate beats the baseline by
    ``min_speedup`` (jitter guard); the emitted object round-trips
    through :func:`repro.kernels.runtime.parse_table`."""
    entries = []
    for r in results:
        best = r.best
        if best.config == r.baseline:
            continue
        if r.baseline_us / best.us < min_speedup:
            continue
        entries.append({
            "kernel": r.kernel, "backend": r.backend, "dtype": r.dtype,
            "bucket": _pow2_ceil(r.size),
            "config": {k: int(v) for k, v in best.config.items()},
        })
    table = {"version": 1, "entries": entries}
    runtime.parse_table(table, "<autotune>")  # validate before handing out
    return table
