"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth: each Pallas kernel is validated
against its oracle with ``assert_allclose`` across shape/dtype sweeps
(tests/test_kernels.py). They are also the ``xla`` execution path used by
the 512-device dry-runs (Pallas interpret mode would inline the grid loop
into the HLO and distort the cost analysis).

Score convention
----------------
The paper (Alg. 3) computes Hamming distances and selects top-k; we store
*matching bits* ``score = rbit - popcount(xor)`` so that top-k is always
"largest score", matching the qk-score convention of the baselines.
GQA aggregation (paper §3.2) sums match scores over the query heads that
share a kv head.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Number of hash bits packed per cache word.
WORD_BITS = 32


# ---------------------------------------------------------------------------
# Bit packing
# ---------------------------------------------------------------------------
def bitpack_ref(bits: jax.Array) -> jax.Array:
    """Pack a trailing axis of {0,1} bits into uint32 words.

    bits: (..., rbit) any int/bool dtype with values in {0, 1}.
    returns (..., rbit // 32) uint32, word w = sum_j bits[32w+j] << j.
    """
    rbit = bits.shape[-1]
    assert rbit % WORD_BITS == 0, f"rbit={rbit} must be a multiple of 32"
    w = rbit // WORD_BITS
    b = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], w, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def bitunpack_ref(words: jax.Array, rbit: int) -> jax.Array:
    """Inverse of :func:`bitpack_ref` -> (..., rbit) int32 in {0,1}."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], rbit).astype(jnp.int32)


# ---------------------------------------------------------------------------
# HashEncode (paper Alg. 2): sign(x @ W_H) -> bitpack
# ---------------------------------------------------------------------------
def hash_encode_ref(x: jax.Array, w_h: jax.Array) -> jax.Array:
    """x: (..., d), w_h: (d, rbit)  ->  (..., rbit//32) uint32.

    sign(0) is treated as +1 (bit set) so the encoding is deterministic.
    The projection is computed in f32 regardless of input dtype: sign is
    all that survives, but near-zero projections must not flip bits
    between the kernel and the oracle.
    """
    proj = jnp.einsum("...d,dr->...r", x.astype(jnp.float32),
                      w_h.astype(jnp.float32))
    return bitpack_ref((proj >= 0).astype(jnp.uint32))


def hash_encode_mlp_ref(x: jax.Array, w1: jax.Array, b1: jax.Array,
                        w2: jax.Array) -> jax.Array:
    """Non-linear (Spotlight-style) hash encode oracle.

    x: (..., d), w1: (d, hidden), b1: (hidden,), w2: (hidden, rbit)
    ->  (..., rbit//32) uint32: sign(relu(x@w1 + b1) @ w2), bit-packed.
    All matmuls in f32 for the same sign-stability reason as
    :func:`hash_encode_ref`.
    """
    hid = jax.nn.relu(jnp.einsum("...d,dh->...h", x.astype(jnp.float32),
                                 w1.astype(jnp.float32))
                      + b1.astype(jnp.float32))
    proj = jnp.einsum("...h,hr->...r", hid, w2.astype(jnp.float32))
    return bitpack_ref((proj >= 0).astype(jnp.uint32))


# ---------------------------------------------------------------------------
# Hamming score (paper Alg. 3 lines 10-11, + GQA aggregation)
# ---------------------------------------------------------------------------
def hamming_score_ref(q_codes: jax.Array, k_codes: jax.Array,
                      rbit: int) -> jax.Array:
    """Aggregated match scores of one kv-head's code cache.

    q_codes: (G, W) uint32 -- the G query heads sharing this kv head.
    k_codes: (S, W) uint32 -- the cached key codes.
    returns: (S,) int32, score[s] = sum_g (rbit - popcount(q_g ^ k_s)).
    Higher = more similar. Bounded by [0, G*rbit].
    """
    x = jnp.bitwise_xor(q_codes[:, None, :], k_codes[None, :, :])
    ham = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    g = q_codes.shape[0]
    return g * rbit - jnp.sum(ham, axis=0)


def hamming_score_batched_ref(q_codes: jax.Array, k_codes: jax.Array,
                              rbit: int) -> jax.Array:
    """Batched/multi-head oracle.

    q_codes: (B, H_kv, G, W), k_codes: (B, S, H_kv, W)
    returns scores (B, H_kv, S) int32.
    """
    x = jnp.bitwise_xor(q_codes[:, :, :, None, :],
                        jnp.moveaxis(k_codes, 1, 2)[:, :, None, :, :])
    ham = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    g = q_codes.shape[2]
    return g * rbit - jnp.sum(ham, axis=2)


def hamming_score_latent_ref(q_codes: jax.Array, k_codes: jax.Array,
                             rbit: int) -> jax.Array:
    """Single-stream (MLA latent) oracle.

    q_codes: (B, H, W) — all H query heads hashed against the shared
    latent stream — k_codes: (B, S, W). Returns (B, S) int32 with
    score = H*rbit - sum_h hamming(q_h, k): the latent stream is one kv
    head whose GQA group is every query head.
    """
    x = jnp.bitwise_xor(q_codes[:, :, None, :], k_codes[:, None, :, :])
    ham = jnp.sum(jax.lax.population_count(x).astype(jnp.int32),
                  axis=(1, 3))
    return q_codes.shape[1] * rbit - ham


# ---------------------------------------------------------------------------
# Attention oracles
# ---------------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: Optional[float] = None,
                  q_offset: int = 0,
                  bias: Optional[jax.Array] = None) -> jax.Array:
    """Plain softmax attention for one head group.

    q: (Sq, d), k: (Sk, d), v: (Sk, dv). q_offset: absolute position of
    q[0] for causal masking (decode: q_offset = cache_len - Sq ... etc).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        sq, sk = q.shape[0], k.shape[0]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return (probs @ v.astype(jnp.float32)).astype(q.dtype)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, q_offset: int = 0,
            window: Optional[int] = None) -> jax.Array:
    """Multi-head GQA attention oracle.

    q: (B, Sq, H, d), k/v: (B, Sk, H_kv, d). Returns (B, Sq, H, d).
    ``window``: optional sliding-window size (Mixtral SWA).
    """
    b, sq, h, d = q.shape
    h_kv = k.shape[2]
    g = h // h_kv
    qf = q.astype(jnp.float32) * (d ** -0.5)
    qf = qf.reshape(b, sq, h_kv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos if causal else jnp.ones((sq, sk), bool)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def mla_chunk_attention_ref(q_lat: jax.Array, ckv: jax.Array,
                            krope: jax.Array,
                            q_offset=0, *, lora_rank: int,
                            scale: float) -> jax.Array:
    """Split-latent MLA chunked-prefill oracle.

    q_lat: (B, C, H, r+rd) absorbed queries, ckv: (B, S, r), krope:
    (B, S, rd), q_offset: scalar or (B,) absolute position of
    q_lat[:, 0]. Causal at absolute positions; logits are the split
    form q_c·c + q_r·k_r and the values are the ckv rows (the caller
    applies W_uv) — the ground truth for ``mla_prefill_batched``.
    Matmul-then-normalize with masked lanes at exactly 0 mass, matching
    the kernel's accumulation convention bit-for-bit in the
    single-kv-tile regime.
    """
    b, c, h, _ = q_lat.shape
    s = ckv.shape[1]
    q = q_lat.astype(jnp.float32) * scale
    q_c, q_r = q[..., :lora_rank], q[..., lora_rank:]
    logits = (jnp.einsum("bchr,bsr->bchs", q_c, ckv.astype(jnp.float32))
              + jnp.einsum("bchr,bsr->bchs", q_r,
                           krope.astype(jnp.float32)))
    qpos = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (-1, 1)) \
        + jnp.arange(c)[None]                        # (1|B, C)
    kpos = jnp.arange(s)[None, None, None, :]        # (1, 1, 1, S)
    mask = jnp.broadcast_to(kpos <= qpos[:, :, None, None], logits.shape)
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bchs,bsr->bchr", p, ckv.astype(jnp.float32))
    return o / jnp.maximum(l, 1e-30)[..., None]


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         mask: Optional[jax.Array] = None) -> jax.Array:
    """Single-token decode oracle for one kv head.

    q: (G, d), k/v: (S, d), mask: optional (S,) bool (True = attend).
    Returns (G, d).
    """
    d = q.shape[-1]
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * (d ** -0.5)
    if mask is not None:
        logits = jnp.where(mask[None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return (probs @ v.astype(jnp.float32)).astype(q.dtype)


def gather_decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                                v_cache: jax.Array,
                                idx: jax.Array) -> jax.Array:
    """Gather-then-attend oracle (HATA decode, one kv head).

    q: (G, d), k_cache/v_cache: (S, d), idx: (k,) int32 row indices.
    Equivalent to the fused-gather flash decode kernel.
    """
    return decode_attention_ref(q, k_cache[idx], v_cache[idx])


def masked_gather_decode_ref(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, idx: jax.Array,
                             sel_valid: Optional[jax.Array] = None,
                             ) -> jax.Array:
    """Batched masked gather-attention oracle (HATA decode, all heads).

    q: (B, H, d), k_cache/v_cache: (B, S, H_kv, d) native cache layout,
    idx: (B, H_kv, k) int32 selected rows, sel_valid: optional
    (B, H_kv, k) bool (True = attend). The ground truth for the batched
    fused gather kernel: invalid selections' logits go to -inf before
    the softmax. Returns (B, H, d).
    """
    b, h, d = q.shape
    h_kv = k_cache.shape[2]
    g = h // h_kv
    kg = jnp.take_along_axis(jnp.moveaxis(k_cache, 2, 1), idx[..., None],
                             axis=2)                  # (B, H_kv, k, d)
    vg = jnp.take_along_axis(jnp.moveaxis(v_cache, 2, 1), idx[..., None],
                             axis=2)
    qf = q.reshape(b, h_kv, g, d).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, kg.astype(jnp.float32))
    if sel_valid is not None:
        logits = jnp.where(sel_valid[:, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs, vg.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def gather_pool_rows_ref(pool_flat: jax.Array,
                         phys_idx: jax.Array) -> jax.Array:
    """Gather per-head rows from a flattened shared page pool.

    pool_flat: (N_phys, H_kv, d); phys_idx: (B, H_kv, k) int32 physical
    rows. Returns (B, H_kv, k, d): row ``phys_idx[b, h, j]`` read at
    head ``h`` — the XLA stand-in for the shared-pool kernel's per-row
    DMA source.
    """
    per_head = jnp.moveaxis(pool_flat, 1, 0)          # (H_kv, N, d)
    return jax.vmap(lambda rows, ix: rows[ix],
                    in_axes=(0, 1), out_axes=1)(per_head, phys_idx)


def masked_gather_decode_pool_ref(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, phys_idx: jax.Array,
                                  sel_valid: Optional[jax.Array] = None,
                                  ) -> jax.Array:
    """Shared-pool oracle for ``flash_decode_gathered_paged``.

    q: (B, H, d); k_pool/v_pool: (N_phys, H_kv, d) flattened page
    pools; phys_idx: (B, H_kv, k) int32 physical rows; sel_valid as in
    :func:`masked_gather_decode_ref`. Same masked softmax math — only
    the gather source differs, which is the whole point: given equal
    selected rows the paged output is bit-identical.
    """
    b, h, d = q.shape
    h_kv = k_pool.shape[1]
    g = h // h_kv
    kg = gather_pool_rows_ref(k_pool, phys_idx)       # (B, H_kv, k, d)
    vg = gather_pool_rows_ref(v_pool, phys_idx)
    qf = q.reshape(b, h_kv, g, d).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, kg.astype(jnp.float32))
    if sel_valid is not None:
        logits = jnp.where(sel_valid[:, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs, vg.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def mla_gather_decode_pool_ref(q_lat: jax.Array, ckv_pool: jax.Array,
                               krope_pool: jax.Array, phys_idx: jax.Array,
                               sel_mask: Optional[jax.Array] = None, *,
                               lora_rank: int, scale: float,
                               return_stats: bool = False):
    """Shared-pool oracle for ``mla_decode_gathered_paged``.

    ckv_pool: (N_phys, r), krope_pool: (N_phys, rd), phys_idx: (B, k)
    physical rows of the shared latent pool. Same split-form logits and
    values as :func:`mla_gather_decode_ref`; ``return_stats`` yields the
    unnormalized (m, l, o~) partials (paged SP shards).
    """
    sel_c = ckv_pool[phys_idx]                        # (B, k, r)
    sel_r = krope_pool[phys_idx]
    q_c = q_lat[..., :lora_rank].astype(sel_c.dtype)
    q_r = q_lat[..., lora_rank:].astype(sel_r.dtype)
    logits = (jnp.einsum("bhr,bkr->bhk", q_c, sel_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bkr->bhk", q_r, sel_r,
                           preferred_element_type=jnp.float32)) * scale
    if sel_mask is not None:
        logits = jnp.where(sel_mask[:, None, :], logits, -jnp.inf)
    m = jnp.maximum(jnp.max(logits, axis=-1), -1e30)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhk,bkr->bhr", p.astype(sel_c.dtype), sel_c,
                   preferred_element_type=jnp.float32)
    if return_stats:
        return m, l, o
    return o / jnp.maximum(l, 1e-30)[..., None]


def gather_decode_stats_pool_ref(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, phys_idx: jax.Array,
                                 sel_mask: Optional[jax.Array] = None,
                                 ) -> Tuple[jax.Array, jax.Array,
                                            jax.Array]:
    """Shared-pool oracle for ``flash_decode_gathered_stats_paged``.

    Same partials math as :func:`gather_decode_stats_ref`, but the
    gather source is the flattened (N_phys, H_kv, d) page pool and
    ``phys_idx`` (B, H_kv, R) carries physical rows. A fully-masked row
    emits (m=-1e30, l=0, o=0).
    """
    b, h, d = q.shape
    h_kv = k_pool.shape[1]
    g = h // h_kv
    # (B, R, H_kv, d) — the same operand layout as the contiguous
    # gather_decode_stats_ref, so the two oracles (and hence the paged
    # and contiguous stats paths) stay bit-identical, not just close
    kg = jnp.moveaxis(gather_pool_rows_ref(k_pool, phys_idx), 1, 2)
    vg = jnp.moveaxis(gather_pool_rows_ref(v_pool, phys_idx), 1, 2)
    qg = q.reshape(b, h_kv, g, d)
    logits = jnp.einsum("bhgd,brhd->bhgr", qg, kg,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    if sel_mask is not None:
        logits = jnp.where(sel_mask[:, :, None, :], logits, -jnp.inf)
    m = jnp.maximum(jnp.max(logits, axis=-1), -1e30)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgr,brhd->bhgd", p.astype(vg.dtype), vg,
                   preferred_element_type=jnp.float32)
    return m, l, o


def gather_decode_stats_ref(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, idx: jax.Array,
                            sel_mask: Optional[jax.Array] = None,
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gathered flash-partials oracle (sequence-parallel HATA shards).

    q: (B, H, d), k_cache/v_cache: (B, S, H_kv, d) native layout (the
    local shard), idx: (B, H_kv, R) int32 in-range rows, sel_mask:
    optional (B, H_kv, R) bool — False rows contribute nothing (the
    two_stage ownership filter; masks may be arbitrary, not prefixes).
    Returns m/l: (B, H_kv, G) f32, o~: (B, H_kv, G, d) f32
    *unnormalized*, ready for ``merge_partial_softmax`` — the ground
    truth for ``flash_decode_gathered_stats_batched``. A fully-masked
    row emits (m=-1e30, l=0, o=0).
    """
    b, h, d = q.shape
    h_kv = k_cache.shape[2]
    g = h // h_kv
    ridx = jnp.moveaxis(idx, 1, 2)[..., None]         # (B, R, H_kv, 1)
    kg = jnp.take_along_axis(k_cache, ridx, axis=1)   # (B, R, H_kv, d)
    vg = jnp.take_along_axis(v_cache, ridx, axis=1)
    qg = q.reshape(b, h_kv, g, d)
    logits = jnp.einsum("bhgd,brhd->bhgr", qg, kg,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    if sel_mask is not None:
        logits = jnp.where(sel_mask[:, :, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.maximum(m, -1e30)
    p = jnp.exp(logits - m_safe[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgr,brhd->bhgd", p.astype(vg.dtype), vg,
                   preferred_element_type=jnp.float32)
    return m_safe, l, o


def mla_gather_decode_ref(q_lat: jax.Array, ckv: jax.Array,
                          krope: jax.Array, idx: jax.Array,
                          sel_mask: Optional[jax.Array] = None, *,
                          lora_rank: int, scale: float,
                          return_stats: bool = False):
    """Split-latent MLA gathered-decode oracle.

    q_lat: (B, H, r+rd) absorbed queries, ckv: (B, S, r), krope:
    (B, S, rd), idx: (B, k) int32 selected rows of the shared latent
    stream, sel_mask: optional (B, k) bool. Logits are the split form
    q_c·c + q_r·k_r (no concatenated latent copy); values are the ckv
    rows (the caller applies W_uv). Returns o_lat (B, H, r) f32
    normalized, or the unnormalized flash partials (m, l, o~) when
    ``return_stats`` — the ground truth for
    ``mla_decode_gathered_batched``.
    """
    sel_c = jnp.take_along_axis(ckv, idx[..., None], axis=1)   # (B, k, r)
    sel_r = jnp.take_along_axis(krope, idx[..., None], axis=1)
    q_c = q_lat[..., :lora_rank].astype(sel_c.dtype)
    q_r = q_lat[..., lora_rank:].astype(sel_r.dtype)
    logits = (jnp.einsum("bhr,bkr->bhk", q_c, sel_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bkr->bhk", q_r, sel_r,
                           preferred_element_type=jnp.float32)) * scale
    if sel_mask is not None:
        logits = jnp.where(sel_mask[:, None, :], logits, -jnp.inf)
    m = jnp.maximum(jnp.max(logits, axis=-1), -1e30)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhk,bkr->bhr", p.astype(sel_c.dtype), sel_c,
                   preferred_element_type=jnp.float32)
    if return_stats:
        return m, l, o
    return o / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Partial-softmax (flash) statistics — used by the distributed SP decode
# merge and by the flash kernels' scratch math.
# ---------------------------------------------------------------------------
def softmax_stats_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      mask: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard flash statistics (m, l, o~) for exact cross-shard merge.

    q: (G, d), k/v: (S, d). Returns m: (G,), l: (G,), o: (G, dv) where
    o = sum_s exp(logit - m) v_s  (unnormalized).
    """
    d = q.shape[-1]
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * (d ** -0.5)
    if mask is not None:
        logits = jnp.where(mask[None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    # A fully-masked shard contributes nothing; keep exp() finite.
    m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
    p = jnp.exp(logits - m_safe[:, None])
    l = jnp.sum(p, axis=-1)
    o = p @ v.astype(jnp.float32)
    return m_safe, l, o


def merge_softmax_stats_ref(stats: Tuple[jax.Array, ...]) -> jax.Array:
    """Merge per-shard (m, l, o) stacked on a leading axis.

    m/l: (P, ...), o: (P, ..., dv) -> (..., dv) — any batch shape
    between the shard axis and o's value axis (the in-process stand-in
    for ``collectives.merge_partial_softmax``'s pmax/psum).
    """
    m, l, o = stats
    m_g = jnp.max(m, axis=0)
    alpha = jnp.exp(m - m_g[None])                 # (P, ...)
    l_g = jnp.sum(alpha * l, axis=0)
    o_g = jnp.sum(alpha[..., None] * o, axis=0)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]
