"""Flash decode kernels: one query token against a KV cache.

These implement the paper's "fuse gather with FlashAttention" (§4,
third optimization) on TPU. The batched variants at the bottom are the
*only* HATA decode data path — GQA, MLA-latent and the sequence-parallel
shards all bottom out in the same paged-gather chunk pipeline
(:func:`_paged_chunk_pipeline`).

``flash_decode``
    Dense/compacted decode: the G query heads of one GQA group attend
    over (S, d) K/V with an optional validity mask length. Used (a) for
    dense decode and (b) as stage 2 of the *gather_dense* HATA path,
    where an XLA row-gather first compacts the top-k rows — that gather
    is a single fused HBM pass, which GSPMD also partitions best.

``flash_decode_gathered``
    The per-head fused-gather variant: top-k row indices are scalar-
    prefetched into SMEM and drive the BlockSpec index_map, so the
    kernel DMAs exactly the selected KV rows HBM->VMEM (the TPU
    paged-attention pattern with page_size = 1 row). Kept as the
    benchmark baseline for the batched pipeline.

``flash_decode_gathered_batched``
    The production decode path: the same fused gather, batched over
    (B, H_kv) in a single grid so one dispatch serves the whole decode
    wave, reading the KV cache in its native (B, S, H_kv, d) layout.
    Applies the selection-validity mask inside the kernel, which is what
    lets the caller drop the exact-recompute correction branch the
    per-head variant needed (see core/hash_attention.py).

``flash_decode_gathered_stats_batched``
    The sequence-parallel variant of the same kernel: identical paged
    gather + online softmax, but it emits the flash partials (m, l, o~)
    *unnormalized* instead of dividing by l, so a sharded caller can
    psum-merge across shards (``collectives.merge_partial_softmax``).
    Accepts an arbitrary per-selection ``sel_mask`` because the
    two_stage SP mode attends only over the global winners a shard
    *owns* — not a prefix of the selection.

``mla_decode_gathered_batched``
    The split-latent MLA variant (beyond-paper HATA-over-latent): one
    shared (B, S, r) + (B, S, rope) latent cache, absorbed queries, and
    logits computed as q_c·c + q_r·k_r so no concatenated copy of the
    latent cache is ever materialized. Same chunk pipeline, two DMA
    streams per selected row — the (ckv, krope) pair. Normalized or
    stats-emitting (``return_stats``) for the SP shards.

Trade-off (see DESIGN.md §3): row-granular DMA descriptors issue at
(1, d) granularity — the bytes win is identical to gather_dense, but
the DMA issue rate can bind at small d; ``block_k`` batches rows into
double-buffered chunks so a whole chunk's row copies are in flight
while the previous chunk computes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Dense / compacted decode
# ---------------------------------------------------------------------------
def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_k: int, n_blocks: int):
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = len_ref[0]

    @pl.when(ki * block_k < valid_len)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale        # (G, d)
        k = k_ref[...].astype(jnp.float32)                # (block_k, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, block_k)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(kpos < valid_len, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 valid_len: Optional[jax.Array] = None, *,
                 block_k: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """q: (G, d), k/v: (S, d), valid_len: scalar int32 (default S)."""
    interpret = runtime.resolve_interpret(interpret)
    g, d = q.shape
    s = k.shape[0]
    if valid_len is None:
        valid_len = jnp.int32(s)
    valid_len = jnp.asarray(valid_len, jnp.int32).reshape(1)
    block_k = runtime.decode_block_k(block_k, size=s, dtype=q.dtype)
    block_k = min(block_k, s)
    n_blocks = pl.cdiv(s, block_k)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((g, d), lambda i, len_ref: (0, 0)),
            pl.BlockSpec((block_k, d), lambda i, len_ref: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, len_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda i, len_ref: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=d ** -0.5, block_k=block_k,
                          n_blocks=n_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), q.dtype),
        interpret=interpret,
    )(valid_len, q, k, v)


# ---------------------------------------------------------------------------
# Fused-gather decode (scalar-prefetched top-k indices), per head
# ---------------------------------------------------------------------------
def _gather_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, rows: int, n_blocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale            # (G, d)
    k = k_ref[...].astype(jnp.float32)                    # (rows, d)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (G, rows)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    v = v_ref[...].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(bi == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_gathered(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, idx: jax.Array, *,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Fused gather+decode. q: (G, d), caches: (S, d), idx: (k,) int32.

    Each grid step DMAs one selected KV row pair (page_size=1 paged
    attention); the index_map reads the scalar-prefetched idx from SMEM.
    Exact w.r.t. ``ref.gather_decode_attention_ref`` for duplicate-free
    idx (top-k indices are unique by construction).
    """
    interpret = runtime.resolve_interpret(interpret)
    g, d = q.shape
    n_sel = idx.shape[0]
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sel,),
        in_specs=[
            pl.BlockSpec((g, d), lambda i, idx_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda i, idx_ref: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, scale=d ** -0.5, rows=1,
                          n_blocks=n_sel),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), q.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Shared paged-row DMA chunk pipeline
# ---------------------------------------------------------------------------
def _paged_chunk_pipeline(n_chunks: int, block_k: int, row_copies,
                          compute, carry):
    """Double-buffered selected-row DMA pipeline shared by every batched
    gather kernel (GQA normalized, GQA stats, MLA split-latent).

    ``row_copies(pos, j, slot)`` returns the async-copy descriptors that
    land selected row ``pos`` (an index into the padded selection) in
    buffer row ``j`` of double-buffer ``slot``; ``compute(ci, slot,
    carry)`` consumes one resident chunk. Chunk ci+1's row copies are
    issued *before* chunk ci is consumed, so a whole chunk's DMAs are in
    flight while the previous chunk computes (and, on hardware, overlap
    the MXU work). Both the chunk walk and the per-row issue/drain are
    ``fori_loop``s: trace size is O(1) in the budget, where the previous
    revision python-unrolled one DMA pair per selected row and large
    budgets exploded the jaxpr.

    Callers must pad the selection to ``n_chunks * block_k`` entries
    (kept in-range) and mask the tail out of the softmax — uniform
    chunks are what keep the loop bodies static.
    """
    def start(ci, slot):
        def issue(j, _):
            for c in row_copies(ci * block_k + j, j, slot):
                c.start()
            return 0
        jax.lax.fori_loop(0, block_k, issue, 0)

    def wait(ci, slot):
        def drain(j, _):
            for c in row_copies(ci * block_k + j, j, slot):
                c.wait()
            return 0
        jax.lax.fori_loop(0, block_k, drain, 0)

    start(0, 0)

    def body(ci, carry):
        slot = jax.lax.rem(ci, 2)

        @pl.when(ci + 1 < n_chunks)
        def _prefetch():
            start(ci + 1, 1 - slot)

        wait(ci, slot)
        return compute(ci, slot, carry)

    return jax.lax.fori_loop(0, n_chunks, body, carry)


def _pad_selection(idx: jax.Array, sel_mask: Optional[jax.Array],
                   block_k: int):
    """Pad the selection axis to a block_k multiple (zeros stay in-range;
    padded mask entries are False). Returns (idx, sel_mask, n_chunks)."""
    n_sel = idx.shape[-1]
    block_k = min(block_k, n_sel)
    n_chunks = pl.cdiv(n_sel, block_k)
    pad = n_chunks * block_k - n_sel
    if pad:
        cfg = [(0, 0)] * (idx.ndim - 1) + [(0, pad)]
        idx = jnp.pad(idx, cfg)
        if sel_mask is not None:
            sel_mask = jnp.pad(sel_mask.astype(jnp.int32), cfg)
    if sel_mask is not None:
        sel_mask = sel_mask.astype(jnp.int32)
    return idx, sel_mask, block_k, n_chunks


# ---------------------------------------------------------------------------
# Batched fused-gather decode: score -> select -> gather in one pipeline
# ---------------------------------------------------------------------------
def _gqa_gather_kernel(idx_ref, nvalid_ref, q_ref, *refs, scale: float,
                       block_k: int, n_chunks: int, n_sel: int,
                       has_mask: bool, return_stats: bool,
                       shared_pool: bool = False):
    from jax.experimental.pallas import tpu as pltpu  # noqa: F401
    if has_mask:
        mask_ref, k_ref, v_ref = refs[:3]
        refs = refs[3:]
    else:
        mask_ref = None
        k_ref, v_ref = refs[:2]
        refs = refs[2:]
    if return_stats:
        m_ref, l_ref, o_ref, kbuf, vbuf, sems = refs
    else:
        (o_ref, kbuf, vbuf, sems) = refs
        m_ref = l_ref = None

    bi = pl.program_id(0)
    hi = pl.program_id(1)
    n_valid = nvalid_ref[bi, hi]
    q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, d)
    g, d = q.shape

    def row_copies(pos, j, slot):
        from jax.experimental.pallas import tpu as pltpu
        row = idx_ref[bi, hi, pos]
        # shared_pool: the caches are one (N_phys, H_kv, d) page pool
        # shared by every request, and ``row`` is already a *physical*
        # row (the caller translated logical -> page*size+offset), so
        # the DMA source drops the batch index. Everything else —
        # chunking, masking, softmax — is identical to the contiguous
        # path, which is what makes paged decode bit-exact against it.
        k_src = (k_ref.at[pl.ds(row, 1), hi] if shared_pool
                 else k_ref.at[bi, pl.ds(row, 1), hi])
        v_src = (v_ref.at[pl.ds(row, 1), hi] if shared_pool
                 else v_ref.at[bi, pl.ds(row, 1), hi])
        return [
            pltpu.make_async_copy(k_src, kbuf.at[slot, pl.ds(j, 1)],
                                  sems.at[slot, 0, j]),
            pltpu.make_async_copy(v_src, vbuf.at[slot, pl.ds(j, 1)],
                                  sems.at[slot, 1, j]),
        ]

    def compute(ci, slot, carry):
        m, l, acc = carry
        k = kbuf[slot].astype(jnp.float32)                # (block_k, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, block_k)
        # validity applied *inside* the kernel: invalid selections'
        # logits go to -inf before the softmax. p is zeroed explicitly
        # so an all-invalid chunk can't inject exp(-inf - -inf) mass
        # while m is still at its -inf init. Padded tail rows (pos >=
        # n_sel) are masked by the same predicate since n_valid <= n_sel.
        kpos = ci * block_k + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        vmask = kpos < jnp.minimum(n_valid, n_sel)
        if has_mask:
            sel = mask_ref[0, 0, pl.ds(ci * block_k, block_k)]
            vmask = vmask & (sel != 0)[None, :]
        logits = jnp.where(vmask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(vmask, jnp.exp(logits - m_new), 0.0)
        l_new = l * alpha + jnp.sum(p, -1, keepdims=True)
        v = vbuf[slot].astype(jnp.float32)
        acc_new = acc * alpha + jnp.dot(p, v,
                                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    carry0 = (jnp.full((g, 1), NEG_INF, jnp.float32),
              jnp.zeros((g, 1), jnp.float32),
              jnp.zeros((g, d), jnp.float32))
    m, l, acc = _paged_chunk_pipeline(n_chunks, block_k, row_copies,
                                      compute, carry0)
    if return_stats:
        m_ref[0, 0] = m[:, 0]
        l_ref[0, 0] = l[:, 0]
        o_ref[0, 0] = acc.astype(o_ref.dtype)
    else:
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _gqa_gather_call(q, k_cache, v_cache, idx, n_valid, sel_mask, *,
                     block_k, interpret, return_stats,
                     shared_pool=False):
    b, h_kv, g, d = q.shape
    n_sel = idx.shape[-1]
    assert idx.shape == (b, h_kv, n_sel), (idx.shape, q.shape)
    if shared_pool:
        assert k_cache.ndim == 3, (k_cache.shape,)  # (N_phys, H_kv, d)
    else:
        assert k_cache.ndim == 4, (k_cache.shape,)  # (B, S, H_kv, d)
    if n_valid is None:
        n_valid = jnp.full((b, h_kv), n_sel, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    # scalar or exact-shape only: a (B,) vector would silently broadcast
    # onto the trailing h_kv axis whenever B == H_kv
    assert n_valid.shape in ((), (b, h_kv)), (n_valid.shape, q.shape)
    n_valid = jnp.broadcast_to(n_valid, (b, h_kv))
    idx, sel_mask, block_k, n_chunks = _pad_selection(
        idx.astype(jnp.int32), sel_mask, block_k)
    has_mask = sel_mask is not None
    from jax.experimental.pallas import tpu as pltpu
    k_pad = idx.shape[-1]
    in_specs = [pl.BlockSpec((1, 1, g, d),
                             lambda bi, hi, idx_ref, nv_ref: (bi, hi, 0, 0))]
    if has_mask:
        in_specs.append(pl.BlockSpec(
            (1, 1, k_pad), lambda bi, hi, idx_ref, nv_ref: (bi, hi, 0)))
    in_specs += [pl.BlockSpec(memory_space=pltpu.ANY),
                 pl.BlockSpec(memory_space=pltpu.ANY)]
    out_spec = pl.BlockSpec((1, 1, g, d),
                            lambda bi, hi, idx_ref, nv_ref: (bi, hi, 0, 0))
    if return_stats:
        ml_spec = pl.BlockSpec((1, 1, g),
                               lambda bi, hi, idx_ref, nv_ref: (bi, hi, 0))
        out_specs = (ml_spec, ml_spec, out_spec)
        out_shape = (jax.ShapeDtypeStruct((b, h_kv, g), jnp.float32),
                     jax.ShapeDtypeStruct((b, h_kv, g), jnp.float32),
                     jax.ShapeDtypeStruct((b, h_kv, g, d), jnp.float32))
    else:
        out_specs = out_spec
        out_shape = jax.ShapeDtypeStruct((b, h_kv, g, d), q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h_kv),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, block_k, d), k_cache.dtype),
            pltpu.VMEM((2, block_k, d), v_cache.dtype),
            pltpu.SemaphoreType.DMA((2, 2, block_k)),
        ],
    )
    operands = (idx, n_valid, q)
    if has_mask:
        operands += (sel_mask,)
    operands += (k_cache, v_cache)
    return pl.pallas_call(
        functools.partial(_gqa_gather_kernel, scale=d ** -0.5,
                          block_k=block_k, n_chunks=n_chunks, n_sel=n_sel,
                          has_mask=has_mask, return_stats=return_stats,
                          shared_pool=shared_pool),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=runtime.resolve_interpret(interpret),
    )(*operands)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_gathered_batched(q: jax.Array, k_cache: jax.Array,
                                  v_cache: jax.Array, idx: jax.Array,
                                  n_valid: Optional[jax.Array] = None,
                                  sel_mask: Optional[jax.Array] = None, *,
                                  block_k: Optional[int] = None,
                                  interpret: Optional[bool] = None,
                                  ) -> jax.Array:
    """Batched fused gather+decode attention — one dispatch, no vmap.

    q: (B, H_kv, G, d), k_cache/v_cache: (B, S, H_kv, d) *native* cache
    layout, idx: (B, H_kv, k) int32 selected rows, n_valid: optional
    (B, H_kv) int32 count of valid selections — entries past it are
    masked out of the softmax (idx must sort invalid entries last,
    which lax.top_k guarantees under the match-score convention).
    sel_mask: optional (B, H_kv, k) bool/int32 arbitrary per-selection
    mask, ANDed with the prefix mask (sequence-parallel ownership
    filtering). Returns (B, H_kv, G, d).

    The TPU paged-attention pattern with page_size = 1 row: the caches
    stay in ANY/HBM space (never auto-tiled into VMEM), the top-k
    indices are scalar-prefetched into SMEM, and each (B, H_kv) grid
    step walks its selection in ``block_k``-row double-buffered chunks —
    all of a chunk's row-pair DMAs in flight while the previous chunk
    runs the online softmax (see ``_paged_chunk_pipeline``). No
    transposed cache copy, no compacted intermediate; the only HBM
    traffic is the k selected rows. Invalid rows' DMAs still land (idx
    stays in-range) but their logits are masked to -inf inside the
    kernel, so the output is bit-identical to running over only the
    valid prefix (same chunk alignment).
    """
    return _gqa_gather_call(q, k_cache, v_cache, idx, n_valid, sel_mask,
                            block_k=runtime.gather_block_k(
                                block_k, size=idx.shape[-1],
                                dtype=q.dtype),
                            interpret=interpret, return_stats=False)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_gathered_stats_batched(
        q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
        idx: jax.Array, n_valid: Optional[jax.Array] = None,
        sel_mask: Optional[jax.Array] = None, *,
        block_k: Optional[int] = None,
        interpret: Optional[bool] = None):
    """Stats-emitting variant of :func:`flash_decode_gathered_batched`.

    Same paged gather and in-kernel masking, but returns the flash
    partials (m, l, o~) — m/l: (B, H_kv, G) f32, o~: (B, H_kv, G, d)
    f32 *unnormalized* — for the sequence-parallel psum merge
    (``collectives.merge_partial_softmax``). A grid cell whose whole
    selection is masked emits (m=-1e30, l=0, o=0), the merge's
    nothing-to-contribute convention.
    """
    return _gqa_gather_call(q, k_cache, v_cache, idx, n_valid, sel_mask,
                            block_k=runtime.gather_block_k(
                                block_k, size=idx.shape[-1],
                                dtype=q.dtype),
                            interpret=interpret, return_stats=True)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_gathered_paged(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, phys_idx: jax.Array,
                                n_valid: Optional[jax.Array] = None,
                                sel_mask: Optional[jax.Array] = None, *,
                                block_k: Optional[int] = None,
                                interpret: Optional[bool] = None,
                                ) -> jax.Array:
    """Block-table-indirect variant of :func:`flash_decode_gathered_batched`.

    q: (B, H_kv, G, d); k_pool/v_pool: (N_phys, H_kv, d) — the shared
    per-layer page pool flattened to physical rows; phys_idx:
    (B, H_kv, k) int32 *physical* rows (the caller translates selected
    logical rows through its block table — logical // page and
    logical % page — *before* the call, so selection math is untouched
    and the kernel's per-row DMA just reads a different address space).
    n_valid / sel_mask as in the contiguous variant. Same chunk
    pipeline, same in-kernel masking: paged decode is bit-exact vs. the
    contiguous path given equal selected rows.
    """
    return _gqa_gather_call(q, k_pool, v_pool, phys_idx, n_valid,
                            sel_mask,
                            block_k=runtime.gather_block_k(
                                block_k, size=phys_idx.shape[-1],
                                dtype=q.dtype),
                            interpret=interpret, return_stats=False,
                            shared_pool=True)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_gathered_stats_paged(
        q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
        phys_idx: jax.Array, n_valid: Optional[jax.Array] = None,
        sel_mask: Optional[jax.Array] = None, *,
        block_k: Optional[int] = None,
        interpret: Optional[bool] = None):
    """Stats-emitting shared-pool gather: the paged twin of
    :func:`flash_decode_gathered_stats_batched`, for sequence-parallel
    shards whose local slice lives in a page pool.

    Same chunk pipeline, DMA source and in-kernel masking as
    :func:`flash_decode_gathered_paged` (``phys_idx`` carries physical
    rows translated before the call), but returns the unnormalized
    (m, l, o~) flash partials for ``merge_partial_softmax`` — no new
    kernel code, just the existing (shared_pool, return_stats) corner
    of the shared gather call.
    """
    return _gqa_gather_call(q, k_pool, v_pool, phys_idx, n_valid,
                            sel_mask,
                            block_k=runtime.gather_block_k(
                                block_k, size=phys_idx.shape[-1],
                                dtype=q.dtype),
                            interpret=interpret, return_stats=True,
                            shared_pool=True)


# ---------------------------------------------------------------------------
# Batched split-latent MLA fused-gather decode
# ---------------------------------------------------------------------------
def _mla_gather_kernel(idx_ref, nvalid_ref, q_ref, *refs, scale: float,
                       lora_rank: int, block_k: int, n_chunks: int,
                       n_sel: int, has_mask: bool, return_stats: bool,
                       shared_pool: bool = False):
    if has_mask:
        mask_ref, ckv_ref, kr_ref = refs[:3]
        refs = refs[3:]
    else:
        mask_ref = None
        ckv_ref, kr_ref = refs[:2]
        refs = refs[2:]
    if return_stats:
        m_ref, l_ref, o_ref, cbuf, rbuf, sems = refs
    else:
        o_ref, cbuf, rbuf, sems = refs
        m_ref = l_ref = None

    bi = pl.program_id(0)
    n_valid = nvalid_ref[bi]
    q = q_ref[0].astype(jnp.float32) * scale              # (H, r+rd)
    h = q.shape[0]
    q_c = q[:, :lora_rank]
    q_r = q[:, lora_rank:]

    def row_copies(pos, j, slot):
        from jax.experimental.pallas import tpu as pltpu
        row = idx_ref[bi, pos]
        # shared_pool: (N_phys, r) / (N_phys, rd) page pools with
        # physical rows — see _gqa_gather_kernel.row_copies.
        c_src = (ckv_ref.at[pl.ds(row, 1)] if shared_pool
                 else ckv_ref.at[bi, pl.ds(row, 1)])
        r_src = (kr_ref.at[pl.ds(row, 1)] if shared_pool
                 else kr_ref.at[bi, pl.ds(row, 1)])
        return [
            pltpu.make_async_copy(c_src, cbuf.at[slot, pl.ds(j, 1)],
                                  sems.at[slot, 0, j]),
            pltpu.make_async_copy(r_src, rbuf.at[slot, pl.ds(j, 1)],
                                  sems.at[slot, 1, j]),
        ]

    def compute(ci, slot, carry):
        m, l, acc = carry
        c = cbuf[slot].astype(jnp.float32)                # (block_k, r)
        kr = rbuf[slot].astype(jnp.float32)               # (block_k, rd)
        # absorbed-q split-latent logits: q·[c;k_r] = q_c·c + q_r·k_r —
        # the concatenated latent row never exists in VMEM.
        logits = (jax.lax.dot_general(
                      q_c, c, (((1,), (1,)), ((), ())),
                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(
                      q_r, kr, (((1,), (1,)), ((), ())),
                      preferred_element_type=jnp.float32))  # (H, block_k)
        kpos = ci * block_k + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        vmask = kpos < jnp.minimum(n_valid, n_sel)
        if has_mask:
            sel = mask_ref[0, pl.ds(ci * block_k, block_k)]
            vmask = vmask & (sel != 0)[None, :]
        logits = jnp.where(vmask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(vmask, jnp.exp(logits - m_new), 0.0)
        l_new = l * alpha + jnp.sum(p, -1, keepdims=True)
        # values are the compressed-latent rows themselves (W_uv is
        # applied by the caller after the merge)
        acc_new = acc * alpha + jnp.dot(p, c,
                                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    carry0 = (jnp.full((h, 1), NEG_INF, jnp.float32),
              jnp.zeros((h, 1), jnp.float32),
              jnp.zeros((h, lora_rank), jnp.float32))
    m, l, acc = _paged_chunk_pipeline(n_chunks, block_k, row_copies,
                                      compute, carry0)
    if return_stats:
        m_ref[0] = m[:, 0]
        l_ref[0] = l[:, 0]
        o_ref[0] = acc
    else:
        o_ref[0] = acc / jnp.maximum(l, 1e-30)


def _mla_gather_call(q_lat, ckv, krope, idx, n_valid, sel_mask, *,
                     lora_rank, scale, block_k, interpret, return_stats,
                     shared_pool=False):
    b, h, qdim = q_lat.shape
    assert qdim > lora_rank, (q_lat.shape, lora_rank)
    if shared_pool:
        assert ckv.ndim == 2, (ckv.shape,)          # (N_phys, r)
    else:
        assert ckv.ndim == 3, (ckv.shape,)          # (B, S, r)
    n_sel = idx.shape[-1]
    assert idx.shape == (b, n_sel), (idx.shape, q_lat.shape)
    if n_valid is None:
        n_valid = jnp.full((b,), n_sel, jnp.int32)
    n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    idx, sel_mask, block_k, n_chunks = _pad_selection(
        idx.astype(jnp.int32), sel_mask, block_k)
    has_mask = sel_mask is not None
    from jax.experimental.pallas import tpu as pltpu
    k_pad = idx.shape[-1]
    r = lora_rank
    in_specs = [pl.BlockSpec((1, h, qdim),
                             lambda bi, idx_ref, nv_ref: (bi, 0, 0))]
    if has_mask:
        in_specs.append(pl.BlockSpec(
            (1, k_pad), lambda bi, idx_ref, nv_ref: (bi, 0)))
    in_specs += [pl.BlockSpec(memory_space=pltpu.ANY),
                 pl.BlockSpec(memory_space=pltpu.ANY)]
    o_spec = pl.BlockSpec((1, h, r), lambda bi, idx_ref, nv_ref: (bi, 0, 0))
    if return_stats:
        ml_spec = pl.BlockSpec((1, h), lambda bi, idx_ref, nv_ref: (bi, 0))
        out_specs = (ml_spec, ml_spec, o_spec)
        out_shape = (jax.ShapeDtypeStruct((b, h), jnp.float32),
                     jax.ShapeDtypeStruct((b, h), jnp.float32),
                     jax.ShapeDtypeStruct((b, h, r), jnp.float32))
    else:
        out_specs = o_spec
        out_shape = jax.ShapeDtypeStruct((b, h, r), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, block_k, r), ckv.dtype),
            pltpu.VMEM((2, block_k, krope.shape[-1]), krope.dtype),
            pltpu.SemaphoreType.DMA((2, 2, block_k)),
        ],
    )
    operands = (idx, n_valid, q_lat)
    if has_mask:
        operands += (sel_mask,)
    operands += (ckv, krope)
    return pl.pallas_call(
        functools.partial(_mla_gather_kernel, scale=scale,
                          lora_rank=lora_rank, block_k=block_k,
                          n_chunks=n_chunks, n_sel=n_sel,
                          has_mask=has_mask, return_stats=return_stats,
                          shared_pool=shared_pool),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=runtime.resolve_interpret(interpret),
    )(*operands)


@functools.partial(jax.jit, static_argnames=("lora_rank", "scale",
                                             "block_k", "interpret",
                                             "return_stats"))
def mla_decode_gathered_batched(q_lat: jax.Array, ckv: jax.Array,
                                krope: jax.Array, idx: jax.Array,
                                n_valid: Optional[jax.Array] = None,
                                sel_mask: Optional[jax.Array] = None, *,
                                lora_rank: int, scale: float,
                                block_k: Optional[int] = None,
                                interpret: Optional[bool] = None,
                                return_stats: bool = False):
    """Batched split-latent MLA fused gather+decode — one dispatch.

    q_lat: (B, H, r+rd) absorbed queries (f32), ckv: (B, S, r) and
    krope: (B, S, rd) latent caches in native layout, idx: (B, k) int32
    selected rows (one shared latent stream per layer — no per-head
    selection), n_valid: optional (B,) valid-selection prefix count,
    sel_mask: optional (B, k) arbitrary mask (SP ownership filtering).
    ``scale`` is the model's (qk_nope+qk_rope)**-0.5, not r**-0.5.

    Same paged chunk pipeline as the GQA variant, but each selected row
    DMAs a *pair* of latent rows (ckv, krope) and the logits are the
    absorbed-q split form q_c·c + q_r·k_r, so neither a concatenated
    latent cache copy nor an (B, S) score tensor is materialized. The
    attention values are the ckv rows themselves; the caller applies
    W_uv after (for SP shards: after the psum merge).

    Returns o_lat (B, H, r) f32, or the unnormalized flash partials
    (m, l, o~) when ``return_stats`` (see
    :func:`flash_decode_gathered_stats_batched`).
    """
    return _mla_gather_call(q_lat, ckv, krope, idx, n_valid, sel_mask,
                            lora_rank=lora_rank, scale=scale,
                            block_k=runtime.gather_block_k(
                                block_k, size=idx.shape[-1],
                                dtype=q_lat.dtype),
                            interpret=interpret,
                            return_stats=return_stats)


@functools.partial(jax.jit, static_argnames=("lora_rank", "scale",
                                             "block_k", "interpret",
                                             "return_stats"))
def mla_decode_gathered_paged(q_lat: jax.Array, ckv_pool: jax.Array,
                              krope_pool: jax.Array, phys_idx: jax.Array,
                              n_valid: Optional[jax.Array] = None,
                              sel_mask: Optional[jax.Array] = None, *,
                              lora_rank: int, scale: float,
                              block_k: Optional[int] = None,
                              interpret: Optional[bool] = None,
                              return_stats: bool = False):
    """Block-table-indirect variant of :func:`mla_decode_gathered_batched`.

    ckv_pool: (N_phys, r), krope_pool: (N_phys, rd) — the shared latent
    page pools flattened to physical rows; phys_idx: (B, k) int32
    physical rows (logical selection translated through the block table
    before the call). Same split-latent chunk pipeline; returns o_lat
    (B, H, r) f32 normalized (the serving decode wave path), or the
    unnormalized (m, l, o~) flash partials when ``return_stats`` (the
    paged sequence-parallel shards, which merge across shards first).
    """
    return _mla_gather_call(q_lat, ckv_pool, krope_pool, phys_idx,
                            n_valid, sel_mask, lora_rank=lora_rank,
                            scale=scale,
                            block_k=runtime.gather_block_k(
                                block_k, size=phys_idx.shape[-1],
                                dtype=q_lat.dtype),
                            interpret=interpret,
                            return_stats=return_stats,
                            shared_pool=True)
