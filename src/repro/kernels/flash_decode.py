"""Flash decode kernels: one query token against a KV cache.

Two variants implement the paper's "fuse gather with FlashAttention"
(§4, third optimization) on TPU:

``flash_decode``
    Dense/compacted decode: the G query heads of one GQA group attend
    over (S, d) K/V with an optional validity mask length. Used (a) for
    dense decode and (b) as stage 2 of the *gather_dense* HATA path,
    where an XLA row-gather first compacts the top-k rows — that gather
    is a single fused HBM pass, which GSPMD also partitions best.

``flash_decode_gathered``
    The fused-gather variant: top-k row indices are scalar-prefetched
    into SMEM and drive the BlockSpec index_map, so the kernel DMAs
    exactly the selected KV rows HBM->VMEM (the TPU paged-attention
    pattern with page_size = 1 row). No compacted copy is materialized.
    Trade-off (see DESIGN.md §3): row-granular DMA descriptors issue at
    (1, d) granularity — bytes win is identical to gather_dense, but the
    DMA issue rate can bind at small d; `rows_per_block` batches the
    grid so multiple row DMAs are in flight.

``flash_decode_gathered_batched``
    The production decode path: the same fused gather, batched over
    (B, H_kv) in a single grid so one dispatch serves the whole decode
    wave, reading the KV cache in its native (B, S, H_kv, d) layout.
    Applies the selection-validity mask inside the kernel, which is what
    lets the caller drop the exact-recompute correction branch the
    per-head variant needed (see core/hash_attention.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Dense / compacted decode
# ---------------------------------------------------------------------------
def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_k: int, n_blocks: int):
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = len_ref[0]

    @pl.when(ki * block_k < valid_len)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale        # (G, d)
        k = k_ref[...].astype(jnp.float32)                # (block_k, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, block_k)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(kpos < valid_len, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 valid_len: Optional[jax.Array] = None, *,
                 block_k: int = 1024, interpret: bool = True) -> jax.Array:
    """q: (G, d), k/v: (S, d), valid_len: scalar int32 (default S)."""
    g, d = q.shape
    s = k.shape[0]
    if valid_len is None:
        valid_len = jnp.int32(s)
    valid_len = jnp.asarray(valid_len, jnp.int32).reshape(1)
    block_k = min(block_k, s)
    n_blocks = pl.cdiv(s, block_k)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((g, d), lambda i, len_ref: (0, 0)),
            pl.BlockSpec((block_k, d), lambda i, len_ref: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, len_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda i, len_ref: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=d ** -0.5, block_k=block_k,
                          n_blocks=n_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), q.dtype),
        interpret=interpret,
    )(valid_len, q, k, v)


# ---------------------------------------------------------------------------
# Fused-gather decode (scalar-prefetched top-k indices)
# ---------------------------------------------------------------------------
def _gather_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, rows: int, n_blocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale            # (G, d)
    k = k_ref[...].astype(jnp.float32)                    # (rows, d)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (G, rows)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    v = v_ref[...].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(bi == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_gathered(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, idx: jax.Array, *,
                          interpret: bool = True) -> jax.Array:
    """Fused gather+decode. q: (G, d), caches: (S, d), idx: (k,) int32.

    Each grid step DMAs one selected KV row pair (page_size=1 paged
    attention); the index_map reads the scalar-prefetched idx from SMEM.
    Exact w.r.t. ``ref.gather_decode_attention_ref`` for duplicate-free
    idx (top-k indices are unique by construction).
    """
    g, d = q.shape
    n_sel = idx.shape[0]
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sel,),
        in_specs=[
            pl.BlockSpec((g, d), lambda i, idx_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda i, idx_ref: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, scale=d ** -0.5, rows=1,
                          n_blocks=n_sel),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), q.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Batched fused-gather decode: score -> select -> gather in one pipeline
# ---------------------------------------------------------------------------
def _gather_batched_kernel(idx_ref, nvalid_ref, q_ref, k_ref, v_ref,
                           o_ref, kbuf, vbuf, sems, *, scale: float,
                           block_k: int, n_sel: int):
    from jax.experimental.pallas import tpu as pltpu
    bi = pl.program_id(0)
    hi = pl.program_id(1)
    n_valid = nvalid_ref[bi, hi]
    q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, d)
    g, d = q.shape
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, d), jnp.float32)
    for base in range(0, n_sel, block_k):
        rows = min(block_k, n_sel - base)

        def row_dma(j, which, buf):
            row = idx_ref[bi, hi, base + j]
            src = (k_ref if which == 0 else v_ref)
            return pltpu.make_async_copy(
                src.at[bi, pl.ds(row, 1), hi],            # (1, d) row
                buf.at[pl.ds(j, 1)], sems.at[which, j])

        # issue every row-pair DMA of the chunk, then drain: the copies
        # overlap each other (and, on hardware, the previous chunk's
        # compute) instead of serializing row by row.
        for j in range(rows):
            row_dma(j, 0, kbuf).start()
            row_dma(j, 1, vbuf).start()
        for j in range(rows):
            row_dma(j, 0, kbuf).wait()
            row_dma(j, 1, vbuf).wait()

        k = kbuf[:rows].astype(jnp.float32)               # (rows, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, rows)
        # sel_valid applied *inside* the kernel: invalid selections'
        # logits go to -inf before the softmax. p is zeroed explicitly
        # so an all-invalid chunk can't inject exp(-inf - -inf) mass
        # while m is still at its -inf init.
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        vmask = kpos < n_valid
        logits = jnp.where(vmask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(vmask, jnp.exp(logits - m_new), 0.0)
        l = l * alpha + jnp.sum(p, -1, keepdims=True)
        v = vbuf[:rows].astype(jnp.float32)
        acc = acc * alpha + jnp.dot(p, v,
                                    preferred_element_type=jnp.float32)
        m = m_new
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_gathered_batched(q: jax.Array, k_cache: jax.Array,
                                  v_cache: jax.Array, idx: jax.Array,
                                  n_valid: Optional[jax.Array] = None, *,
                                  block_k: int = 128,
                                  interpret: bool = True) -> jax.Array:
    """Batched fused gather+decode attention — one dispatch, no vmap.

    q: (B, H_kv, G, d), k_cache/v_cache: (B, S, H_kv, d) *native* cache
    layout, idx: (B, H_kv, k) int32 selected rows, n_valid: optional
    (B, H_kv) int32 count of valid selections — entries past it are
    masked out of the softmax (idx must sort invalid entries last,
    which lax.top_k guarantees under the match-score convention).
    Returns (B, H_kv, G, d).

    The TPU paged-attention pattern with page_size = 1 row: the caches
    stay in ANY/HBM memory space (never auto-tiled into VMEM), the
    top-k indices are scalar-prefetched into SMEM, and each (B, H_kv)
    grid step manually DMAs its selected rows HBM->VMEM in
    ``block_k``-row chunks — all of a chunk's row-pair copies in flight
    at once — then runs the chunk through an online softmax. No
    transposed cache copy, no compacted intermediate; the only HBM
    traffic is the k selected rows. Invalid rows' DMAs still land (idx
    stays in-range) but their logits are masked to -inf inside the
    kernel, so the output is bit-identical to running over only the
    valid prefix (same chunk alignment).
    """
    b, h_kv, g, d = q.shape
    n_sel = idx.shape[-1]
    assert idx.shape == (b, h_kv, n_sel), (idx.shape, q.shape)
    if n_valid is None:
        n_valid = jnp.full((b, h_kv), n_sel, jnp.int32)
    assert n_valid.shape == (b, h_kv), (n_valid.shape, q.shape)
    block_k = min(block_k, n_sel)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h_kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, hi, idx_ref, nv_ref: (bi, hi, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, hi, idx_ref, nv_ref:
                               (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_k, d), k_cache.dtype),
            pltpu.VMEM((block_k, d), v_cache.dtype),
            pltpu.SemaphoreType.DMA((2, block_k)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gather_batched_kernel, scale=d ** -0.5,
                          block_k=block_k, n_sel=n_sel),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h_kv, g, d), q.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), n_valid.astype(jnp.int32), q, k_cache,
      v_cache)
