"""Causal flash attention Pallas kernel (prefill path).

Single-head kernel, online-softmax over kv blocks (Dao et al.), grid
(q_blocks, kv_blocks) with the kv dimension innermost and running
(m, l, acc) statistics held in VMEM scratch. Causally-dead kv blocks are
skipped with ``pl.when`` so the causal prefill does ~half the work.

Batch/heads are mapped by ``ops.flash_attention`` via vmap (on real TPU
the G query heads of a GQA group would be folded into the q-block
sublanes; single-head keeps the kernel readable and the grid identical).

VMEM at defaults (block_q=block_k=512, d=128, f32): q/k/v tiles 768 KiB,
acc 256 KiB, stats 4 KiB — well inside the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_kv_blocks: int,
                  q_offset: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Absolute positions of this tile.
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _body():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        logits = jax.lax.dot_general(                     # (block_q, block_k)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                               # (block_q, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        # Dead rows (everything masked so far) contribute exp(NEG_INF-m)=0.
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip tiles strictly above the diagonal band.
        first_q = qi * block_q + q_offset
        last_q = first_q + block_q - 1
        live = ki * block_k <= last_q
        if window is not None:
            live = jnp.logical_and(
                live, (ki + 1) * block_k - 1 > first_q - window)
        pl.when(live)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 512,
                    block_k: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Single-head flash attention. q: (Sq, d), k: (Sk, d), v: (Sk, dv)
    -> (Sq, dv). dv may differ from d (MLA materialized form)."""
    interpret = runtime.resolve_interpret(interpret)
    sq, d = q.shape
    sk = k.shape[0]
    dv = v.shape[-1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(sk, block_k)
    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=d ** -0.5, causal=causal, window=window,
            block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
            q_offset=q_offset),
        grid=(n_q, n_k),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, dv), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
