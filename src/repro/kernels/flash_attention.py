"""Flash attention Pallas kernels (prefill path).

Online-softmax over kv blocks (Dao et al.) with the kv dimension
innermost and running (m, l, acc) statistics held in VMEM scratch.
Causally-dead kv blocks are skipped with ``pl.when`` so the causal
prefill does ~half the work.

``flash_attention``
    The original single-head kernel, grid (q_blocks, kv_blocks). Kept as
    the readable reference / kernel-test subject; the production paths
    below fold batch + heads into one dispatch.

``flash_prefill_batched``
    The production prefill/chunked-prefill kernel: grid
    (B, H_kv, q_blocks, kv_blocks) with the G query heads of each GQA
    group folded into the q tile, reading K/V in their native
    (B, S, H_kv, d) layout — the former per-(B, H) vmap dispatch made
    XLA materialize ``g`` copies of the whole KV cache via jnp.repeat.
    ``q_offset`` is a *traced* (B,) vector read through scalar prefetch,
    so one compiled shape serves every chunk position of every prompt
    (the former static offset recompiled per chunk).

``flash_prefill_paged``
    The block-table variant: K/V tiles are whole pool pages fetched
    through a scalar-prefetched block-table ``index_map`` (the same
    indirection as ``hamming_score_paged``), so a chunked prefill
    attends over the paged cache *in place* — no gathered dense logical
    view. Garbage rows (page tails past the request's fill, scratch
    pages in unused table slots) sit at logical positions strictly
    above every live query's absolute position, so the causal mask is
    exactly the garbage mask; masked lanes contribute exact zeros (see
    the in-kernel ``p`` zeroing), keeping the output bit-identical to
    the contiguous kernel over the same logical view.

``mla_prefill_batched`` / ``mla_prefill_paged``
    The split-latent MLA twins (mirroring ``mla_decode_gathered_batched``):
    absorbed queries, logits computed in-kernel as q_c·c + q_r·k_r over
    the (ckv, krope) latent streams, values are the ckv rows themselves
    (the caller applies W_uv) — no per-head K/V is ever materialized
    from the latent cache (the former chunked MLA prefill up-projected
    the *whole* gathered logical view every chunk).

Accumulation convention (bit-exactness contract): masked lanes are
forced to exactly 0 probability mass, so an all-masked tile is an exact
identity on (m, l, acc) and the online softmax is invariant to the
q-chunk partition — chunked prefill equals the same prompt prefilled in
one chunk bit-for-bit, and the dead-tile ``pl.when`` skip equals
processing the tile.

VMEM at defaults (block_q=256, block_k=512, g=8, d=128, f32): q tile
1 MiB, k/v tiles 512 KiB, acc 1 MiB, stats 16 KiB — inside the ~16 MiB
VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_kv_blocks: int,
                  q_offset: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Absolute positions of this tile.
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _body():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        logits = jax.lax.dot_general(                     # (block_q, block_k)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                               # (block_q, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        # Dead rows (everything masked so far) contribute exp(NEG_INF-m)=0.
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip tiles strictly above the diagonal band.
        first_q = qi * block_q + q_offset
        last_q = first_q + block_q - 1
        live = ki * block_k <= last_q
        if window is not None:
            live = jnp.logical_and(
                live, (ki + 1) * block_k - 1 > first_q - window)
        pl.when(live)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Single-head flash attention. q: (Sq, d), k: (Sk, d), v: (Sk, dv)
    -> (Sq, dv). dv may differ from d (MLA materialized form)."""
    interpret = runtime.resolve_interpret(interpret)
    sq, d = q.shape
    sk = k.shape[0]
    dv = v.shape[-1]
    block_q = runtime.attn_block_q(block_q, size=sk, dtype=q.dtype)
    block_k = runtime.attn_block_k(block_k, size=sk, dtype=q.dtype)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(sk, block_k)
    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=d ** -0.5, causal=causal, window=window,
            block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
            q_offset=q_offset),
        grid=(n_q, n_k),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, dv), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _offset_vec(q_offset, b: int) -> jax.Array:
    """Broadcast a scalar/None/(B,) traced offset to a (B,) int32."""
    if q_offset is None:
        return jnp.zeros((b,), jnp.int32)
    return jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))


# ---------------------------------------------------------------------------
# Batched GQA flash prefill (traced q_offset, GQA folded into the tile)
# ---------------------------------------------------------------------------
def _prefill_batched_kernel(*refs, scale: float, causal: bool,
                            window: Optional[int], block_q: int,
                            block_k: int, n_kv_blocks: int, g: int,
                            sk: int, paged: bool,
                            windowed_pages: int = 0):
    if paged:
        bt_ref, qoff_ref, q_ref, k_ref, v_ref = refs[:5]
        del bt_ref                      # consumed by the index_map
        refs = refs[5:]
    else:
        qoff_ref, q_ref, k_ref, v_ref = refs[:4]
        refs = refs[4:]
    o_ref, m_ref, l_ref, acc_ref = refs

    bi = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = qoff_ref[bi]
    # Windowed page-skip (paged only): the grid's kv extent was cut to
    # the ``windowed_pages`` pages that can intersect the window band
    # [off - window + 1, off + sq), and the index_map rebased the page
    # fetch at ``base`` = the first possibly-live page — recompute the
    # same traced base here so absolute kv positions stay aligned with
    # the pages actually fetched (bit-exact: the dropped pages are all
    # strictly below the window, i.e. exact identities on (m, l, acc)).
    if windowed_pages:
        base = jnp.clip((off - window + 1) // block_k, 0,
                        sk // block_k - n_kv_blocks)
        k_start = (base + ki) * block_k
    else:
        k_start = ki * block_k
    rows = block_q * g
    # Folded row r holds (q-row r // g, group head r % g); absolute
    # positions depend only on the q-row.
    qpos = off + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (rows, block_k), 0) // g
    kpos = k_start + jax.lax.broadcasted_iota(
        jnp.int32, (rows, block_k), 1)

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale      # (block_q, g, d)
        q2 = q.reshape(rows, q.shape[-1])
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (block_k, d)
        logits = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (rows, block_k)
        mask = kpos < sk                              # static k padding
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # Masked lanes carry exactly 0 mass (not exp(NEG_INF - m), which
        # is 1 while m is still at its -inf init): an all-masked tile is
        # an exact identity on (m, l, acc), which is what makes the
        # accumulation invariant to the chunk partition (chunked ≡
        # monolithic bit-for-bit) and the dead-tile skip exact.
        p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)     # (block_k, dv)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # Skip tiles strictly above the diagonal band — and, with a
        # window, whole tiles strictly below it. The predicate is
        # traced (q_offset comes from SMEM) — pl.when handles it.
        first_q = off + qi * block_q
        live = k_start <= first_q + block_q - 1
        if window is not None:
            live = jnp.logical_and(
                live, k_start + block_k - 1 > first_q - window)
        pl.when(live)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l).astype(o_ref.dtype)
        o_ref[0] = out.reshape(block_q, g, out.shape[-1])


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_prefill_batched(q: jax.Array, k: jax.Array, v: jax.Array,
                          q_offset: Optional[jax.Array] = None, *,
                          causal: bool = True,
                          window: Optional[int] = None,
                          block_q: Optional[int] = None,
                          block_k: Optional[int] = None,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Batched GQA flash prefill — one dispatch, no vmap, no K/V copies.

    q: (B, Sq, H, d), k: (B, Sk, H_kv, d), v: (B, Sk, H_kv, dv) in
    their native layouts; q_offset: traced scalar or (B,) int32 absolute
    position of q[:, 0] (None = 0) — read via scalar prefetch, so every
    chunk position of a chunked prefill reuses one compiled shape.
    Returns (B, Sq, H, dv) in q.dtype.

    Grid (B, H_kv, q-blocks, kv-blocks): each step processes one GQA
    group, its G query heads folded into the q tile as ``block_q * g``
    MXU rows — where the former per-(B, H) vmap forced XLA to
    ``jnp.repeat`` the K/V cache ``g`` times before dispatch.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, sq, h, d = q.shape
    b2, sk, h_kv, d2 = k.shape
    assert (b, d) == (b2, d2) and h % h_kv == 0, (q.shape, k.shape)
    block_q = runtime.prefill_block_q(block_q, size=sk, dtype=q.dtype)
    block_k = runtime.prefill_block_k(block_k, size=sk, dtype=q.dtype)
    g = h // h_kv
    dv = v.shape[-1]
    q_off = _offset_vec(q_offset, b)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(sk, block_k)
    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h_kv, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, g, d),
                         lambda bi, hi, qi, ki, off: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki, off: (bi, ki, hi, 0)),
            pl.BlockSpec((1, block_k, 1, dv),
                         lambda bi, hi, qi, ki, off: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g, dv),
                               lambda bi, hi, qi, ki, off: (bi, qi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, 1), jnp.float32),
            pltpu.VMEM((block_q * g, 1), jnp.float32),
            pltpu.VMEM((block_q * g, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _prefill_batched_kernel, scale=d ** -0.5, causal=causal,
            window=window, block_q=block_q, block_k=block_k,
            n_kv_blocks=n_k, g=g, sk=sk, paged=False),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dv), q.dtype),
        interpret=interpret,
    )(q_off, q, k, v)


@functools.partial(jax.jit, static_argnames=("window", "block_q",
                                             "interpret"))
def flash_prefill_paged(q: jax.Array, k_pool: jax.Array,
                        v_pool: jax.Array, block_table: jax.Array,
                        q_offset: Optional[jax.Array] = None, *,
                        window: Optional[int] = None,
                        block_q: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Block-table variant of :func:`flash_prefill_batched`.

    q: (B, C, H, d) the prefill chunk; k_pool/v_pool:
    (P, page, H_kv, d) — the shared per-layer page pools, read *in
    place*; block_table: (B, T) int32 page ids; q_offset: traced scalar
    or (B,) tokens already in the cache. Returns (B, C, H, dv).

    One kv tile = one pool page, fetched through the scalar-prefetched
    block-table index_map (the ``hamming_score_paged`` indirection).
    Always causal at absolute positions: every garbage row the table
    can name (page tails past the fill, scratch pages in unused slots)
    sits at a logical position strictly above every live query, so
    causality is exactly the garbage mask and the output is
    bit-identical to the contiguous kernel over the gathered logical
    view (same page-sized kv blocking).

    Sliding-window page-skip: with ``window`` set, only
    ``ceil((C + window) / page) + 1`` pages can intersect the causal
    window band of a C-row chunk, so the kv grid is cut to that many
    steps and the index_map *rebases* the page walk at the first
    possibly-live page (a traced function of the prefetched
    ``q_offset``) instead of scoring the full table width. Pages
    strictly below the window are never fetched; in-kernel masking
    makes the skip bit-exact vs. the full-width walk.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, sq, h, d = q.shape
    p, page, h_kv, d2 = k_pool.shape
    block_q = runtime.prefill_block_q(block_q, size=p * page,
                                      dtype=q.dtype)
    assert d == d2 and h % h_kv == 0, (q.shape, k_pool.shape)
    g = h // h_kv
    dv = v_pool.shape[-1]
    b2, t = block_table.shape
    assert b == b2, (q.shape, block_table.shape)
    q_off = _offset_vec(q_offset, b)
    block_q = min(block_q, sq)
    n_q = pl.cdiv(sq, block_q)
    t_live = t
    if window is not None:
        # pages intersecting [off - window + 1, off + sq - 1]: the span
        # is sq + window - 1 rows, straddling at most this many pages
        t_live = min(t, (sq + window - 2) // page + 2)

    def _page(ki, bt, off, bi):
        if window is None or t_live == t:
            return bt[bi, ki]
        base = jnp.clip((off[bi] - window + 1) // page, 0, t - t_live)
        return bt[bi, base + ki]

    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h_kv, n_q, t_live),
        in_specs=[
            pl.BlockSpec((1, block_q, g, d),
                         lambda bi, hi, qi, ki, bt, off: (bi, qi, hi, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, hi, qi, ki, bt, off:
                         (_page(ki, bt, off, bi), 0, hi, 0)),
            pl.BlockSpec((1, page, 1, dv),
                         lambda bi, hi, qi, ki, bt, off:
                         (_page(ki, bt, off, bi), 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g, dv),
                               lambda bi, hi, qi, ki, bt, off:
                               (bi, qi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, 1), jnp.float32),
            pltpu.VMEM((block_q * g, 1), jnp.float32),
            pltpu.VMEM((block_q * g, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _prefill_batched_kernel, scale=d ** -0.5, causal=True,
            window=window, block_q=block_q, block_k=page,
            n_kv_blocks=t_live, g=g, sk=t * page, paged=True,
            windowed_pages=0 if (window is None or t_live == t)
            else t_live),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dv), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), q_off, q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# Split-latent MLA flash prefill (absorbed q, q_c·c + q_r·k_r in-kernel)
# ---------------------------------------------------------------------------
def _mla_prefill_kernel(*refs, scale: float, lora_rank: int,
                        block_q: int, block_k: int, n_kv_blocks: int,
                        h: int, sk: int, paged: bool):
    if paged:
        bt_ref, qoff_ref, q_ref, c_ref, r_ref = refs[:5]
        del bt_ref
        refs = refs[5:]
    else:
        qoff_ref, q_ref, c_ref, r_ref = refs[:4]
        refs = refs[4:]
    o_ref, m_ref, l_ref, acc_ref = refs

    bi = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = qoff_ref[bi]
    rows = block_q * h
    qpos = off + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (rows, block_k), 0) // h
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (rows, block_k), 1)

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale    # (block_q, H, r+rd)
        q2 = q.reshape(rows, q.shape[-1])
        q_c = q2[:, :lora_rank]
        q_r = q2[:, lora_rank:]
        c = c_ref[0].astype(jnp.float32)            # (block_k, r)
        kr = r_ref[0].astype(jnp.float32)           # (block_k, rd)
        # absorbed-q split-latent logits: q·[c;k_r] = q_c·c + q_r·k_r —
        # no per-head K is ever materialized from the latent stream.
        logits = (jax.lax.dot_general(
                      q_c, c, (((1,), (1,)), ((), ())),
                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(
                      q_r, kr, (((1,), (1,)), ((), ())),
                      preferred_element_type=jnp.float32))
        mask = (kpos < sk) & (kpos <= qpos)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        # values are the compressed-latent rows themselves (the caller
        # applies W_uv after)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, c, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    live = ki * block_k <= off + qi * block_q + block_q - 1
    pl.when(live)(_body)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = acc_ref[...] / l
        o_ref[0] = out.reshape(block_q, h, lora_rank)


@functools.partial(jax.jit, static_argnames=(
    "lora_rank", "scale", "block_q", "block_k", "interpret"))
def mla_prefill_batched(q_lat: jax.Array, ckv: jax.Array,
                        krope: jax.Array,
                        q_offset: Optional[jax.Array] = None, *,
                        lora_rank: int, scale: float,
                        block_q: Optional[int] = None,
                        block_k: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Batched split-latent MLA flash prefill (the prefill twin of
    ``mla_decode_gathered_batched``).

    q_lat: (B, C, H, r+rd) absorbed queries (f32 — W_uk folded in);
    ckv: (B, S, r) and krope: (B, S, rd) latent caches in native
    layout; q_offset: traced scalar or (B,) absolute position of
    q_lat[:, 0]. ``scale`` is the model's (qk_nope+qk_rope)**-0.5.
    Returns o_lat (B, C, H, r) f32 — the caller applies W_uv.

    All H query heads share the one latent stream, so they fold into
    the q tile (grid (B, q-blocks, kv-blocks)) and the logits are the
    split form q_c·c + q_r·k_r — neither a concatenated latent copy nor
    per-head K/V up-projections of the context are ever materialized.
    Always causal (the chunked-prefill context read).
    """
    interpret = runtime.resolve_interpret(interpret)
    b, sq, h, qdim = q_lat.shape
    assert qdim > lora_rank, (q_lat.shape, lora_rank)
    b2, sk, r = ckv.shape
    block_q = runtime.prefill_block_q(block_q, size=sk,
                                      dtype=q_lat.dtype)
    block_k = runtime.prefill_block_k(block_k, size=sk,
                                      dtype=q_lat.dtype)
    assert b == b2 and r == lora_rank, (q_lat.shape, ckv.shape)
    rd = krope.shape[-1]
    q_off = _offset_vec(q_offset, b)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(sk, block_k)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, h, qdim),
                         lambda bi, qi, ki, off: (bi, qi, 0, 0)),
            pl.BlockSpec((1, block_k, r),
                         lambda bi, qi, ki, off: (bi, ki, 0)),
            pl.BlockSpec((1, block_k, rd),
                         lambda bi, qi, ki, off: (bi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, h, r),
                               lambda bi, qi, ki, off: (bi, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q * h, 1), jnp.float32),
            pltpu.VMEM((block_q * h, 1), jnp.float32),
            pltpu.VMEM((block_q * h, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _mla_prefill_kernel, scale=scale, lora_rank=lora_rank,
            block_q=block_q, block_k=block_k, n_kv_blocks=n_k, h=h,
            sk=sk, paged=False),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, r), jnp.float32),
        interpret=interpret,
    )(q_off, q_lat, ckv, krope)


@functools.partial(jax.jit, static_argnames=(
    "lora_rank", "scale", "block_q", "interpret"))
def mla_prefill_paged(q_lat: jax.Array, ckv_pool: jax.Array,
                      krope_pool: jax.Array, block_table: jax.Array,
                      q_offset: Optional[jax.Array] = None, *,
                      lora_rank: int, scale: float,
                      block_q: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Block-table variant of :func:`mla_prefill_batched`.

    ckv_pool: (P, page, r), krope_pool: (P, page, rd) — the shared
    latent page pools read in place; block_table: (B, T) int32. One kv
    tile = one (ckv, krope) page pair through the scalar-prefetched
    index_map; causality at absolute positions masks every garbage row
    (see :func:`flash_prefill_paged`).
    """
    interpret = runtime.resolve_interpret(interpret)
    b, sq, h, qdim = q_lat.shape
    assert qdim > lora_rank, (q_lat.shape, lora_rank)
    p, page, r = ckv_pool.shape
    block_q = runtime.prefill_block_q(block_q, size=p * page,
                                      dtype=q_lat.dtype)
    assert r == lora_rank, (ckv_pool.shape, lora_rank)
    rd = krope_pool.shape[-1]
    b2, t = block_table.shape
    assert b == b2, (q_lat.shape, block_table.shape)
    q_off = _offset_vec(q_offset, b)
    block_q = min(block_q, sq)
    n_q = pl.cdiv(sq, block_q)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_q, t),
        in_specs=[
            pl.BlockSpec((1, block_q, h, qdim),
                         lambda bi, qi, ki, bt, off: (bi, qi, 0, 0)),
            pl.BlockSpec((1, page, r),
                         lambda bi, qi, ki, bt, off: (bt[bi, ki], 0, 0)),
            pl.BlockSpec((1, page, rd),
                         lambda bi, qi, ki, bt, off: (bt[bi, ki], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, h, r),
                               lambda bi, qi, ki, bt, off: (bi, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q * h, 1), jnp.float32),
            pltpu.VMEM((block_q * h, 1), jnp.float32),
            pltpu.VMEM((block_q * h, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _mla_prefill_kernel, scale=scale, lora_rank=lora_rank,
            block_q=block_q, block_k=page, n_kv_blocks=t, h=h,
            sk=t * page, paged=True),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, r), jnp.float32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), q_off, q_lat, ckv_pool, krope_pool)
