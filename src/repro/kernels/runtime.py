"""Autotuner-backed dispatch layer for the Pallas kernel stack.

Two knob classes live here.

**Interpret mode.** One switch decides whether every kernel entry point
runs compiled (Mosaic) or in interpret mode, instead of each entry
point hardcoding ``interpret=True``:

  * auto (default): ``interpret=False`` iff ``jax.default_backend()``
    is ``"tpu"`` — the kernels compile on real hardware and emulate
    everywhere else (CPU CI, tests, benchmarks).
  * ``REPRO_PALLAS_INTERPRET=0|1`` overrides the auto rule (e.g. force
    interpret on a TPU host while bisecting a Mosaic lowering issue, or
    assert-compile in a TPU CI job).

**Block sizes.** Every kernel family is registered in :data:`KERNELS`
with its tunable tile parameters, and resolution goes through ONE
precedence chain (:func:`resolve`)::

    explicit caller arg  >  env knob  >  tuning table  >  builtin

  * *explicit arg* — tests and benchmarks pin tilings to compare
    kernels at matched blocking; passed through untouched.
  * *env knob* (``REPRO_GATHER_BLOCK_K`` etc.) — the deployment
    escape hatch; validated (positive, backend-alignment) with an
    error naming the knob.
  * *tuning table* — a persisted JSON table keyed on
    (kernel, shape-bucket, dtype, backend). Defaults ship in
    ``kernels/tuning/default.json``; ``REPRO_TUNING_TABLE=<path>``
    points at a site-specific table (e.g. one emitted by
    ``repro.kernels.autotune`` / ``benchmarks/autotune_sweep.py``).
  * *builtin* — the hand-tuned seed defaults, so an empty or missing
    table is never an error.

The table's **backend** key is ``jax.default_backend()`` (``cpu`` /
``tpu`` / ``gpu``) or ``"*"``; **dtype** is a jnp dtype name or
``"*"``; **bucket** is a positive integer — the entry covers every
size up to it, and lookup picks the *tightest* covering bucket — or
``"*"`` (any size). The autotuner only ever emits numerics-preserving
configs (bit-exactness is asserted per candidate), so switching tables
must never change model outputs; see DESIGN.md §3 "Autotuner &
dispatch".

Resolution happens at trace time: the kernel wrappers are jitted with
``interpret``/``block_*`` as static args, so the first call under a
given configuration bakes it into the jit cache. Change the env before
the process imports jax, not mid-run.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def _env_flag(name: str) -> Optional[bool]:
    val = os.environ.get(name)
    if val is None:
        return None
    low = val.strip().lower()
    if low in _TRUTHY:
        return True
    if low in _FALSY:
        return False
    raise ValueError(f"{name}={val!r}: expected one of "
                     f"{_TRUTHY + _FALSY}")


def use_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode."""
    override = _env_flag("REPRO_PALLAS_INTERPRET")
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Kernel entry points pass their ``interpret=None`` default here."""
    return use_interpret() if interpret is None else bool(interpret)


# ===========================================================================
# Kernel registry
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One tunable tile parameter of a kernel family."""
    env: str            # env knob; wins over the table
    default: int        # builtin fallback (the hand-tuned seed value)
    tpu_align: int = 8  # required multiple when resolving for TPU


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Registry entry: tunable params + what the bucket axis measures."""
    params: Mapping[str, ParamSpec]
    size_axis: str


KERNELS: Dict[str, KernelSpec] = {
    # fused projection+sign+pack; tiles the encoded rows
    "hash_encode": KernelSpec(
        {"block_s": ParamSpec("REPRO_ENCODE_BLOCK_S", 512)},
        "rows encoded per call"),
    # batched Hamming scoring; tiles the code-cache rows
    "hamming_score": KernelSpec(
        {"block_s": ParamSpec("REPRO_HAMMING_BLOCK_S", 2048)},
        "code-cache rows (S)"),
    # fused top-k gather+decode; DMA chunk over the selected rows
    "gather_decode": KernelSpec(
        {"block_k": ParamSpec("REPRO_GATHER_BLOCK_K", 128)},
        "selected rows (budget k)"),
    # dense single-sequence flash decode; tiles the kv cache rows
    "flash_decode": KernelSpec(
        {"block_k": ParamSpec("REPRO_DECODE_BLOCK_K", 1024)},
        "cache rows (S)"),
    # batched flash prefill; q tile x kv tile (paged twins tile kv at
    # the pool page size instead — tune that via "paged_pool")
    "flash_prefill": KernelSpec(
        {"block_q": ParamSpec("REPRO_PREFILL_BLOCK_Q", 256),
         "block_k": ParamSpec("REPRO_PREFILL_BLOCK_K", 512)},
        "kv rows (S_k)"),
    # single-head training/prefill flash attention
    "flash_attention": KernelSpec(
        {"block_q": ParamSpec("REPRO_ATTN_BLOCK_Q", 512),
         "block_k": ParamSpec("REPRO_ATTN_BLOCK_K", 512)},
        "sequence rows (S)"),
    # serving page pools: the paged kernels always tile kv at the pool
    # page size, so pool construction time IS their block-size decision
    "paged_pool": KernelSpec(
        {"page_size": ParamSpec("REPRO_PAGE_SIZE", 8)},
        "rows per page"),
}


class TuningTableError(ValueError):
    """A tuning table failed schema validation (hard error — a
    malformed table must never silently fall back to defaults)."""


Bucket = Union[int, str]


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One validated tuning-table entry."""
    kernel: str
    backend: str                    # "cpu" | "tpu" | "gpu" | "*"
    dtype: str                     # jnp dtype name | "*"
    bucket: Bucket                 # covers sizes <= bucket; "*" = any
    config: Mapping[str, int]


class TuningTable:
    """Parsed, validated table with (backend, dtype, bucket) lookup."""

    def __init__(self, entries: List[KernelConfig], path: str):
        self.entries = entries
        self.path = path

    def lookup(self, kernel: str, *, backend: str,
               dtype: Optional[str], size: Optional[int]
               ) -> Optional[Mapping[str, int]]:
        """Most-specific covering entry: exact backend beats ``"*"``,
        exact dtype beats ``"*"``, the tightest bucket >= size beats a
        wildcard bucket. Returns the entry's config dict or None."""
        best: Optional[KernelConfig] = None
        best_key: Optional[Tuple] = None
        for e in self.entries:
            if e.kernel != kernel:
                continue
            if e.backend != "*" and e.backend != backend:
                continue
            if e.dtype != "*" and (dtype is None or e.dtype != dtype):
                continue
            if e.bucket == "*":
                bucket_rank: Tuple[int, int] = (1, 0)
            else:
                if size is None or int(e.bucket) < size:
                    continue
                bucket_rank = (0, int(e.bucket))
            key = (0 if e.backend != "*" else 1,
                   0 if e.dtype != "*" else 1,
                   bucket_rank)
            if best is None or key < best_key:
                best, best_key = e, key
        return None if best is None else best.config


def _validate_entry(raw: Any, i: int, path: str) -> KernelConfig:
    ctx = f"{path}: entries[{i}]"
    if not isinstance(raw, dict):
        raise TuningTableError(f"{ctx}: expected an object, got "
                               f"{type(raw).__name__}")
    required = {"kernel", "backend", "dtype", "bucket", "config"}
    extra = set(raw) - required
    if extra or set(raw) != required:
        raise TuningTableError(
            f"{ctx}: keys must be exactly {sorted(required)} "
            f"(got {sorted(raw)})")
    kernel = raw["kernel"]
    if kernel not in KERNELS:
        raise TuningTableError(
            f"{ctx}: unknown kernel {kernel!r} — known kernels: "
            f"{sorted(KERNELS)}")
    for field in ("backend", "dtype"):
        if not isinstance(raw[field], str) or not raw[field]:
            raise TuningTableError(
                f"{ctx}: {field} must be a non-empty string "
                f"(got {raw[field]!r})")
    bucket = raw["bucket"]
    if bucket != "*":
        if not isinstance(bucket, int) or isinstance(bucket, bool) \
                or bucket <= 0:
            raise TuningTableError(
                f"{ctx}: bucket must be a positive integer or \"*\" "
                f"(got {bucket!r})")
    config = raw["config"]
    if not isinstance(config, dict) or not config:
        raise TuningTableError(
            f"{ctx}: config must be a non-empty object of "
            f"param -> int (got {config!r})")
    spec = KERNELS[kernel]
    for pname, pval in config.items():
        if pname not in spec.params:
            raise TuningTableError(
                f"{ctx}: kernel {kernel!r} has no tunable param "
                f"{pname!r} — tunable: {sorted(spec.params)}")
        if not isinstance(pval, int) or isinstance(pval, bool) \
                or pval <= 0:
            raise TuningTableError(
                f"{ctx}: {kernel}.{pname} must be a positive integer "
                f"(got {pval!r})")
    return KernelConfig(kernel=kernel, backend=raw["backend"],
                        dtype=raw["dtype"], bucket=bucket,
                        config=dict(config))


def parse_table(obj: Any, path: str = "<inline>") -> TuningTable:
    """Validate a decoded JSON table object. Raises
    :class:`TuningTableError` on any schema violation."""
    if not isinstance(obj, dict):
        raise TuningTableError(f"{path}: table must be a JSON object")
    if obj.get("version") != 1:
        raise TuningTableError(
            f"{path}: unsupported table version {obj.get('version')!r} "
            "(expected 1)")
    entries = obj.get("entries")
    if not isinstance(entries, list):
        raise TuningTableError(f"{path}: \"entries\" must be a list")
    return TuningTable(
        [_validate_entry(e, i, path) for i, e in enumerate(entries)],
        path)


_DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(__file__),
                                   "tuning", "default.json")


@functools.lru_cache(maxsize=None)
def _load_table(path: str) -> TuningTable:
    try:
        with open(path) as f:
            obj = json.load(f)
    except FileNotFoundError:
        raise TuningTableError(
            f"tuning table not found: {path} (REPRO_TUNING_TABLE must "
            "point at an existing table; the packaged default lives at "
            f"{_DEFAULT_TABLE_PATH})")
    except json.JSONDecodeError as e:
        raise TuningTableError(f"{path}: not valid JSON ({e})")
    return parse_table(obj, path)


def active_table() -> TuningTable:
    """The table in effect: ``REPRO_TUNING_TABLE`` or the packaged
    default. Parsed once per path (lru-cached)."""
    return _load_table(os.environ.get("REPRO_TUNING_TABLE")
                       or _DEFAULT_TABLE_PATH)


def clear_table_cache() -> None:
    """Drop parsed-table caches (tests that rewrite a table in place)."""
    _load_table.cache_clear()


# ===========================================================================
# Resolution
# ===========================================================================
def block_env(name: str, default: int) -> int:
    """Env-tunable block size. Unset -> ``default``; set -> validated
    positive integer (a 0/negative/garbage knob used to crash deep
    inside the kernel trace with no pointer back to the knob)."""
    val = os.environ.get(name)
    if val is None:
        return default
    try:
        ival = int(val)
    except ValueError:
        raise ValueError(
            f"{name}={val!r}: expected a positive integer block size")
    if ival <= 0:
        raise ValueError(
            f"{name}={ival}: block sizes must be positive (the knob "
            "counts rows per kernel tile)")
    return ival


def _check_aligned(kernel: str, param: str, value: int, spec: ParamSpec,
                   backend: str, source: str) -> int:
    where = f"{source}; override knob: {spec.env}" \
        if spec.env not in source else source
    if value <= 0:
        raise ValueError(
            f"{kernel}.{param}={value} (from {where}): block sizes "
            "must be positive")
    if backend == "tpu" and spec.tpu_align > 1 \
            and value % spec.tpu_align != 0:
        raise ValueError(
            f"{kernel}.{param}={value} (from {where}): must be a "
            f"multiple of {spec.tpu_align} on the tpu backend "
            "(f32 sublane tiling — see DESIGN.md §3 "
            "'Autotuner & dispatch')")
    return value


def _dtype_key(dtype) -> Optional[str]:
    if dtype is None:
        return None
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


def resolve(kernel: str, param: str, explicit: Optional[int] = None, *,
            size: Optional[int] = None, dtype=None,
            backend: Optional[str] = None) -> int:
    """Resolve one tile parameter: explicit > env > table > builtin.

    ``size`` is the kernel's bucket axis (see the registry's
    ``size_axis``); ``dtype`` the stream dtype; both optional hints —
    without them only wildcard table entries match. Explicit caller
    args bypass validation (kernel tests pin arbitrary tilings);
    env- and table-sourced values are validated against the backend's
    alignment with an error naming the knob.
    """
    spec = KERNELS[kernel].params[param]
    if explicit is not None:
        return int(explicit)
    backend = backend or jax.default_backend()
    if os.environ.get(spec.env) is not None:
        return _check_aligned(kernel, param,
                              block_env(spec.env, spec.default), spec,
                              backend, f"env {spec.env}")
    cfg = active_table().lookup(kernel, backend=backend,
                                dtype=_dtype_key(dtype), size=size)
    if cfg is not None and param in cfg:
        return _check_aligned(kernel, param, cfg[param], spec, backend,
                              f"tuning table {active_table().path}")
    return spec.default


# ---------------------------------------------------------------------------
# Per-kernel getters (the dispatch surface the kernel wrappers call).
# Signatures stay compatible with the old flat-env getters; ``size`` /
# ``dtype`` hints opt a call site into shape-bucketed table entries.
# ---------------------------------------------------------------------------
def gather_block_k(block_k: Optional[int] = None, *,
                   size: Optional[int] = None, dtype=None) -> int:
    """Rows per DMA chunk of the paged fused-gather kernels
    (bucket axis: the selection budget k)."""
    return resolve("gather_decode", "block_k", block_k, size=size,
                   dtype=dtype)


def hamming_block_s(block_s: Optional[int] = None, *,
                    size: Optional[int] = None, dtype=None) -> int:
    """Code-cache rows per tile of the batched Hamming kernels."""
    return resolve("hamming_score", "block_s", block_s, size=size,
                   dtype=dtype)


def encode_block_s(block_s: Optional[int] = None, *,
                   size: Optional[int] = None, dtype=None) -> int:
    """Sequence rows per tile of the fused hash-encode kernel."""
    return resolve("hash_encode", "block_s", block_s, size=size,
                   dtype=dtype)


def decode_block_k(block_k: Optional[int] = None, *,
                   size: Optional[int] = None, dtype=None) -> int:
    """KV rows per tile of the dense flash-decode kernel."""
    return resolve("flash_decode", "block_k", block_k, size=size,
                   dtype=dtype)


def prefill_block_q(block_q: Optional[int] = None, *,
                    size: Optional[int] = None, dtype=None) -> int:
    """Query rows per tile of the batched flash-prefill kernels. The
    GQA group (or all H heads for MLA) is folded into the tile, so the
    folded row count is ``block_q * g`` — size it with that in mind."""
    return resolve("flash_prefill", "block_q", block_q, size=size,
                   dtype=dtype)


def prefill_block_k(block_k: Optional[int] = None, *,
                    size: Optional[int] = None, dtype=None) -> int:
    """KV rows per tile of the batched flash-prefill kernels (the paged
    variants always tile at the pool's page size instead)."""
    return resolve("flash_prefill", "block_k", block_k, size=size,
                   dtype=dtype)


def attn_block_q(block_q: Optional[int] = None, *,
                 size: Optional[int] = None, dtype=None) -> int:
    """Query rows per tile of the single-head flash attention."""
    return resolve("flash_attention", "block_q", block_q, size=size,
                   dtype=dtype)


def attn_block_k(block_k: Optional[int] = None, *,
                 size: Optional[int] = None, dtype=None) -> int:
    """KV rows per tile of the single-head flash attention."""
    return resolve("flash_attention", "block_k", block_k, size=size,
                   dtype=dtype)


def pool_page_size(page_size: Optional[int] = None, *,
                   dtype=None) -> int:
    """Rows per page of the serving page pools. The paged score /
    prefill / gather kernels all tile kv at the pool page size, so
    this is THEIR block-size decision, made once at
    ``init_paged_pools`` / ``init_offloaded_pools`` time (the table's
    tpu entry carries the >=128-row pages the MXU wants; CPU keeps the
    small pages the allocator-granularity tests assume)."""
    return resolve("paged_pool", "page_size", page_size, dtype=dtype)
