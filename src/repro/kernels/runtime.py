"""Runtime knobs for the Pallas kernel stack.

One switch decides whether every kernel entry point runs compiled
(Mosaic) or in interpret mode, instead of each entry point hardcoding
``interpret=True``:

  * auto (default): ``interpret=False`` iff ``jax.default_backend()``
    is ``"tpu"`` — the kernels compile on real hardware and emulate
    everywhere else (CPU CI, tests, benchmarks).
  * ``REPRO_PALLAS_INTERPRET=0|1`` overrides the auto rule (e.g. force
    interpret on a TPU host while bisecting a Mosaic lowering issue, or
    assert-compile in a TPU CI job).

Block sizes are the second knob class. Every kernel keeps a tuned
default but reads it through :func:`block_env`, so a deployment can
sweep ``REPRO_GATHER_BLOCK_K`` / ``REPRO_HAMMING_BLOCK_S`` / ... without
touching call sites (see DESIGN.md §3 for what each block controls).

Resolution happens at trace time: the kernel wrappers are jitted with
``interpret``/``block_*`` as static args, so the first call under a
given configuration bakes it into the jit cache. Change the env before
the process imports jax, not mid-run.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def _env_flag(name: str) -> Optional[bool]:
    val = os.environ.get(name)
    if val is None:
        return None
    low = val.strip().lower()
    if low in _TRUTHY:
        return True
    if low in _FALSY:
        return False
    raise ValueError(f"{name}={val!r}: expected one of "
                     f"{_TRUTHY + _FALSY}")


def use_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode."""
    override = _env_flag("REPRO_PALLAS_INTERPRET")
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Kernel entry points pass their ``interpret=None`` default here."""
    return use_interpret() if interpret is None else bool(interpret)


def block_env(name: str, default: int) -> int:
    """Env-tunable block size (``None``-default resolution helper)."""
    val = os.environ.get(name)
    return default if val is None else int(val)


def gather_block_k(block_k: Optional[int] = None) -> int:
    """Rows per DMA chunk of the paged fused-gather kernels."""
    if block_k is not None:
        return block_k
    return block_env("REPRO_GATHER_BLOCK_K", 128)


def hamming_block_s(block_s: Optional[int] = None) -> int:
    """Code-cache rows per tile of the batched Hamming kernels."""
    if block_s is not None:
        return block_s
    return block_env("REPRO_HAMMING_BLOCK_S", 2048)


def encode_block_s(block_s: Optional[int] = None) -> int:
    """Sequence rows per tile of the fused hash-encode kernel."""
    if block_s is not None:
        return block_s
    return block_env("REPRO_ENCODE_BLOCK_S", 512)


def prefill_block_q(block_q: Optional[int] = None) -> int:
    """Query rows per tile of the batched flash-prefill kernels. The
    GQA group (or all H heads for MLA) is folded into the tile, so the
    folded row count is ``block_q * g`` — size it with that in mind."""
    if block_q is not None:
        return block_q
    return block_env("REPRO_PREFILL_BLOCK_Q", 256)


def prefill_block_k(block_k: Optional[int] = None) -> int:
    """KV rows per tile of the batched flash-prefill kernels (the paged
    variants always tile at the pool's page size instead)."""
    if block_k is not None:
        return block_k
    return block_env("REPRO_PREFILL_BLOCK_K", 512)
