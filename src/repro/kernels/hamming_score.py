"""High-performance Hamming score Pallas kernel (paper §4, second opt).

The GPU version is XOR + ``popc`` + warp reduction with coalesced
HBM->SRAM loads. The TPU mapping: the packed code cache streams
HBM->VMEM in (block_s, W) uint32 tiles, XOR against the (G, W) query
codes broadcast from VMEM, ``lax.population_count`` on the VPU, and a
sublane reduction over the G query heads sharing the kv head (the GQA
aggregation of paper §3.2 fused into the same kernel).

This operator is memory-bound *by design* — its entire purpose is that
the code cache is rbit/8 = 16 bytes/token instead of 2*d*2 = 512
bytes/token for the K rows it replaces: the kernel exists to make the
16-byte stream the only HBM traffic.

Output is "match score" = G*rbit - sum_g hamming(q_g, k) (int32), so
top-k always selects the LARGEST scores (see kernels/ref.py docstring).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, k_ref, out_ref, *, g_rbit: int):
    q = q_ref[...]                      # (G, W) uint32
    k = k_ref[...]                      # (block_s, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], k[None, :, :])   # (G, block_s, W)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    ham = jnp.sum(pc, axis=(0, 2))      # (block_s,)
    out_ref[...] = (g_rbit - ham)[None, :]


@functools.partial(jax.jit, static_argnames=("rbit", "block_s", "interpret"))
def hamming_score(q_codes: jax.Array, k_codes: jax.Array, *, rbit: int,
                  block_s: int = 2048, interpret: bool = True) -> jax.Array:
    """Aggregated hash match scores for one kv head.

    q_codes: (G, W) uint32, k_codes: (S, W) uint32 -> (S,) int32.
    Batched shapes via ``ops.hamming_score`` (vmap over B, H_kv).
    """
    g, w = q_codes.shape
    s, w2 = k_codes.shape
    assert w == w2, (q_codes.shape, k_codes.shape)
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    out = pl.pallas_call(
        functools.partial(_hamming_kernel, g_rbit=g * rbit),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((g, w), lambda i: (0, 0)),
            pl.BlockSpec((block_s, w), lambda i: (i, 0)),
        ],
        # Keep a 2D (1, block_s) output layout: (block_s,) 1D outputs do
        # not map onto the (sublane, lane) register tiling.
        out_specs=pl.BlockSpec((1, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, s), jnp.int32),
        interpret=interpret,
    )(q_codes, k_codes)
    return out[0]
