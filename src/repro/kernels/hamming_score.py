"""High-performance Hamming score Pallas kernel (paper §4, second opt).

The GPU version is XOR + ``popc`` + warp reduction with coalesced
HBM->SRAM loads. The TPU mapping: the packed code cache streams
HBM->VMEM in (block_s, W) uint32 tiles, XOR against the (G, W) query
codes broadcast from VMEM, ``lax.population_count`` on the VPU, and a
sublane reduction over the G query heads sharing the kv head (the GQA
aggregation of paper §3.2 fused into the same kernel).

This operator is memory-bound *by design* — its entire purpose is that
the code cache is rbit/8 = 16 bytes/token instead of 2*d*2 = 512
bytes/token for the K rows it replaces: the kernel exists to make the
16-byte stream the only HBM traffic.

Output is "match score" = G*rbit - sum_g hamming(q_g, k) (int32), so
top-k always selects the LARGEST scores (see kernels/ref.py docstring).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime


def _hamming_kernel(q_ref, k_ref, out_ref, *, g_rbit: int):
    q = q_ref[...]                      # (G, W) uint32
    k = k_ref[...]                      # (block_s, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], k[None, :, :])   # (G, block_s, W)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    ham = jnp.sum(pc, axis=(0, 2))      # (block_s,)
    out_ref[...] = (g_rbit - ham)[None, :]


def _hamming_batched_kernel(q_ref, k_ref, out_ref, *, g_rbit: int):
    q = q_ref[0, 0]                     # (G, W) uint32
    k = k_ref[0, :, 0, :]               # (block_s, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], k[None, :, :])   # (G, block_s, W)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    out_ref[0, 0] = g_rbit - jnp.sum(pc, axis=(0, 2))


@functools.partial(jax.jit, static_argnames=("rbit", "block_s", "interpret"))
def hamming_score(q_codes: jax.Array, k_codes: jax.Array, *, rbit: int,
                  block_s: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Aggregated hash match scores for one kv head.

    q_codes: (G, W) uint32, k_codes: (S, W) uint32 -> (S,) int32.
    Batched shapes via ``ops.hamming_score`` (vmap over B, H_kv).
    """
    interpret = runtime.resolve_interpret(interpret)
    g, w = q_codes.shape
    s, w2 = k_codes.shape
    assert w == w2, (q_codes.shape, k_codes.shape)
    block_s = runtime.hamming_block_s(block_s, size=s,
                                      dtype=k_codes.dtype)
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    out = pl.pallas_call(
        functools.partial(_hamming_kernel, g_rbit=g * rbit),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((g, w), lambda i: (0, 0)),
            pl.BlockSpec((block_s, w), lambda i: (i, 0)),
        ],
        # Keep a 2D (1, block_s) output layout: (block_s,) 1D outputs do
        # not map onto the (sublane, lane) register tiling.
        out_specs=pl.BlockSpec((1, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, s), jnp.int32),
        interpret=interpret,
    )(q_codes, k_codes)
    return out[0]


@functools.partial(jax.jit, static_argnames=("rbit", "block_s", "interpret"))
def hamming_score_batched(q_codes: jax.Array, k_codes: jax.Array, *,
                          rbit: int, block_s: Optional[int] = None,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Batched aggregated hash match scores — one dispatch, no vmap.

    q_codes: (B, H_kv, G, W) uint32, k_codes: (B, S, H_kv, W) uint32
    -> (B, H_kv, S) int32.

    The grid is (B, H_kv, S-blocks) and the code cache streams in its
    *native* (B, S, H_kv, W) layout — the per-head vmap of
    :func:`hamming_score` forced XLA to materialize a transposed
    (B, H_kv, S, W) copy of the whole code cache before dispatch, which
    doubled the 16-byte/token stream this kernel exists to minimize.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, h_kv, g, w = q_codes.shape
    b2, s, h_kv2, w2 = k_codes.shape
    assert (b, h_kv, w) == (b2, h_kv2, w2), (q_codes.shape, k_codes.shape)
    block_s = runtime.hamming_block_s(block_s, size=s,
                                      dtype=k_codes.dtype)
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        functools.partial(_hamming_batched_kernel, g_rbit=g * rbit),
        grid=(b, h_kv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, w), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, w),
                         lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_s),
                               lambda bi, hi, si: (bi, hi, si)),
        out_shape=jax.ShapeDtypeStruct((b, h_kv, s), jnp.int32),
        interpret=interpret,
    )(q_codes, k_codes)


def _hamming_paged_kernel(bt_ref, nv_ref, q_ref, k_ref, out_ref, *,
                          g_rbit: int, page: int):
    del bt_ref                          # consumed by the index_map
    bi = pl.program_id(0)
    si = pl.program_id(2)
    q = q_ref[0, 0]                     # (G, W) uint32
    k = k_ref[0, :, 0, :]               # (page, W) uint32 — one pool page
    x = jnp.bitwise_xor(q[:, None, :], k[None, :, :])   # (G, page, W)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    score = g_rbit - jnp.sum(pc, axis=(0, 2))           # (page,)
    # Garbage masked *in-kernel*: rows at logical positions >= n_valid
    # (pages past the request's fill, scratch-page rows of inactive
    # slots, tail rows of the last partial page) score -1 — below the
    # floor of 0 for valid rows — exactly what mask_scores would write,
    # so the paged scores equal the contiguous masked scores bit-exact.
    kpos = si * page + jax.lax.broadcasted_iota(
        jnp.int32, (1, page), 1)[0]
    out_ref[0, 0] = jnp.where(kpos < nv_ref[bi], score, -1)


@functools.partial(jax.jit, static_argnames=("rbit", "interpret"))
def hamming_score_paged(q_codes: jax.Array, codes_pool: jax.Array,
                        block_table: jax.Array, n_valid: jax.Array, *,
                        rbit: int,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Batched Hamming match scores over a paged code pool.

    q_codes: (B, H_kv, G, W) uint32; codes_pool: (P, page, H_kv, W)
    uint32 — the shared per-layer page pool; block_table: (B, T) int32
    page ids; n_valid: (B,) int32 valid logical rows. Returns
    (B, H_kv, T * page) int32 logical scores with invalid rows at -1.

    Identical math to :func:`hamming_score_batched`, but the code tile
    for grid step (b, h, t) is fetched through the scalar-prefetched
    block table — the index_map reads ``bt[b, t]`` to pick the physical
    page, so the kernel streams exactly the pages the table names and
    never sees a compacted copy. One tile = one page; garbage rows are
    masked to -1 in-kernel (see ``_hamming_paged_kernel``).
    """
    interpret = runtime.resolve_interpret(interpret)
    b, h_kv, g, w = q_codes.shape
    p, page, h_kv2, w2 = codes_pool.shape
    assert (h_kv, w) == (h_kv2, w2), (q_codes.shape, codes_pool.shape)
    b2, t = block_table.shape
    assert b == b2, (q_codes.shape, block_table.shape)
    n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h_kv, t),
        in_specs=[
            pl.BlockSpec((1, 1, g, w),
                         lambda bi, hi, si, bt, nv: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page, 1, w),
                         lambda bi, hi, si, bt, nv: (bt[bi, si], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, page),
                               lambda bi, hi, si, bt, nv: (bi, hi, si)),
    )
    return pl.pallas_call(
        functools.partial(_hamming_paged_kernel, g_rbit=g * rbit,
                          page=page),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h_kv, t * page), jnp.int32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), n_valid, q_codes, codes_pool)


def _hamming_latent_paged_kernel(bt_ref, nv_ref, q_ref, k_ref, out_ref, *,
                                 h_rbit: int, page: int):
    del bt_ref
    bi = pl.program_id(0)
    si = pl.program_id(1)
    q = q_ref[0]                        # (H, W) uint32
    k = k_ref[0]                        # (page, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], k[None, :, :])
    pc = jax.lax.population_count(x).astype(jnp.int32)
    score = h_rbit - jnp.sum(pc, axis=(0, 2))
    kpos = si * page + jax.lax.broadcasted_iota(
        jnp.int32, (1, page), 1)[0]
    out_ref[0] = jnp.where(kpos < nv_ref[bi], score, -1)


@functools.partial(jax.jit, static_argnames=("rbit", "interpret"))
def hamming_score_latent_paged(q_codes: jax.Array, codes_pool: jax.Array,
                               block_table: jax.Array,
                               n_valid: jax.Array, *, rbit: int,
                               interpret: Optional[bool] = None,
                               ) -> jax.Array:
    """Single-stream (MLA latent) paged match scores.

    q_codes: (B, H, W) uint32; codes_pool: (P, page, W) uint32;
    block_table: (B, T) int32; n_valid: (B,). Returns (B, T * page)
    int32 with invalid rows at -1. The latent analogue of
    :func:`hamming_score_paged` — per-request block tables force a
    (B, pages) grid (the contiguous latent kernel folds the whole batch
    into one tile, but here each request walks its own page list).
    """
    interpret = runtime.resolve_interpret(interpret)
    b, h, w = q_codes.shape
    p, page, w2 = codes_pool.shape
    assert w == w2, (q_codes.shape, codes_pool.shape)
    b2, t = block_table.shape
    assert b == b2, (q_codes.shape, block_table.shape)
    n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda bi, si, bt, nv: (bi, 0, 0)),
            pl.BlockSpec((1, page, w),
                         lambda bi, si, bt, nv: (bt[bi, si], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page),
                               lambda bi, si, bt, nv: (bi, si)),
    )
    return pl.pallas_call(
        functools.partial(_hamming_latent_paged_kernel, h_rbit=h * rbit,
                          page=page),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t * page), jnp.int32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), n_valid, q_codes, codes_pool)


def _hamming_latent_kernel(q_ref, k_ref, out_ref, *, h_rbit: int):
    q = q_ref[...]                      # (B, H, W) uint32
    k = k_ref[...]                      # (B, block_s, W) uint32
    x = jnp.bitwise_xor(q[:, :, None, :], k[:, None, :, :])
    pc = jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] = h_rbit - jnp.sum(pc, axis=(1, 3))    # (B, block_s)


@functools.partial(jax.jit, static_argnames=("rbit", "block_s", "interpret"))
def hamming_score_latent(q_codes: jax.Array, k_codes: jax.Array, *,
                         rbit: int, block_s: Optional[int] = None,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Single-stream (MLA latent) aggregated match scores.

    q_codes: (B, H, W) uint32 — every query head hashed against the one
    shared latent stream — k_codes: (B, S, W) uint32 -> (B, S) int32
    with score = H*rbit - sum_h hamming(q_h, k).

    The latent stream is :func:`hamming_score_batched`'s degenerate
    case of a single kv head whose GQA group is all H query heads, so
    the batch dim would be the whole grid — instead the grid is
    S-blocks only and each step streams the (B, block_s, W) slab of
    every request at once (one latent stream per layer makes the whole
    batch's tile a contiguous (B, block_s) slab in the native layout).
    Same 16-byte/token HBM stream, 1/B the dispatch count.
    """
    interpret = runtime.resolve_interpret(interpret)
    b, h, w = q_codes.shape
    b2, s, w2 = k_codes.shape
    assert (b, w) == (b2, w2), (q_codes.shape, k_codes.shape)
    block_s = runtime.hamming_block_s(block_s, size=s,
                                      dtype=k_codes.dtype)
    block_s = min(block_s, s)
    n_blocks = pl.cdiv(s, block_s)
    return pl.pallas_call(
        functools.partial(_hamming_latent_kernel, h_rbit=h * rbit),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((b, h, w), lambda si: (0, 0, 0)),
            pl.BlockSpec((b, block_s, w), lambda si: (0, si, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_s), lambda si: (0, si)),
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.int32),
        interpret=interpret,
    )(q_codes, k_codes)
