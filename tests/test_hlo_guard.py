"""HLO collective-count guards (distributed/hlo_guard.py).

Compiles the serving workers' step fns and pins the collective ops in
the optimized HLO against tests/data/hlo_collectives.json — plus the
negative control: an injected extra psum in the partial-softmax merge
MUST trip the guard, otherwise the guard guards nothing.
"""
import pytest

from repro.distributed.collectives import assert_collective_counts
from repro.distributed.hlo_guard import colocated_case, load_baseline
from tests.conftest import run_subprocess


def test_colocated_engine_has_zero_collectives():
    baseline = load_baseline()
    got = colocated_case()
    for step, expected in baseline["cases"]["colocated_paged"].items():
        assert_collective_counts(got[step], expected,
                                 label=f"colocated_paged/{step}")
        # belt and braces: the single-host path must be collective-free
        assert got[step] == {}, got[step]


def test_sharded_engine_matches_baseline_subprocess():
    run_subprocess("""
from repro.distributed.hlo_guard import (build_cases,
                                         check_against_baseline,
                                         load_baseline)
check_against_baseline(build_cases(4), load_baseline())
print("OK")
""", n_devices=4)


def test_injected_extra_collective_trips_guard_subprocess():
    run_subprocess("""
import jax
import repro.distributed.decode as ddec

orig = ddec.merge_partial_softmax
def leaky_merge(m, l, o, axis_name):
    # regression stand-in: one extra all-reduce of the merged output
    return jax.lax.psum(orig(m, l, o, axis_name), axis_name)
ddec.merge_partial_softmax = leaky_merge

from repro.distributed.hlo_guard import (load_baseline, sharded_case)
from repro.distributed.collectives import assert_collective_counts
got = sharded_case(4)
expected = load_baseline()["cases"]["sharded_pool_p4"]
try:
    assert_collective_counts(got["decode"], expected["decode"],
                             label="injected")
except AssertionError as e:
    assert "drifted" in str(e), e
    print("OK")
else:
    raise SystemExit("guard did not trip on an injected collective")
""", n_devices=4)
