"""Differential suite for the paged KV+code cache subsystem.

Four layers of guarantees:

  1. Kernel parity — the block-table-indirect kernels (paged Hamming,
     shared-pool fused gather, GQA and MLA) are *bit-exact* against the
     contiguous batched pipeline holding the same rows, across ragged
     depths, window on/off and budget clamping.
  2. Allocator properties — no page is ever leaked or double-freed
     under random admit/retain/release/evict traces; the prefix cache
     keeps refcounts consistent through registration, adoption and LRU
     eviction.
  3. Model parity — chunked paged prefill reproduces the monolithic
     prefill's logits; a prefix-shared prefill reproduces the cold
     prefill's logits on the *same pages*.
  4. Engine parity — the paged scheduler's greedy outputs equal the
     offline decode per request (GQA and MLA/MoE), through chunked
     prefill, prefix sharing, preemption-and-replay, growth past the
     dense engine's max_len wall, and pool-exhaustion truncation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.configs import get_reduced
from repro.configs.base import HataConfig
from repro.core import cache_view
from repro.core import hash_attention as ha
from repro.core import kvcache, paged_cache
from repro.core.paged_cache import (PageAllocator, PagedKVPool,
                                    PagedMLAPool, PrefixCache)
from repro.kernels import ops, ref
from repro.models import Model
from repro.serving import PagedServingEngine, Request, ServingEngine

RNG_SEED = 11
HCFG = HataConfig(rbit=64, budget_min=16, budget_max=32,
                  budget_frac=0.5)


# ===========================================================================
# helpers: build a contiguous cache and a paged pool holding the same rows
# ===========================================================================
def _paged_pair_gqa(b=2, h_kv=2, g=2, d=32, page=8, t=6, seed=0):
    """Returns (cache, pool, block_table, n_valid, q, w) where the pool's
    pages hold exactly the contiguous cache's rows, with a shuffled
    page assignment (page 0 reserved as scratch, like the engine)."""
    rng = np.random.default_rng(seed)
    s = t * page
    h = h_kv * g
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=HCFG.rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2 ** 32, cache.codes.shape,
                                       dtype=np.uint32)))
    n_pages = b * t + 1
    perm = rng.permutation(n_pages - 1) + 1           # page 0 = scratch
    bt = perm.reshape(b, t).astype(np.int32)
    k_pool = np.zeros((n_pages, page, h_kv, d), np.float32)
    v_pool = np.zeros((n_pages, page, h_kv, d), np.float32)
    c_pool = np.zeros((n_pages, page, h_kv, HCFG.rbit // 32), np.uint32)
    for bi in range(b):
        for ti in range(t):
            rows = slice(ti * page, (ti + 1) * page)
            k_pool[bt[bi, ti]] = np.asarray(cache.k[bi, rows])
            v_pool[bt[bi, ti]] = np.asarray(cache.v[bi, rows])
            c_pool[bt[bi, ti]] = np.asarray(cache.codes[bi, rows])
    pool = PagedKVPool(k=jnp.asarray(k_pool), v=jnp.asarray(v_pool),
                       codes=jnp.asarray(c_pool))
    n_valid = jnp.asarray(rng.integers(page, s - 1, b), jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, HCFG.rbit)),
                    jnp.float32) / np.sqrt(d)
    return cache, pool, jnp.asarray(bt), n_valid, q, w


def _paged_pair_mla(b=2, h=4, r=32, rd=8, page=8, t=6, seed=0):
    rng = np.random.default_rng(seed)
    s = t * page
    ckv = rng.standard_normal((b, s, r)).astype(np.float32)
    krope = rng.standard_normal((b, s, rd)).astype(np.float32)
    codes = rng.integers(0, 2 ** 32, (b, s, HCFG.rbit // 32),
                         dtype=np.uint32)
    n_pages = b * t + 1
    perm = rng.permutation(n_pages - 1) + 1
    bt = perm.reshape(b, t).astype(np.int32)
    c_pool = np.zeros((n_pages, page, r), np.float32)
    r_pool = np.zeros((n_pages, page, rd), np.float32)
    h_pool = np.zeros((n_pages, page, HCFG.rbit // 32), np.uint32)
    for bi in range(b):
        for ti in range(t):
            rows = slice(ti * page, (ti + 1) * page)
            c_pool[bt[bi, ti]] = ckv[bi, rows]
            r_pool[bt[bi, ti]] = krope[bi, rows]
            h_pool[bt[bi, ti]] = codes[bi, rows]
    pool = PagedMLAPool(ckv=jnp.asarray(c_pool), krope=jnp.asarray(r_pool),
                        codes=jnp.asarray(h_pool))
    n_valid = jnp.asarray(rng.integers(page, s - 1, b), jnp.int32)
    q_codes = jnp.asarray(rng.integers(0, 2 ** 32, (b, h, HCFG.rbit // 32),
                                       dtype=np.uint32))
    q_lat = jnp.asarray(rng.standard_normal((b, h, r + rd)), jnp.float32)
    return (jnp.asarray(ckv), jnp.asarray(krope), jnp.asarray(codes),
            pool, jnp.asarray(bt), n_valid, q_codes, q_lat)


# ===========================================================================
# 1. kernel parity (xla refs AND pallas interpret)
# ===========================================================================
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_hamming_bit_exact(impl):
    cache, pool, bt, n_valid, q, w = _paged_pair_gqa(seed=1)
    q_codes = ha.aggregate_q_codes(q, w, pool.k.shape[2])
    with ops.use_impl(impl):
        sp = ops.hamming_scores_paged(q_codes, pool.codes, bt, n_valid,
                                      rbit=HCFG.rbit)
    sc = ref.hamming_score_batched_ref(q_codes, cache.codes, HCFG.rbit)
    sc = ha.mask_scores(sc, n_valid)
    assert_array_equal(np.asarray(sp), np.asarray(sc))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_hamming_latent_bit_exact(impl):
    (_, _, codes, pool, bt, n_valid, q_codes, _) = _paged_pair_mla(seed=2)
    with ops.use_impl(impl):
        sp = ops.hamming_scores_latent_paged(q_codes, pool.codes, bt,
                                             n_valid, rbit=HCFG.rbit)
    sc = ref.hamming_score_latent_ref(q_codes, codes, HCFG.rbit)
    sc = ha.mask_scores(sc[:, None], n_valid)[:, 0]
    assert_array_equal(np.asarray(sp), np.asarray(sc))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_gather_bit_exact(impl):
    """Given equal selected rows, the shared-pool gather kernel must be
    bit-identical to the contiguous batched kernel."""
    cache, pool, bt, n_valid, q, w = _paged_pair_gqa(seed=3)
    rng = np.random.default_rng(3)
    b, h_kv, page = q.shape[0], pool.k.shape[2], pool.page_size
    k_sel = 16
    nv = np.asarray(n_valid)
    idx = np.stack([np.stack([
        rng.choice(nv[bi], size=k_sel, replace=False)
        for _ in range(h_kv)]) for bi in range(b)]).astype(np.int32)
    sel_valid = np.arange(k_sel)[None, None] < \
        rng.integers(4, k_sel + 1, (b, h_kv))[..., None]
    phys = np.asarray(paged_cache.physical_rows(bt, jnp.asarray(idx),
                                                page))
    with ops.use_impl(impl):
        out_p = ops.gather_decode_attention_paged(
            q, pool.k, pool.v, jnp.asarray(phys),
            sel_valid=jnp.asarray(sel_valid))
        out_c = ops.gather_decode_attention(
            q, cache.k, cache.v, jnp.asarray(idx),
            sel_valid=jnp.asarray(sel_valid), fused=True)
    assert_array_equal(np.asarray(out_p), np.asarray(out_c))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_mla_gather_bit_exact(impl):
    (ckv, krope, _, pool, bt, n_valid, _, q_lat) = _paged_pair_mla(seed=4)
    rng = np.random.default_rng(4)
    b, page = q_lat.shape[0], pool.page_size
    r = pool.ckv.shape[-1]
    k_sel = 16
    nv = np.asarray(n_valid)
    idx = np.stack([rng.choice(nv[bi], size=k_sel, replace=False)
                    for bi in range(b)]).astype(np.int32)
    sel_n = rng.integers(4, k_sel + 1, b).astype(np.int32)
    phys = np.asarray(paged_cache.physical_rows(bt, jnp.asarray(idx),
                                                page))
    scale = (r + pool.krope.shape[-1]) ** -0.5
    with ops.use_impl(impl):
        out_p = ops.mla_gather_decode_paged(
            q_lat, pool.ckv, pool.krope, jnp.asarray(phys),
            lora_rank=r, scale=scale, n_valid=jnp.asarray(sel_n))
        out_c = ops.mla_gather_decode(
            q_lat, ckv, krope, jnp.asarray(idx), lora_rank=r,
            scale=scale, n_valid=jnp.asarray(sel_n))
    assert_array_equal(np.asarray(out_p), np.asarray(out_c))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("window", [None, 24])
def test_hata_decode_paged_matches_batched(impl, window):
    """Full pipeline parity: scores, selection and outputs of the paged
    decode step equal the contiguous batched pipeline, at ragged
    depths, window on/off."""
    cache, pool, bt, n_valid, q, w = _paged_pair_gqa(seed=5)
    rng = np.random.default_rng(5)
    b, h_kv, d = q.shape[0], pool.k.shape[2], q.shape[-1]
    pos = n_valid - 1                                 # append at pos
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    hcfg = HCFG
    with ops.use_impl(impl):
        ref_out = ha.hata_decode_batched(q, k1, v1, w, cache, hcfg=hcfg,
                                         pos=pos, window=window,
                                         fused_gather=True)
        out, pool2, idx, scores = ha.hata_decode_paged(
            q, k1, v1, w, pool, bt, hcfg=hcfg, pos=pos, window=window)
    assert_array_equal(np.asarray(idx), np.asarray(ref_out.idx))
    assert_array_equal(np.asarray(scores),
                       np.asarray(ha.mask_scores(ref_out.scores, pos + 1,
                                                 window=window)))
    assert_array_equal(np.asarray(out), np.asarray(ref_out.out))
    # the appended rows landed at the right physical slots
    phys = paged_cache.physical_rows(bt, pos, pool.page_size)
    got = paged_cache._flat(pool2.k)[phys]            # (B, H_kv, d)
    assert_array_equal(np.asarray(got), np.asarray(k1[:, 0]))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_hata_decode_paged_budget_clamp_short_cache(impl):
    """cache_len <= budget: every valid row selected, paged ≡ batched
    bit-exact (the short-cache exactness guarantee survives paging)."""
    cache, pool, bt, _, q, w = _paged_pair_gqa(seed=6)
    rng = np.random.default_rng(6)
    b, h_kv, d = q.shape[0], pool.k.shape[2], q.shape[-1]
    pos = jnp.asarray(rng.integers(2, HCFG.budget_min, b), jnp.int32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    with ops.use_impl(impl):
        ref_out = ha.hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                         pos=pos, fused_gather=True)
        out, _, idx, _ = ha.hata_decode_paged(q, k1, v1, w, pool, bt,
                                              hcfg=HCFG, pos=pos)
    assert_array_equal(np.asarray(idx), np.asarray(ref_out.idx))
    assert_array_equal(np.asarray(out), np.asarray(ref_out.out))


def test_hash_encode_heads_single_dispatch_bit_exact():
    """The (H, S-blocks) single-dispatch encode ≡ XLA oracle ≡ the
    legacy per-(batch, head) vmap, including the decode shape S=1."""
    from repro.kernels.hash_encode import hash_encode as single_encode
    rng = np.random.default_rng(7)
    for b, s, h, d, rbit in [(2, 9, 3, 16, 64), (3, 1, 2, 32, 64)]:
        x = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((h, d, rbit)), jnp.float32)
        oracle = ref.hash_encode_ref(
            np.moveaxis(np.asarray(x), 2, 0).reshape(h, b * s, d)[0], w[0])
        with ops.use_impl("pallas"):
            got = ops.hash_encode_heads(x, w)
        with ops.use_impl("xla"):
            want = ops.hash_encode_heads(x, w)
        legacy = jax.vmap(jax.vmap(single_encode, in_axes=(1, 0),
                                   out_axes=1), in_axes=(0, None))(x, w)
        assert_array_equal(np.asarray(got), np.asarray(want))
        assert_array_equal(np.asarray(got), np.asarray(legacy))
        assert_array_equal(np.asarray(got[:, :, 0].reshape(b * s, -1)[0]),
                           np.asarray(oracle[0]))


# ===========================================================================
# 2. allocator + prefix-cache properties
# ===========================================================================
def test_chunk_append_tail_past_table_capacity_is_dropped():
    """A chunk whose zero-padded tail reaches past the block-table
    capacity must not write anywhere (regression: the out-of-bounds
    table column used to alias back into physical page 0)."""
    rng = np.random.default_rng(20)
    page, t = 4, 3
    pool = paged_cache.init_paged_kv_pool(10, page, 2, 8, rbit=64,
                                          dtype=jnp.float32)
    before = np.asarray(pool.k).copy()
    bt = jnp.asarray(np.array([[7, 8, 9]], np.int32))
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 8)), jnp.float32)
    c = jnp.asarray(rng.integers(0, 2 ** 32, (1, 8, 2, 2),
                                 dtype=np.uint32))
    # ctx=8: rows 8..11 are real (page 9), rows 12..15 overflow the table
    pool = paged_cache.append_chunk_kv(pool, k, k, c, bt, jnp.int32(8))
    after = np.asarray(pool.k)
    assert_array_equal(after[9], np.asarray(k[0, :4]))  # real rows land
    mask = np.ones(10, bool)
    mask[9] = False
    assert_array_equal(after[mask], before[mask])       # nothing else
    mla = paged_cache.init_paged_mla_pool(10, page, 8, 4, rbit=64,
                                          dtype=jnp.float32)
    before_m = np.asarray(mla.ckv).copy()
    ck = jnp.asarray(rng.standard_normal((1, 8, 8)), jnp.float32)
    kr = jnp.asarray(rng.standard_normal((1, 8, 4)), jnp.float32)
    cm = jnp.asarray(rng.integers(0, 2 ** 32, (1, 8, 2),
                                  dtype=np.uint32))
    mla = paged_cache.append_chunk_mla(mla, ck, kr, cm, bt, jnp.int32(8))
    after_m = np.asarray(mla.ckv)
    assert_array_equal(after_m[9], np.asarray(ck[0, :4]))
    assert_array_equal(after_m[mask], before_m[mask])


def test_allocator_random_trace_no_leak_no_double_free():
    rng = np.random.default_rng(8)
    alloc = PageAllocator(32)
    held = []                                          # [pages...]
    for _ in range(500):
        op = rng.integers(0, 3)
        if op == 0:                                    # admit
            n = int(rng.integers(1, 5))
            pages = alloc.alloc(n)
            if pages is None:
                assert alloc.free_count() < n
            else:
                assert len(set(pages)) == n
                held.append(pages)
        elif op == 1 and held:                         # evict/finish
            alloc.release(held.pop(rng.integers(len(held))))
        elif op == 2 and held:                         # prefix adoption
            donor = held[rng.integers(len(held))]
            alloc.retain(donor)
            held.append(list(donor))
        alloc.check()
        n_held = sum(len(h) for h in held)
        refs = sum(alloc.refcount(p)
                   for p in {p for h in held for p in h})
        assert refs == n_held
    for h in held:
        alloc.release(h)
    alloc.check()
    assert alloc.free_count() == 32


def test_allocator_double_free_raises():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.release(pages)
    with pytest.raises(ValueError):
        alloc.release(pages)
    with pytest.raises(ValueError):
        alloc.retain([pages[0]])
    alloc.check()


def test_prefix_cache_register_lookup_evict():
    alloc = PageAllocator(16)
    cache = PrefixCache(alloc, page_size=4)
    toks = np.arange(11, dtype=np.int32)               # 2 full pages
    pages = alloc.alloc(3)
    cache.register(toks, pages)
    assert alloc.refcount(pages[0]) == 2               # owner + cache
    assert alloc.refcount(pages[2]) == 1               # partial page
    # adoption: same prefix, clamped to (len-1)//page full pages
    hit = cache.lookup(toks)
    assert hit == pages[:2] and alloc.refcount(pages[1]) == 3
    alloc.release(hit)
    # a 9-token prompt sharing one full page only
    hit = cache.lookup(np.concatenate([toks[:7], [99, 99]]).astype(np.int32))
    assert hit == pages[:1]
    alloc.release(hit)
    # owner finishes: cached pages survive via the cache's refs
    alloc.release(pages)
    assert alloc.refcount(pages[0]) == 1
    assert alloc.free_count() == 16 - 2
    # eviction returns them to the free list
    assert cache.evict(2) == 2
    alloc.check()
    assert alloc.free_count() == 16


# ===========================================================================
# 3 + 4. model + engine parity (reduced configs, f32, CPU/xla impl)
# ===========================================================================
def _setup_model(arch):
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)
            / cfg.moe.top_k))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen():
    return _setup_model("qwen1.5-0.5b")


def _offline(model, params, prompt, n_new, max_len=64):
    caches = model.init_caches(1, max_len, layout="list")
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, caches,
        jnp.int32(0))
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt) + model.cfg.meta_tokens
    for _ in range(n_new - 1):
        logits, caches = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), caches,
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_paged_engine_matches_offline_gqa(qwen):
    cfg, model, params = qwen
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(6, 16)).astype(np.int32)
               for _ in range(5)]
    eng = PagedServingEngine(model, params, num_pages=24, page_size=8,
                             max_batch=2, prefill_chunk=8)
    done = eng.run([Request(prompt=p, max_new_tokens=6)
                    for p in prompts])
    assert len(done) == 5
    for r in done:
        assert r.output == _offline(model, params, r.prompt, 6), r.id
        assert not r.truncated
    eng.alloc.check()
    # finished requests freed their pages; only the prefix cache's
    # retained full pages (and the scratch page) remain live
    assert eng.alloc.used_count() == 1 + len(eng.prefix)
    eng.prefix.clear()
    eng.alloc.check()
    assert eng.alloc.used_count() == 1                 # only scratch


def test_paged_engine_matches_offline_mla_moe():
    cfg, model, params = _setup_model("deepseek-v2-lite-16b")
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(6, 14)).astype(np.int32)
               for _ in range(3)]
    eng = PagedServingEngine(model, params, num_pages=20, page_size=8,
                             max_batch=2, prefill_chunk=8)
    done = eng.run([Request(prompt=p, max_new_tokens=5)
                    for p in prompts])
    for r in done:
        assert r.output == _offline(model, params, r.prompt, 5), r.id
    eng.alloc.check()


def test_chunked_prefill_matches_monolithic(qwen):
    """Chunk-by-chunk paged prefill reproduces the one-shot prefill's
    last-token logits."""
    cfg, model, params = qwen
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)
    # monolithic (contiguous cache)
    caches = model.init_caches(1, 64, layout="list")
    want, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            caches, jnp.int32(0))
    # chunked (paged, through the view API)
    chunk, page, t = 8, 8, 6
    bt = jnp.asarray(np.arange(1, t + 1, dtype=np.int32)[None])
    views = [cache_view.paged_view(p_, bt)
             for p_ in model.init_paged_pools(t + 1, page)]
    got = None
    for ctx in range(0, len(prompt), chunk):
        end = min(ctx + chunk, len(prompt))
        toks = np.zeros(chunk, np.int32)
        toks[:end - ctx] = prompt[ctx:end]
        got, views = model.prefill_chunk(
            params, jnp.asarray(toks[None]), views,
            jnp.int32(ctx), jnp.int32(end - ctx - 1))
    assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5,
                    rtol=1e-5)


def test_prefix_sharing_identical_logits(qwen):
    """A prefill that adopts the donor's prefix pages produces the same
    logits as its own cold prefill — on shared pages, no recompute."""
    cfg, model, params = qwen
    rng = np.random.default_rng(13)
    page, t, chunk = 8, 6, 8
    prefix = rng.integers(0, cfg.vocab_size, 2 * page).astype(np.int32)
    suffix = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    prompt = np.concatenate([prefix, suffix])

    def run_chunks(pools, bt, start):
        logits = None
        views = [cache_view.paged_view(p_, bt) for p_ in pools]
        for ctx in range(start, len(prompt), chunk):
            end = min(ctx + chunk, len(prompt))
            toks = np.zeros(chunk, np.int32)
            toks[:end - ctx] = prompt[ctx:end]
            logits, views = model.prefill_chunk(
                params, jnp.asarray(toks[None]), views,
                jnp.int32(ctx), jnp.int32(end - ctx - 1))
        return logits, [v.unwrap() for v in views]

    pools = model.init_paged_pools(2 * t + 1, page)
    bt_cold = jnp.asarray(np.arange(1, t + 1, dtype=np.int32)[None])
    cold, pools = run_chunks(pools, bt_cold, 0)
    # warm: adopt the donor's two prefix pages, own pages for the rest
    warm_pages = np.concatenate([np.asarray(bt_cold[0, :2]),
                                 np.arange(t + 1, 2 * t - 1,
                                           dtype=np.int32)])
    bt_warm = jnp.asarray(np.concatenate(
        [warm_pages, [0] * (t - len(warm_pages))]).astype(np.int32)[None])
    warm, _ = run_chunks(pools, bt_warm, 2 * page)
    assert_array_equal(np.asarray(warm), np.asarray(cold))


def test_paged_engine_prefix_sharing_end_to_end(qwen):
    cfg, model, params = qwen
    rng = np.random.default_rng(14)
    prefix = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    reqs = [Request(prompt=np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, 6).astype(np.int32)]),
        max_new_tokens=4) for _ in range(4)]
    eng = PagedServingEngine(model, params, num_pages=32, page_size=8,
                             max_batch=2, prefill_chunk=8)
    done = eng.run(reqs)
    for r in done:
        assert r.output == _offline(model, params, r.prompt, 4), r.id
    # 3 of 4 requests adopted the two full prefix pages
    assert eng.stats["prefix_hit_tokens"] == 3 * 16
    eng.alloc.check()


def test_paged_engine_preemption_replays_exactly(qwen):
    cfg, model, params = qwen
    rng = np.random.default_rng(15)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        12).astype(np.int32),
                    max_new_tokens=16) for _ in range(3)]
    eng = PagedServingEngine(model, params, num_pages=9, page_size=8,
                             max_batch=3, prefill_chunk=8,
                             prefix_sharing=False)
    done = eng.run(reqs)
    assert eng.stats["preemptions"] >= 1
    assert any(r.preemptions for r in done)
    for r in done:
        assert r.output == _offline(model, params, r.prompt, 16), r.id
        assert not r.truncated
    eng.alloc.check()


def test_paged_engine_grows_past_dense_wall(qwen):
    """A request that the dense engine truncates at max_len completes
    in the paged engine by appending pages."""
    cfg, model, params = qwen
    rng = np.random.default_rng(16)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    dense = ServingEngine(model, params, max_batch=1, max_len=32)
    [r_dense] = dense.run([Request(prompt=prompt.copy(),
                                   max_new_tokens=40)])
    assert r_dense.truncated and len(r_dense.output) < 40
    eng = PagedServingEngine(model, params, num_pages=8, page_size=8,
                             max_batch=1)
    [r] = eng.run([Request(prompt=prompt.copy(), max_new_tokens=40)])
    assert not r.truncated and len(r.output) == 40
    assert r.output == _offline(model, params, prompt, 40, max_len=64)
    eng.alloc.check()


def test_paged_engine_truncates_when_pool_exhausted(qwen):
    cfg, model, params = qwen
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    eng = PagedServingEngine(model, params, num_pages=3, page_size=8,
                             max_batch=1)                # 16 usable rows
    [r] = eng.run([Request(prompt=prompt, max_new_tokens=40)])
    assert r.truncated and len(r.output) < 40
    eng.alloc.check()
    assert eng.alloc.used_count() == 1                 # pages freed


def test_paged_engine_logical_capacity_wall(qwen):
    """max_len_pages bounds a single request's growth independently of
    pool size (and pins the static budget to table_pages * page_size,
    the dense engine's budget semantics)."""
    cfg, model, params = qwen
    rng = np.random.default_rng(19)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    eng = PagedServingEngine(model, params, num_pages=16, page_size=8,
                             max_batch=1, max_len_pages=3)
    assert eng.table_pages == 3                        # 24-row capacity
    [r] = eng.run([Request(prompt=prompt, max_new_tokens=40)])
    assert r.truncated and len(r.output) < 40
    eng.alloc.check()
    assert eng.alloc.free_count() >= 16 - 1 - 1        # pages returned


def test_paged_engine_oversized_prompt_truncated_at_admission(qwen):
    """A prompt that can never fit the logical capacity is rejected
    before any prefill chunk runs (no wasted compute, no preemption)."""
    cfg, model, params = qwen
    rng = np.random.default_rng(21)
    big = Request(prompt=rng.integers(0, cfg.vocab_size,
                                      40).astype(np.int32),
                  max_new_tokens=4)
    ok = Request(prompt=rng.integers(0, cfg.vocab_size,
                                     10).astype(np.int32),
                 max_new_tokens=4)
    eng = PagedServingEngine(model, params, num_pages=16, page_size=8,
                             max_batch=1, max_len_pages=3)
    done = eng.run([big, ok])
    assert big.truncated and big.output == []
    assert eng.stats["prefill_chunks"] > 0             # ok's chunks only
    assert not ok.truncated
    assert ok.output == _offline(model, params, ok.prompt, 4)
    eng.alloc.check()


def test_dense_engine_truncation_is_immediate(qwen):
    """Satellite fix: a request at the cache ceiling stops decoding and
    frees its slot right away, with the explicit flag set."""
    cfg, model, params = qwen
    rng = np.random.default_rng(18)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        8).astype(np.int32),
                    max_new_tokens=64) for _ in range(2)]
    eng = ServingEngine(model, params, max_batch=1, max_len=16)
    done = eng.run(reqs)
    assert len(done) == 2
    for r in done:
        assert r.truncated
        # 8 prompt rows + first token => decodes until row 16 is full
        assert len(r.output) == 16 - 8 + 1
        assert r.t_done is not None


def test_dense_engine_oversized_prompt_truncated_at_admission(qwen):
    cfg, model, params = qwen
    rng = np.random.default_rng(22)
    big = Request(prompt=rng.integers(0, cfg.vocab_size,
                                      20).astype(np.int32),
                  max_new_tokens=4)
    ok = Request(prompt=rng.integers(0, cfg.vocab_size,
                                     8).astype(np.int32),
                 max_new_tokens=4)
    eng = ServingEngine(model, params, max_batch=1, max_len=16)
    done = eng.run([big, ok])
    assert big.truncated and big.output == []
    assert not ok.truncated and len(ok.output) == 4


def test_pool_sharding_specs():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.sharding import ShardingPolicy
    cfg, model, params = _setup_model("qwen1.5-0.5b")
    pools = model.init_paged_pools(4, 8)
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("dp", "model"))
    pol = ShardingPolicy(cfg, mesh)
    specs = pol.pool_specs(pools)
    flat, _ = jax.tree_util.tree_flatten(specs,
                                         is_leaf=lambda x:
                                         isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)
    # head axis lands on "model" when it divides (1-device mesh: always)
    k_spec = specs[0].k if hasattr(specs[0], "k") else flat[0]
    assert k_spec == P(None, None, "model", None)
