"""Autotuner dispatch layer (kernels/runtime.py + kernels/autotune.py).

Covers the three contracts the tuning-table refactor added:

  1. Schema validation — a malformed table is a hard
     :class:`~repro.kernels.runtime.TuningTableError`, never a silent
     fall-through to defaults.
  2. Resolution precedence — explicit caller arg > env knob > tuning
     table > builtin, with validation errors that *name the knob*.
  3. Bit-exactness — switching tuning tables (including the
     deliberately weird committed table in ``tests/data/``) must never
     change kernel outputs, because the autotuner only emits
     numerics-invariant axes (see DESIGN.md §3).

Plus the PR's multi-layer dispatch satellites: the stacked MLA gather
op and the offload tier's batched chunked-prefill context uploads.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core import cache_view as cv
from repro.core import offload
from repro.kernels import ops, runtime
from repro.kernels.flash_attention import (flash_attention,
                                           flash_prefill_batched)
from repro.kernels.flash_decode import flash_decode_gathered_batched
from repro.kernels.hamming_score import hamming_score
from repro.kernels.hash_encode import hash_encode

DATA = os.path.join(os.path.dirname(__file__), "data")
WEIRD_TABLE = os.path.join(DATA, "tuning_weird.json")


@pytest.fixture(autouse=True)
def _fresh_tables(monkeypatch):
    """Each test starts from the packaged default table (the suite may
    itself be running under REPRO_TUNING_TABLE in the CI tuning-table
    job — these tests manage the env var explicitly)."""
    monkeypatch.delenv("REPRO_TUNING_TABLE", raising=False)
    runtime.clear_table_cache()
    yield
    runtime.clear_table_cache()


def _table(entries):
    return {"version": 1, "entries": entries}


def _entry(**kw):
    e = {"kernel": "hash_encode", "backend": "*", "dtype": "*",
         "bucket": "*", "config": {"block_s": 64}}
    e.update(kw)
    return e


# ===========================================================================
# 1. table schema validation
# ===========================================================================
def test_parse_ok_and_default_table_loads():
    t = runtime.parse_table(_table([_entry()]))
    assert t.entries[0].config == {"block_s": 64}
    # the packaged default must always parse
    assert runtime.active_table().entries


def test_unknown_kernel_is_hard_error():
    with pytest.raises(runtime.TuningTableError, match="unknown kernel"):
        runtime.parse_table(_table([_entry(kernel="warp_drive")]))


@pytest.mark.parametrize("bucket", [0, -3, True, "big", 2.5, None])
def test_malformed_bucket_is_hard_error(bucket):
    with pytest.raises(runtime.TuningTableError, match="bucket"):
        runtime.parse_table(_table([_entry(bucket=bucket)]))


@pytest.mark.parametrize("obj", [
    [],                                       # not an object
    {"entries": []},                          # missing version
    {"version": 2, "entries": []},            # wrong version
    {"version": 1},                           # missing entries
    {"version": 1, "entries": {"a": 1}},      # entries not a list
])
def test_malformed_toplevel_is_hard_error(obj):
    with pytest.raises(runtime.TuningTableError):
        runtime.parse_table(obj)


def test_unknown_param_is_hard_error():
    with pytest.raises(runtime.TuningTableError, match="no tunable param"):
        runtime.parse_table(_table([_entry(config={"block_q": 64})]))


@pytest.mark.parametrize("val", [0, -1, True, "64", 1.5, None])
def test_bad_param_value_is_hard_error(val):
    with pytest.raises(runtime.TuningTableError, match="positive integer"):
        runtime.parse_table(_table([_entry(config={"block_s": val})]))


def test_extra_or_missing_entry_keys_are_hard_errors():
    with pytest.raises(runtime.TuningTableError, match="keys"):
        runtime.parse_table(_table([_entry(note="searched on ci-host")]))
    short = _entry()
    del short["backend"]
    with pytest.raises(runtime.TuningTableError, match="keys"):
        runtime.parse_table(_table([short]))


def test_missing_table_file_is_hard_error(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TUNING_TABLE", str(tmp_path / "nope.json"))
    runtime.clear_table_cache()
    with pytest.raises(runtime.TuningTableError, match="not found"):
        runtime.active_table()


def test_invalid_json_is_hard_error(monkeypatch, tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{")
    monkeypatch.setenv("REPRO_TUNING_TABLE", str(p))
    runtime.clear_table_cache()
    with pytest.raises(runtime.TuningTableError, match="not valid JSON"):
        runtime.active_table()


# ===========================================================================
# 2. lookup + resolution precedence
# ===========================================================================
def test_lookup_specificity_order():
    t = runtime.parse_table(_table([
        _entry(config={"block_s": 100}),
        _entry(bucket=4096, config={"block_s": 200}),
        _entry(bucket=1024, config={"block_s": 300}),
        _entry(backend="cpu", config={"block_s": 400}),
        _entry(backend="cpu", dtype="float32", config={"block_s": 500}),
    ]))

    def look(**kw):
        return t.lookup("hash_encode", **kw)["block_s"]

    assert look(backend="tpu", dtype=None, size=512) == 300    # tightest
    assert look(backend="tpu", dtype=None, size=2048) == 200
    assert look(backend="tpu", dtype=None, size=8192) == 100   # wildcard
    assert look(backend="tpu", dtype=None, size=None) == 100
    # exact backend beats any wildcard-backend bucket specificity
    assert look(backend="cpu", dtype="bfloat16", size=512) == 400
    assert look(backend="cpu", dtype="float32", size=512) == 500
    assert t.lookup("flash_decode", backend="cpu", dtype=None,
                    size=None) is None


def test_resolve_precedence_chain(monkeypatch, tmp_path):
    p = tmp_path / "t.json"
    p.write_text(json.dumps(_table(
        [_entry(kernel="gather_decode", config={"block_k": 48})])))
    monkeypatch.setenv("REPRO_TUNING_TABLE", str(p))
    runtime.clear_table_cache()
    # table > builtin
    assert runtime.resolve("gather_decode", "block_k") == 48
    # kernel absent from table -> builtin
    assert runtime.resolve("flash_decode", "block_k") == \
        runtime.KERNELS["flash_decode"].params["block_k"].default
    # env > table
    monkeypatch.setenv("REPRO_GATHER_BLOCK_K", "24")
    assert runtime.resolve("gather_decode", "block_k") == 24
    # explicit > env
    assert runtime.resolve("gather_decode", "block_k", 16) == 16


@pytest.mark.parametrize("bad", ["0", "-8", "2.5", "banana"])
def test_env_knob_errors_name_the_knob(monkeypatch, bad):
    monkeypatch.setenv("REPRO_GATHER_BLOCK_K", bad)
    with pytest.raises(ValueError, match="REPRO_GATHER_BLOCK_K"):
        runtime.resolve("gather_decode", "block_k")


def test_block_env_default_and_validation(monkeypatch):
    monkeypatch.delenv("REPRO_HAMMING_BLOCK_S", raising=False)
    assert runtime.block_env("REPRO_HAMMING_BLOCK_S", 2048) == 2048
    monkeypatch.setenv("REPRO_HAMMING_BLOCK_S", "96")
    assert runtime.block_env("REPRO_HAMMING_BLOCK_S", 2048) == 96
    for bad in ("0", "-4", "x"):
        monkeypatch.setenv("REPRO_HAMMING_BLOCK_S", bad)
        with pytest.raises(ValueError, match="REPRO_HAMMING_BLOCK_S"):
            runtime.block_env("REPRO_HAMMING_BLOCK_S", 2048)


def test_tpu_alignment_enforced_for_env_and_table(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_GATHER_BLOCK_K", "7")
    with pytest.raises(ValueError, match="multiple of 8"):
        runtime.resolve("gather_decode", "block_k", backend="tpu")
    # same value is fine off-TPU
    assert runtime.resolve("gather_decode", "block_k",
                           backend="cpu") == 7
    monkeypatch.delenv("REPRO_GATHER_BLOCK_K")
    p = tmp_path / "t.json"
    p.write_text(json.dumps(_table(
        [_entry(kernel="gather_decode", config={"block_k": 12})])))
    monkeypatch.setenv("REPRO_TUNING_TABLE", str(p))
    runtime.clear_table_cache()
    # table-sourced misalignment names the override knob
    with pytest.raises(ValueError, match="REPRO_GATHER_BLOCK_K"):
        runtime.resolve("gather_decode", "block_k", backend="tpu")
    # explicit caller args bypass validation (tests pin odd tilings)
    assert runtime.resolve("gather_decode", "block_k", 7,
                           backend="tpu") == 7


# ===========================================================================
# 3. bit-exactness across tuning tables
# ===========================================================================
def _matrix_case(kernel):
    """Zero-arg runner over fixed inputs, dispatching through the
    table (no explicit block args)."""
    rng = np.random.default_rng(7)
    if kernel == "hash_encode":
        x = jnp.asarray(rng.standard_normal((200, 64)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        return lambda: hash_encode(x, w)
    if kernel == "hamming_score":
        q = jnp.asarray(rng.integers(0, 2 ** 16, (4, 2)), jnp.uint32)
        k = jnp.asarray(rng.integers(0, 2 ** 16, (700, 2)), jnp.uint32)
        return lambda: hamming_score(q, k, rbit=64)
    if kernel == "flash_attention":
        q = jnp.asarray(rng.standard_normal((512, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((512, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((512, 32)), jnp.float32)
        return lambda: flash_attention(q, k, v, causal=True)
    if kernel == "flash_prefill":
        q = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
        return lambda: flash_prefill_batched(q, k, v)
    if kernel == "gather_decode":
        b, h_kv, g, d, s, ksel = 2, 2, 4, 32, 256, 64
        q = jnp.asarray(rng.standard_normal((b, h_kv, g, d)), jnp.float32)
        kc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
        vc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
        idx = jnp.asarray(np.stack(
            [[rng.permutation(s)[:ksel] for _ in range(h_kv)]
             for _ in range(b)]), jnp.int32)
        return lambda: flash_decode_gathered_batched(q, kc, vc, idx)
    raise AssertionError(kernel)


_MATRIX = [
    # (kernel, param, value the weird table resolves to)
    ("hash_encode", "block_s", 96),
    ("hamming_score", "block_s", 321),
    ("flash_attention", "block_q", 320),
    ("flash_prefill", "block_q", 96),
    # collapses via min(block_k, k): the chunk walk is identical, the
    # table plumbing is still exercised end to end
    ("gather_decode", "block_k", 65536),
]


@pytest.mark.parametrize("kernel,param,weird_val", _MATRIX)
def test_weird_table_outputs_bit_exact(kernel, param, weird_val,
                                       monkeypatch):
    """The committed non-default table must change resolved configs
    without changing a single output bit (resolution happens at trace
    time, so the jit caches are dropped around the switch)."""
    run = _matrix_case(kernel)
    assert runtime.resolve(kernel, param) != weird_val  # non-vacuous
    jax.clear_caches()
    base = jax.tree_util.tree_map(np.asarray, run())

    monkeypatch.setenv("REPRO_TUNING_TABLE", WEIRD_TABLE)
    runtime.clear_table_cache()
    jax.clear_caches()
    assert runtime.resolve(kernel, param) == weird_val
    got = jax.tree_util.tree_map(np.asarray, run())
    jax.tree_util.tree_map(assert_array_equal, base, got)


def test_weird_table_bucketed_entry_dispatches_on_size(monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_TABLE", WEIRD_TABLE)
    runtime.clear_table_cache()
    # cpu/float32 bucket-48 entry: sizes <= 48 take it, larger sizes
    # fall through to the wildcard row
    assert runtime.resolve("hash_encode", "block_s", size=32,
                           dtype=jnp.float32, backend="cpu") == 11
    assert runtime.resolve("hash_encode", "block_s", size=64,
                           dtype=jnp.float32, backend="cpu") == 96


# ===========================================================================
# 4. multi-layer MLA gather dispatch
# ===========================================================================
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("return_stats", [False, True])
def test_mla_multilayer_matches_per_layer_loop(impl, return_stats):
    L, B, H, S, r, rd, k = 3, 2, 4, 96, 16, 8, 24
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((L, B, H, r + rd)), jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((L, B, S, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((L, B, S, rd)), jnp.float32)
    idx = jnp.asarray(np.stack(
        [[rng.permutation(S)[:k] for _ in range(B)] for _ in range(L)]),
        jnp.int32)
    n_valid = jnp.asarray(rng.integers(1, k + 1, (L, B)), jnp.int32)
    scale = (r + rd) ** -0.5
    with ops.use_impl(impl):
        got = ops.mla_gather_decode_multilayer(
            q, ckv, krope, idx, lora_rank=r, scale=scale,
            n_valid=n_valid, return_stats=return_stats)
        want = [ops.mla_gather_decode(
            q[l], ckv[l], krope[l], idx[l], lora_rank=r, scale=scale,
            n_valid=n_valid[l], return_stats=return_stats)
            for l in range(L)]
    if return_stats:
        for j in range(3):
            assert_array_equal(
                np.asarray(got[j]),
                np.stack([np.asarray(w[j]) for w in want]))
    else:
        assert_array_equal(np.asarray(got),
                           np.stack([np.asarray(w) for w in want]))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mla_multilayer_sel_mask(impl):
    L, B, H, S, r, rd, k = 2, 2, 2, 64, 16, 8, 16
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((L, B, H, r + rd)), jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((L, B, S, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((L, B, S, rd)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, S, (L, B, k)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (L, B, k)) > 0)
    # keep at least one valid selection per (layer, request) lane
    mask = mask.at[:, :, 0].set(True)
    scale = (r + rd) ** -0.5
    with ops.use_impl(impl):
        got = ops.mla_gather_decode_multilayer(
            q, ckv, krope, idx, lora_rank=r, scale=scale, sel_mask=mask)
        want = [ops.mla_gather_decode(
            q[l], ckv[l], krope[l], idx[l], lora_rank=r, scale=scale,
            sel_mask=mask[l]) for l in range(L)]
    assert_array_equal(np.asarray(got),
                       np.stack([np.asarray(w) for w in want]))


# ===========================================================================
# 5. batched chunked-prefill context uploads (offload tier)
# ===========================================================================
def _offloaded_mla_layer(rng, T, page, r, rd, rbit):
    pool = offload.init_offloaded_mla_pool(T + 1, page, r, rd,
                                           rbit=rbit)
    pool.host.ckv[...] = rng.standard_normal(
        pool.host.ckv.shape).astype(np.float32)
    pool.host.krope[...] = rng.standard_normal(
        pool.host.krope.shape).astype(np.float32)
    bt = jnp.asarray(np.arange(1, T + 1, dtype=np.int32)[None])
    return cv.OffloadedMLAView(pool, bt)


def test_stage_mla_ctx_uploads_bit_exact_and_batched():
    """One stacked upload pair per wave serves every offloaded layer,
    and the staged prefill_attend is bit-identical to the per-layer
    logical-upload path it replaced."""
    L, T, page, r, rd, rbit, C, ctx, H = 3, 4, 8, 16, 8, 32, 8, 16, 4
    rng = np.random.default_rng(5)
    scale = (r + rd) ** -0.5
    events = []
    prev = ops.set_pcie_listener(lambda n, d: events.append(d))
    try:
        views = []
        for _ in range(L):
            v = _offloaded_mla_layer(rng, T, page, r, rd, rbit)
            ckv_c = jnp.asarray(rng.standard_normal((1, C, r)),
                                jnp.float32)
            krope_c = jnp.asarray(rng.standard_normal((1, C, rd)),
                                  jnp.float32)
            codes_c = jnp.asarray(
                rng.integers(0, 2 ** 16, (1, C, rbit // 32)),
                jnp.uint32)
            views.append(v.append_chunk(ckv_c, krope_c, codes_c,
                                        jnp.int32(ctx)))
        n0 = events.count("up")
        staged = cv.stage_mla_ctx_uploads(views)
        assert events.count("up") - n0 == 2, \
            "one stacked (ckv, krope) upload pair for ALL layers"
        for v in staged:
            assert v.staged_ctx is not None and v.chunk_dev is not None
            q_lat = jnp.asarray(rng.standard_normal((1, C, H, r + rd)),
                                jnp.float32)
            n1 = events.count("up")
            fast = v.prefill_attend(q_lat, jnp.int32(ctx), lora_rank=r,
                                    scale=scale)
            assert events.count("up") == n1, \
                "staged path must not re-upload"
            slow = dataclasses.replace(v, staged_ctx=None).prefill_attend(
                q_lat, jnp.int32(ctx), lora_rank=r, scale=scale)
            assert events.count("up") - n1 == 2, \
                "fallback path uploads per layer"
            assert_array_equal(np.asarray(fast), np.asarray(slow))
    finally:
        ops.set_pcie_listener(prev)


def test_stage_mla_ctx_uploads_passthrough():
    sentinel = ["not-a-view", 42]
    assert cv.stage_mla_ctx_uploads(sentinel) == sentinel
