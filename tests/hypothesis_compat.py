"""Optional-hypothesis shim for the property tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt). Where
it is installed the re-exports below are the real thing; where it is
not, ``@given`` turns the test into a skip — the rest of the module
still collects and runs, instead of the whole file dying at import
(the seed suite's collection error).
"""
import functools
import inspect

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Placeholder so strategy expressions still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            # hide the property-test args from pytest's fixture resolver
            del skipped.__wrapped__
            skipped.__signature__ = inspect.Signature()
            return skipped
        return deco
