"""Distributed runtime: SP decode exactness, two-stage top-k, pipeline,
compressed gradient sync. Multi-device tests run in subprocesses (the
pytest process keeps 1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.optim.compression import (compress_with_feedback,
                                     dequantize_int8)


# ---------------------------------------------------------------------------
# in-process: error-feedback compression math
# ---------------------------------------------------------------------------
def test_error_feedback_telescopes():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = jnp.zeros(256)
    acc_exact, acc_comp = jnp.zeros(256), jnp.zeros(256)
    for _ in range(50):
        q, scale, err = compress_with_feedback(g_true, err)
        acc_comp = acc_comp + dequantize_int8(q, scale)
        acc_exact = acc_exact + g_true
    # accumulated compressed updates converge to exact sum
    rel = float(jnp.linalg.norm(acc_comp - acc_exact)
                / jnp.linalg.norm(acc_exact))
    assert rel < 0.01


def test_int8_wire_format():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(64),
                    jnp.float32)
    q, scale, _ = compress_with_feedback(g, jnp.zeros(64))
    assert q.dtype == jnp.int8
    assert float(jnp.abs(dequantize_int8(q, scale) - g).max()) \
        <= float(scale) * 1.0 + 1e-6


# ---------------------------------------------------------------------------
# subprocess: sequence-parallel decode == local decode (all modes/archs)
# ---------------------------------------------------------------------------
SP_CODE = """
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_reduced
from repro.models import Model
from repro.distributed.decode import SPDecode
from repro.distributed import strategy

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
for arch in ["llama3-405b", "deepseek-v2-lite-16b", "mixtral-8x22b",
             "hymba-1.5b"]:
    cfg = get_reduced(arch, d_model=64)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe,
            capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    p = m.init(key)
    B, S, max_len = 2, 24, 64
    toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    strategy.set_decode_strategy(None)
    caches = m.init_caches(B, max_len)
    lg, c = m.prefill(p, batch, caches, jnp.int32(0))
    ref = []
    for i in range(3):
        lg, c = m.decode_step(p, toks[:, S + i], c,
                              jnp.int32(S + i + cfg.meta_tokens))
        ref.append(lg)
    strategy.set_decode_strategy(SPDecode(
        mesh, seq_axes=("model",), batch_axes=("data",),
        mode="two_stage"))
    caches2 = m.init_caches(B, max_len)
    lg2, c2 = m.prefill(p, batch, caches2, jnp.int32(0))
    for i in range(3):
        lg2, c2 = m.decode_step(p, toks[:, S + i], c2,
                                jnp.int32(S + i + cfg.meta_tokens))
        err = float(jnp.abs(lg2 - ref[i]).max())
        assert err < 1e-4, (arch, i, err)
    strategy.set_decode_strategy(None)
print("SP-OK")
"""


@pytest.mark.slow
def test_sp_decode_two_stage_exact():
    out = run_subprocess(SP_CODE, n_devices=8, timeout=900)
    assert "SP-OK" in out


TOPK_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import distributed_topk

from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
for k in (1, 4, 16, 64):
    scores = jnp.asarray(rng.permutation(256).astype(np.float32))[None]
    fn = shard_map(
        lambda s: distributed_topk(s, k, ("model",), 32),
        mesh=mesh, in_specs=P(None, "model"),
        out_specs=(P(None, None), P(None, None)), check_rep=False)
    gv, gi = fn(scores)
    _, want = jax.lax.top_k(scores, k)
    assert set(np.asarray(gi[0]).tolist()) \
        == set(np.asarray(want[0]).tolist()), k
print("TOPK-OK")
"""


def test_distributed_topk_exact():
    out = run_subprocess(TOPK_CODE, n_devices=8, timeout=600)
    assert "TOPK-OK" in out


HIER_TOPK_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import distributed_topk

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(1)
for k in (1, 8, 32, 128):          # incl. k > S_local (=32)
    scores = jnp.asarray(rng.permutation(256).astype(np.float32))[None]
    fn = shard_map(
        lambda s: distributed_topk(s, k, ("data", "model"), 32),
        mesh=mesh, in_specs=P(None, ("data", "model")),
        out_specs=(P(None, None), P(None, None)), check_rep=False)
    gv, gi = fn(scores)
    _, want = jax.lax.top_k(scores, k)
    assert set(np.asarray(gi[0]).tolist()) \
        == set(np.asarray(want[0]).tolist()), k
print("HIER-OK")
"""


def test_hierarchical_topk_exact_two_axes():
    """The §Perf H2 optimization must stay exact: hierarchical reduce
    over (data, model) == global top-k, including k > S_local."""
    out = run_subprocess(HIER_TOPK_CODE, n_devices=8, timeout=600)
    assert "HIER-OK" in out


PIPE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import spmd_pipeline

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
L, D, n_micro, mb = 8, 16, 6, 4
w = jnp.asarray(rng.standard_normal((L, D, D)).astype(np.float32)) * 0.3
xs = jnp.asarray(rng.standard_normal((n_micro, mb, D)).astype(np.float32))

def stage_fn(params_local, x):     # params_local: (L/4, D, D)
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    y, _ = jax.lax.scan(body, x, params_local)
    return y

pipe = spmd_pipeline(stage_fn, mesh, "pod", n_micro=n_micro)
got = pipe(w, xs)

# sequential reference
y = xs
for i in range(L):
    y = jnp.tanh(y @ w[i])
err = float(jnp.abs(got - y).max())
assert err < 1e-5, err
print("PIPE-OK")
"""


def test_pipeline_matches_sequential():
    out = run_subprocess(PIPE_CODE, n_devices=4, timeout=600)
    assert "PIPE-OK" in out


COMPRESS_PSUM_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compression import compressed_psum, init_error_state

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
err0 = jnp.zeros((4, 64))

def f(g, e):
    (gm,), (en,) = [None], [None]
    out, e_new = compressed_psum([g[0]], [e[0]], "data")
    return out[0], e_new[0]

fn = shard_map(lambda g, e: compressed_psum(g, e, "data"),
               mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")), check_rep=False)
mean, e_new = fn(g[:, None], err0[:, None])
want = g.mean(0)
got = np.asarray(mean)[0, 0]
rel = np.abs(got - np.asarray(want)).max() / np.abs(want).max()
assert rel < 0.05, rel
print("COMPRESS-OK")
"""


def test_compressed_psum_approximates_mean():
    out = run_subprocess(COMPRESS_PSUM_CODE, n_devices=4, timeout=600)
    assert "COMPRESS-OK" in out


# ---------------------------------------------------------------------------
# sharding policy invariants (in-process, no devices needed)
# ---------------------------------------------------------------------------
def test_sharding_policy_all_specs_divide():
    code = """
import jax
from jax.sharding import PartitionSpec
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import ShardingPolicy, axis_size
from repro.launch.mesh import make_production_mesh
from repro.models import Model

mesh = make_production_mesh()
for arch in ASSIGNED_ARCHS:
    cfg = get_config(arch)
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    policy = ShardingPolicy(cfg, mesh)
    specs = policy.param_specs(params)

    def check(leaf, spec):
        assert isinstance(spec, PartitionSpec), (arch, type(spec))
        entries = tuple(spec) + (None,) * (len(leaf.shape) - len(spec))
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                continue
            assert dim % axis_size(mesh, ax) == 0, (arch, leaf.shape,
                                                    spec)
    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: hasattr(x, "shape"))
print("POLICY-OK")
"""
    out = run_subprocess(code, n_devices=512, timeout=600)
    assert "POLICY-OK" in out
