"""Distributed runtime: SP decode exactness, two-stage top-k, pipeline,
compressed gradient sync. Multi-device tests run in subprocesses (the
pytest process keeps 1 device); the stats-variant kernel cases emulate
the shard loop in-process (per-shard math has no cross-device state
beyond the final merge)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from conftest import run_subprocess
from repro.kernels import ops, ref
from repro.optim.compression import (compress_with_feedback,
                                     dequantize_int8)


def _merge_stats(stats):
    """Flash (m, l, o) merge over a leading shard axis — the in-process
    stand-in for collectives.merge_partial_softmax (pmax/psum)."""
    stacked = tuple(jnp.stack([jnp.asarray(x[i]) for x in stats])
                    for i in range(3))
    return np.asarray(ref.merge_softmax_stats_ref(stacked))


# ---------------------------------------------------------------------------
# in-process: two_stage stats-variant gather — kernel ≡ XLA under the merge
# ---------------------------------------------------------------------------
def test_two_stage_stats_kernel_matches_xla_under_merge():
    """The stats-emitting paged-gather kernel must agree with the XLA
    gather shard-for-shard under the psum merge: every shard attends
    only over the global winners it owns (arbitrary ownership masks),
    and the merged output equals global masked gather attention."""
    rng = np.random.default_rng(5)
    b, h_kv, g, d, n_shards, s_local, k = 2, 2, 4, 32, 4, 16, 12
    s = n_shards * s_local
    h = h_kv * g
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    # emulate distributed_topk's replicated output: global winners with
    # a few invalid (-1-score) tail entries
    scores = jnp.asarray(rng.standard_normal((b, h_kv, s)), jnp.float32)
    gv, gi = jax.lax.top_k(scores, k)
    gv = gv.at[:, :, -2:].set(-1.0)                  # invalid tail
    stats_pallas, stats_xla = [], []
    for p_ in range(n_shards):
        off = p_ * s_local
        li = np.asarray(gi) - off
        owned = (li >= 0) & (li < s_local) & (np.asarray(gv) >= 0)
        li_c = jnp.asarray(np.clip(li, 0, s_local - 1), jnp.int32)
        shard_k = kc[:, off:off + s_local]
        shard_v = vc[:, off:off + s_local]
        with ops.use_impl("pallas"):
            stats_pallas.append(ops.gather_decode_stats(
                q, shard_k, shard_v, li_c, jnp.asarray(owned)))
        with ops.use_impl("xla"):
            stats_xla.append(ops.gather_decode_stats(
                q, shard_k, shard_v, li_c, jnp.asarray(owned)))
    merged_p = _merge_stats(stats_pallas)
    merged_x = _merge_stats(stats_xla)
    assert_allclose(merged_p, merged_x, atol=1e-5)
    # and both equal the unsharded masked gather over the same winners
    want = ref.masked_gather_decode_ref(q, kc, vc, gi, gv >= 0)
    got = merged_p.reshape(b, h, d)
    assert_allclose(got, np.asarray(want), atol=1e-5)


def test_two_stage_mla_stats_kernel_matches_xla_under_merge():
    """Same contract for the split-latent MLA stats kernel."""
    rng = np.random.default_rng(6)
    b, h, r, rd, n_shards, s_local, k = 2, 6, 48, 16, 4, 16, 12
    s = n_shards * s_local
    scale = (r + rd) ** -0.5
    q_lat = jnp.asarray(rng.standard_normal((b, h, r + rd)), jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((b, s, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((b, s, rd)), jnp.float32)
    scores = jnp.asarray(rng.standard_normal((b, s)), jnp.float32)
    gv, gi = jax.lax.top_k(scores, k)
    gv = gv.at[:, -2:].set(-1.0)
    stats_pallas, stats_xla = [], []
    for p_ in range(n_shards):
        off = p_ * s_local
        li = np.asarray(gi) - off
        owned = (li >= 0) & (li < s_local) & (np.asarray(gv) >= 0)
        li_c = jnp.asarray(np.clip(li, 0, s_local - 1), jnp.int32)
        args = (q_lat, ckv[:, off:off + s_local],
                krope[:, off:off + s_local], li_c)
        kw = dict(lora_rank=r, scale=scale,
                  sel_mask=jnp.asarray(owned), return_stats=True)
        with ops.use_impl("pallas"):
            stats_pallas.append(ops.mla_gather_decode(*args, **kw))
        with ops.use_impl("xla"):
            stats_xla.append(ops.mla_gather_decode(*args, **kw))
    merged_p = _merge_stats(stats_pallas)
    merged_x = _merge_stats(stats_xla)
    assert_allclose(merged_p, merged_x, atol=1e-5)
    want = ref.mla_gather_decode_ref(q_lat, ckv, krope, gi, gv >= 0,
                                     lora_rank=r, scale=scale)
    assert_allclose(merged_p, np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# in-process: error-feedback compression math
# ---------------------------------------------------------------------------
def test_error_feedback_telescopes():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = jnp.zeros(256)
    acc_exact, acc_comp = jnp.zeros(256), jnp.zeros(256)
    for _ in range(50):
        q, scale, err = compress_with_feedback(g_true, err)
        acc_comp = acc_comp + dequantize_int8(q, scale)
        acc_exact = acc_exact + g_true
    # accumulated compressed updates converge to exact sum
    rel = float(jnp.linalg.norm(acc_comp - acc_exact)
                / jnp.linalg.norm(acc_exact))
    assert rel < 0.01


def test_int8_wire_format():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(64),
                    jnp.float32)
    q, scale, _ = compress_with_feedback(g, jnp.zeros(64))
    assert q.dtype == jnp.int8
    assert float(jnp.abs(dequantize_int8(q, scale) - g).max()) \
        <= float(scale) * 1.0 + 1e-6


# ---------------------------------------------------------------------------
# subprocess: sequence-parallel decode == local decode (all modes/archs)
# ---------------------------------------------------------------------------
SP_CODE = """
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_reduced
from repro.models import Model
from repro.distributed.decode import SPDecode
from repro.distributed import strategy

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
B, S, max_len = 2, 24, 64
for arch in ["llama3-405b", "deepseek-v2-lite-16b", "mixtral-8x22b",
             "hymba-1.5b"]:
    base = get_reduced(arch, d_model=64)
    base = dataclasses.replace(base, dtype="float32")
    if base.moe:
        base = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe,
            capacity_factor=float(base.moe.n_experts) / base.moe.top_k))
    # two_stage is exact at any budget; local_split only where the
    # budget saturates every shard (k_loc == S_local) — run it with a
    # cache-covering budget (clamped_budget floors at the cache size,
    # which meta tokens may have extended past max_len) and no window
    # clamp, so its kernel path has an exactness oracle. The reference
    # is recomputed under the same config.
    saturated = dataclasses.replace(
        base, sliding_window=None, hata=dataclasses.replace(
            base.hata, budget_min=8192, budget_max=8192))
    key = jax.random.PRNGKey(0)
    p = Model(base).init(key)          # shapes independent of budget
    toks = jax.random.randint(key, (B, S + 3), 0, base.vocab_size)
    batch = {"tokens": toks[:, :S]}
    for mode, cfg in (("two_stage", base), ("local_split", saturated)):
        m = Model(cfg)
        strategy.set_decode_strategy(None)
        caches = m.init_caches(B, max_len)
        lg, c = m.prefill(p, batch, caches, jnp.int32(0))
        ref = []
        for i in range(3):
            lg, c = m.decode_step(p, toks[:, S + i], c,
                                  jnp.int32(S + i + cfg.meta_tokens))
            ref.append(lg)
        strategy.set_decode_strategy(SPDecode(
            mesh, seq_axes=("model",), batch_axes=("data",),
            mode=mode))
        caches2 = m.init_caches(B, max_len)
        lg2, c2 = m.prefill(p, batch, caches2, jnp.int32(0))
        for i in range(3):
            lg2, c2 = m.decode_step(p, toks[:, S + i], c2,
                                    jnp.int32(S + i + cfg.meta_tokens))
            err = float(jnp.abs(lg2 - ref[i]).max())
            assert err < 1e-4, (arch, mode, i, err)
        strategy.set_decode_strategy(None)
print("SP-OK")
"""


@pytest.mark.slow
def test_sp_decode_two_stage_exact():
    out = run_subprocess(SP_CODE, n_devices=8, timeout=900)
    assert "SP-OK" in out


TOPK_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import distributed_topk

from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
for k in (1, 4, 16, 64):
    scores = jnp.asarray(rng.permutation(256).astype(np.float32))[None]
    fn = shard_map(
        lambda s: distributed_topk(s, k, ("model",), 32),
        mesh=mesh, in_specs=P(None, "model"),
        out_specs=(P(None, None), P(None, None)), check_rep=False)
    gv, gi = fn(scores)
    _, want = jax.lax.top_k(scores, k)
    assert set(np.asarray(gi[0]).tolist()) \
        == set(np.asarray(want[0]).tolist()), k
print("TOPK-OK")
"""


def test_distributed_topk_exact():
    out = run_subprocess(TOPK_CODE, n_devices=8, timeout=600)
    assert "TOPK-OK" in out


HIER_TOPK_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import distributed_topk

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(1)
for k in (1, 8, 32, 128):          # incl. k > S_local (=32)
    scores = jnp.asarray(rng.permutation(256).astype(np.float32))[None]
    fn = shard_map(
        lambda s: distributed_topk(s, k, ("data", "model"), 32),
        mesh=mesh, in_specs=P(None, ("data", "model")),
        out_specs=(P(None, None), P(None, None)), check_rep=False)
    gv, gi = fn(scores)
    _, want = jax.lax.top_k(scores, k)
    assert set(np.asarray(gi[0]).tolist()) \
        == set(np.asarray(want[0]).tolist()), k
print("HIER-OK")
"""


def test_hierarchical_topk_exact_two_axes():
    """The §Perf H2 optimization must stay exact: hierarchical reduce
    over (data, model) == global top-k, including k > S_local."""
    out = run_subprocess(HIER_TOPK_CODE, n_devices=8, timeout=600)
    assert "HIER-OK" in out


PIPE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import spmd_pipeline

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
L, D, n_micro, mb = 8, 16, 6, 4
w = jnp.asarray(rng.standard_normal((L, D, D)).astype(np.float32)) * 0.3
xs = jnp.asarray(rng.standard_normal((n_micro, mb, D)).astype(np.float32))

def stage_fn(params_local, x):     # params_local: (L/4, D, D)
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    y, _ = jax.lax.scan(body, x, params_local)
    return y

pipe = spmd_pipeline(stage_fn, mesh, "pod", n_micro=n_micro)
got = pipe(w, xs)

# sequential reference
y = xs
for i in range(L):
    y = jnp.tanh(y @ w[i])
err = float(jnp.abs(got - y).max())
assert err < 1e-5, err
print("PIPE-OK")
"""


def test_pipeline_matches_sequential():
    out = run_subprocess(PIPE_CODE, n_devices=4, timeout=600)
    assert "PIPE-OK" in out


COMPRESS_PSUM_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compression import compressed_psum, init_error_state

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
err0 = jnp.zeros((4, 64))

def f(g, e):
    (gm,), (en,) = [None], [None]
    out, e_new = compressed_psum([g[0]], [e[0]], "data")
    return out[0], e_new[0]

fn = shard_map(lambda g, e: compressed_psum(g, e, "data"),
               mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")), check_rep=False)
mean, e_new = fn(g[:, None], err0[:, None])
want = g.mean(0)
got = np.asarray(mean)[0, 0]
rel = np.abs(got - np.asarray(want)).max() / np.abs(want).max()
assert rel < 0.05, rel
print("COMPRESS-OK")
"""


def test_compressed_psum_approximates_mean():
    out = run_subprocess(COMPRESS_PSUM_CODE, n_devices=4, timeout=600)
    assert "COMPRESS-OK" in out


# ---------------------------------------------------------------------------
# sharding policy invariants (in-process, no devices needed)
# ---------------------------------------------------------------------------
def test_sharding_policy_all_specs_divide():
    code = """
import jax
from jax.sharding import PartitionSpec
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import ShardingPolicy, axis_size
from repro.launch.mesh import make_production_mesh
from repro.models import Model

mesh = make_production_mesh()
for arch in ASSIGNED_ARCHS:
    cfg = get_config(arch)
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    policy = ShardingPolicy(cfg, mesh)
    specs = policy.param_specs(params)

    def check(leaf, spec):
        assert isinstance(spec, PartitionSpec), (arch, type(spec))
        entries = tuple(spec) + (None,) * (len(leaf.shape) - len(spec))
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                continue
            assert dim % axis_size(mesh, ax) == 0, (arch, leaf.shape,
                                                    spec)
    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: hasattr(x, "shape"))
print("POLICY-OK")
"""
    out = run_subprocess(code, n_devices=512, timeout=600)
    assert "POLICY-OK" in out
