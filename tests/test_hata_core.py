"""HATA core behaviour: selection exactness, hash training, baselines,
top-k properties (deliverable c)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from numpy.testing import assert_allclose

from repro.configs.base import HataConfig
from repro.core import baselines, hashing, kvcache, topk
from repro.core.hash_attention import hata_decode, hata_prefill
from repro.kernels import ops

RNG = np.random.default_rng(1)
HCFG = HataConfig(rbit=64, budget_min=8, budget_max=32, budget_frac=0.1)


def _mk_cache_and_weights(B=2, H=4, Hkv=2, d=32, S=64, prefill=40):
    cache = kvcache.init_kv_cache(B, S, Hkv, d, rbit=HCFG.rbit,
                                  dtype=jnp.float32)
    w = jnp.asarray(RNG.standard_normal((Hkv, d, HCFG.rbit)),
                    jnp.float32) / np.sqrt(d)
    k = jnp.asarray(RNG.standard_normal((B, prefill, Hkv, d)),
                    jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, prefill, Hkv, d)),
                    jnp.float32)
    q = jnp.asarray(RNG.standard_normal((B, prefill, H, d)), jnp.float32)
    _, cache = hata_prefill(q, k, v, w, cache, hcfg=HCFG,
                            pos=jnp.int32(0))
    return cache, w


def test_hata_decode_equals_dense_when_budget_covers_cache():
    cache, w = _mk_cache_and_weights()
    hcfg = dataclasses.replace(HCFG, budget_min=64, budget_max=64,
                               budget_frac=1.0)
    B, H, d = 2, 4, 32
    q = jnp.asarray(RNG.standard_normal((B, H, d)), jnp.float32)
    k1 = jnp.asarray(RNG.standard_normal((B, 1, 2, d)), jnp.float32)
    v1 = jnp.asarray(RNG.standard_normal((B, 1, 2, d)), jnp.float32)
    res = hata_decode(q, k1, v1, w, cache, hcfg=hcfg, pos=jnp.int32(40))
    want = ops.decode_attention(q, res.cache.k, res.cache.v,
                                jnp.int32(41))
    assert_allclose(np.asarray(res.out), np.asarray(want), atol=1e-5)


def test_hata_decode_never_selects_invalid_rows():
    cache, w = _mk_cache_and_weights(prefill=20)
    B, H, d = 2, 4, 32
    q = jnp.asarray(RNG.standard_normal((B, H, d)), jnp.float32)
    k1 = jnp.asarray(RNG.standard_normal((B, 1, 2, d)), jnp.float32)
    v1 = jnp.asarray(RNG.standard_normal((B, 1, 2, d)), jnp.float32)
    res = hata_decode(q, k1, v1, w, cache, hcfg=HCFG, pos=jnp.int32(20))
    sel_scores = np.take_along_axis(np.asarray(res.scores),
                                    np.asarray(res.idx), axis=-1)
    valid = np.asarray(res.idx) <= 20
    assert (sel_scores[valid] >= 0).all()
    # every invalid position carries score -1
    assert (np.asarray(res.scores)[:, :, 21:] == -1).all()


def test_budget_clamping():
    h = HataConfig(rbit=64, budget_frac=0.0156, budget_min=512,
                   budget_max=8192)
    assert h.budget(32768) == 512
    assert h.budget(524288) == int(0.0156 * 524288)
    assert h.budget(1 << 20) == 8192
    assert h.budget(100) == 100


# ---------------------------------------------------------------------------
# learning-to-hash
# ---------------------------------------------------------------------------
def _structured_qk(n=256, m=16, d=24):
    key = jax.random.PRNGKey(0)
    kq, kk = jax.random.split(key)
    q = jax.random.normal(kq, (n, d))
    k = q[:, None, :] * 0.6 + jax.random.normal(kk, (n, m, d)) * 0.6
    scores = jnp.einsum("nd,nmd->nm", q, k)
    order = jnp.argsort(-scores, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    npos = max(1, m // 10)
    labels = jnp.where(ranks < npos, 20.0, -1.0)
    return q, k, labels


def test_hash_training_reduces_loss():
    q, k, labels = _structured_qk()
    st0 = hashing.hash_train_init(jax.random.PRNGKey(1), q.shape[1], 64)
    l0 = hashing.hash_loss(st0.w_h, q, k, labels, HCFG)
    w = hashing.train_hash_weights(jax.random.PRNGKey(1), q, k, labels,
                                   rbit=64, hcfg=HCFG, epochs=10,
                                   iters=20)
    l1 = hashing.hash_loss(w, q, k, labels, HCFG)
    assert float(l1) < float(l0)


def test_trained_hash_beats_random_on_training_distribution():
    q, k, labels = _structured_qk(n=512)
    w = hashing.train_hash_weights(jax.random.PRNGKey(2), q, k, labels,
                                   rbit=64, hcfg=HCFG, epochs=15,
                                   iters=20)
    # recall evaluated on held-out queries from the same distribution
    qh, kh, _ = _structured_qk(n=64)
    keys = kh.reshape(-1, kh.shape[-1])[:256]
    rec = hashing.hash_topk_recall(qh, keys, w, 16, rbit=64).mean()
    w_lsh = hashing.random_projection_lsh(jax.random.PRNGKey(3),
                                          q.shape[1], 64)
    rec_lsh = hashing.hash_topk_recall(qh, keys, w_lsh, 16,
                                       rbit=64).mean()
    assert float(rec) > float(rec_lsh) - 0.02  # at least on par
    assert float(rec) > 0.2


# ---------------------------------------------------------------------------
# top-k utilities
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(1, 16))
def test_two_stage_topk_matches_global(n_shards, k):
    s = n_shards * 16
    k = min(k, 16)
    scores = jnp.asarray(RNG.permutation(s).astype(np.float32))
    got = topk.two_stage_topk_ref(scores, k, n_shards)
    _, want = jax.lax.top_k(scores, k)
    assert set(np.asarray(got).tolist()) == set(np.asarray(want).tolist())


def test_selection_recall_bounds():
    est = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
    true = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
    r = topk.selection_recall(est, true, 8)
    assert ((np.asarray(r) >= 0) & (np.asarray(r) <= 1)).all()
    r_self = topk.selection_recall(true, true, 8)
    assert (np.asarray(r_self) == 1).all()


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------
def test_loki_high_rank_recovers_exact_ranking():
    keys = jnp.asarray(RNG.standard_normal((64, 16)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((2, 16)), jnp.float32)
    state = baselines.loki_fit(keys, r=16)
    est = baselines.loki_scores(q, state, r=16)   # full rank == exact
    want = baselines.exact_scores(q, keys)
    rec = topk.selection_recall(est[None], want[None], 8)
    assert float(rec[0]) == 1.0


def test_quest_scores_upper_bound_block_max():
    keys = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((1, 8)), jnp.float32)
    state = baselines.quest_fit(keys, block=8)
    tok = baselines.quest_scores(q, state, block=8, s=64)
    exact = keys @ q[0]
    blocks_ub = np.asarray(tok).reshape(8, 8)[:, 0]
    blocks_max = np.asarray(exact).reshape(8, 8).max(1)
    assert (blocks_ub + 1e-5 >= blocks_max).all()


def test_streaming_mask_budget():
    m = baselines.streaming_mask(64, jnp.int32(50), 16, sinks=4)
    m = np.asarray(m)
    assert m[:4].all()               # sinks kept
    assert m[38:50].all()            # recent kept
    assert m.sum() == 16


def test_h2o_select_respects_budget_and_recency():
    cum = jnp.asarray(RNG.random(64).astype(np.float32))
    mask = baselines.h2o_select(cum, jnp.int32(50), 16)
    m = np.asarray(mask)
    assert m[42:50].all()            # recent half
    assert m.sum() <= 16 + 8


def test_snapkv_keeps_window_and_budget():
    keys = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    qwin = jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)
    mask = baselines.snapkv_select(qwin, keys, 16)
    m = np.asarray(mask)
    assert m[-8:].all()
    assert m.sum() <= 16


def test_decode_byte_model_orders_methods():
    kw = dict(s=32768, d=128, budget=512)
    dense = baselines.decode_bytes_per_kv_head("dense", **kw)
    hata = baselines.decode_bytes_per_kv_head("hata", **kw)
    loki = baselines.decode_bytes_per_kv_head("loki", **kw)
    exact = baselines.decode_bytes_per_kv_head("exact-topk", **kw)
    lsh = baselines.decode_bytes_per_kv_head("lsh", **kw)
    assert hata < loki < exact < dense
    assert hata < lsh                # 128 trained bits vs 1500 random
    assert dense / hata > 15         # the paper's bandwidth win
