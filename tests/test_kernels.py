"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis
property tests (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from numpy.testing import assert_allclose

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_gathered
from repro.kernels.hamming_score import hamming_score
from repro.kernels.hash_encode import hash_encode

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# HashEncode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,d,rbit,block_s", [
    (64, 32, 32, 64), (300, 128, 128, 128), (17, 64, 64, 512),
    (1024, 128, 256, 256), (8, 16, 32, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hash_encode_matches_ref(s, d, rbit, block_s, dtype):
    x = jnp.asarray(RNG.standard_normal((s, d)), dtype)
    w = jnp.asarray(RNG.standard_normal((d, rbit)), jnp.float32)
    got = hash_encode(x, w, block_s=block_s)
    want = ref.hash_encode_ref(x, w)
    assert got.dtype == jnp.uint32 and got.shape == (s, rbit // 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8))
def test_bitpack_roundtrip(s, words):
    rbit = words * 32
    bits = RNG.integers(0, 2, (s, rbit)).astype(np.uint32)
    packed = ref.bitpack_ref(jnp.asarray(bits))
    unpacked = ref.bitunpack_ref(packed, rbit)
    np.testing.assert_array_equal(np.asarray(unpacked), bits)


# ---------------------------------------------------------------------------
# Hamming score
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g,s,words,block_s", [
    (1, 128, 1, 64), (4, 1000, 4, 256), (16, 64, 2, 2048),
])
def test_hamming_matches_ref(g, s, words, block_s):
    q = jnp.asarray(RNG.integers(0, 2**32, (g, words), dtype=np.uint32))
    k = jnp.asarray(RNG.integers(0, 2**32, (s, words), dtype=np.uint32))
    rbit = words * 32
    got = hamming_score(q, k, rbit=rbit, block_s=block_s)
    want = ref.hamming_score_ref(q, k, rbit)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 64), st.integers(1, 4))
def test_hamming_bounds_and_self_similarity(g, s, words):
    rbit = words * 32
    q = jnp.asarray(RNG.integers(0, 2**32, (g, words), dtype=np.uint32))
    k = jnp.asarray(RNG.integers(0, 2**32, (s, words), dtype=np.uint32))
    sc = ref.hamming_score_ref(q, k, rbit)
    assert (np.asarray(sc) >= 0).all() and (np.asarray(sc)
                                            <= g * rbit).all()
    # a key equal to a query gets >= rbit matches from that query alone
    k2 = jnp.concatenate([k, q[:1]], axis=0)
    sc2 = ref.hamming_score_ref(q, k2, rbit)
    assert int(sc2[-1]) >= rbit


def test_hamming_symmetry():
    w = 4
    a = jnp.asarray(RNG.integers(0, 2**32, (1, w), dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, (1, w), dtype=np.uint32))
    s_ab = ref.hamming_score_ref(a, b, 128)
    s_ba = ref.hamming_score_ref(b, a, 128)
    assert int(s_ab[0]) == int(s_ba[0])


# ---------------------------------------------------------------------------
# Flash attention (prefill)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sq,sk,d,bq,bk,causal,window", [
    (128, 128, 64, 64, 64, True, None),
    (256, 256, 32, 128, 64, True, 96),
    (64, 128, 64, 64, 64, False, None),
    (96, 96, 128, 32, 32, True, None),
])
def test_flash_attention_matches_ref(sq, sk, d, bq, bk, causal, window):
    q = jnp.asarray(RNG.standard_normal((sq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((sk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((sk, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=sk - sq, block_q=bq, block_k=bk)
    if window is None:
        want = ref.attention_ref(q, k, v, causal=causal,
                                 q_offset=sk - sq)
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    else:
        want = ref.mha_ref(q[None, :, None], k[None, :, None],
                           v[None, :, None], causal=causal,
                           q_offset=sk - sq, window=window)[0, :, 0]
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v)
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# Flash decode (+ fused gather)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g,s,d,valid,block_k", [
    (1, 256, 64, 256, 64), (4, 256, 64, 100, 128), (8, 512, 128, 511, 256),
])
def test_flash_decode_matches_ref(g, s, d, valid, block_k):
    q = jnp.asarray(RNG.standard_normal((g, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    got = flash_decode(q, k, v, jnp.int32(valid), block_k=block_k)
    want = ref.decode_attention_ref(q, k[:valid], v[:valid])
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("g,s,d,n_sel", [(2, 128, 32, 16), (4, 256, 64, 64)])
def test_fused_gather_decode_matches_ref(g, s, d, n_sel):
    q = jnp.asarray(RNG.standard_normal((g, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    idx = jnp.asarray(RNG.choice(s, n_sel, replace=False).astype(np.int32))
    got = flash_decode_gathered(q, k, v, idx)
    want = ref.gather_decode_attention_ref(q, k, v, idx)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# Partial-softmax merge (the SP decode invariant)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5))
def test_softmax_merge_associative(n_shards, g):
    d = 16
    s = n_shards * 8
    q = jnp.asarray(RNG.standard_normal((g, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    full = ref.decode_attention_ref(q, k, v)
    stats = [ref.softmax_stats_ref(q, k[i * 8:(i + 1) * 8],
                                   v[i * 8:(i + 1) * 8])
             for i in range(n_shards)]
    m = jnp.stack([s_[0] for s_ in stats])
    l = jnp.stack([s_[1] for s_ in stats])
    o = jnp.stack([s_[2] for s_ in stats])
    merged = ref.merge_softmax_stats_ref((m, l, o))
    assert_allclose(np.asarray(merged), np.asarray(full, np.float32),
                    atol=1e-5)


def test_softmax_merge_handles_empty_shard():
    g, d = 2, 8
    q = jnp.asarray(RNG.standard_normal((g, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((8, d)), jnp.float32)
    full = ref.decode_attention_ref(q, k, v)
    m1, l1, o1 = ref.softmax_stats_ref(q, k, v)
    # an all-masked shard
    m0, l0, o0 = ref.softmax_stats_ref(q, k, v,
                                       mask=jnp.zeros(8, bool))
    merged = ref.merge_softmax_stats_ref(
        (jnp.stack([m0, m1]), jnp.stack([l0, l1]), jnp.stack([o0, o1])))
    assert_allclose(np.asarray(merged), np.asarray(full, np.float32),
                    atol=1e-5)
