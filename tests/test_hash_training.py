"""End-to-end hash quality subsystem: harvest -> train -> calibrate.

Two pinned scenarios (low-vocab prompts give a random-init model's q/k
enough retrieval structure for trained hashes to beat random
projections — see repro.training docs):

- ``small``: the default 2-layer reduced qwen at seed 0 — harvest
  parity, linear-vs-seed, MLP-vs-linear, install, encode-parity and
  checkpoint round-trips.
- ``calibrated``: the 4-layer variant at seed 2 — the budget
  calibrator's joint allocation finds a strictly lower mean budget at
  >= the global-budget mean recall there.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.training as T
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_reduced
from repro.core import budgets
from repro.core import hash_weights as hwt
from repro.data.hash_dataset import harvest_qk
from repro.kernels import ops
from repro.models import Model
from repro.training import harvest

B, S, VOCAB = 2, 96, 8
TRAIN_KW = dict(epochs=8, iters=10, n_queries=32, m_keys=32)


def _scenario(n_layers, seed):
    cfg = get_reduced("qwen1.5-0.5b", n_layers=n_layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    batches = [{"tokens": rng.integers(0, VOCAB, (B, S))}
               for _ in range(4)]
    return cfg, model, params, batches


@pytest.fixture(scope="module")
def small():
    return _scenario(n_layers=2, seed=0)


@pytest.fixture(scope="module")
def trained_small(small):
    cfg, model, params, batches = small
    p_lin, tr_lin, m_lin = T.train_model_hashes(
        model, params, batches, **TRAIN_KW)
    # hidden == 2*rbit: warm-starts from the linear hash and keeps the
    # better of {warm, fine-tuned} per head on the held-out harvest
    _, tr_mlp, m_mlp = T.train_model_hashes(
        model, params, batches, hidden=2 * cfg.hata.rbit, **TRAIN_KW)
    return p_lin, tr_lin, m_lin, tr_mlp, m_mlp


@pytest.fixture(scope="module")
def calibrated():
    cfg, model, params, batches = _scenario(n_layers=4, seed=2)
    params2, trained, metrics = T.train_model_hashes(
        model, params, batches, **TRAIN_KW)
    table, baseline = T.calibrate_budget_table(
        model, params2, batches[-1], weights=trained)
    return (cfg, model, params2, batches, trained, metrics, table,
            baseline)


# ---------------------------------------------------------------------------
# harvest
# ---------------------------------------------------------------------------
def test_harvest_all_layers_matches_harvest_qk(small):
    """ONE forward pass for all layers == the per-layer re-run, bit-exact."""
    cfg, model, params, batches = small
    layers = harvest.self_attention_layers(model)
    assert layers, "reduced qwen must have self-attention layers"
    all_qk = harvest.harvest_all_layers(model, params, batches[0],
                                        layers=layers)
    for l in layers:
        qh, kh = harvest_qk(model, params, batches[0], l)
        np.testing.assert_array_equal(np.asarray(all_qk[l][0]),
                                      np.asarray(qh))
        np.testing.assert_array_equal(np.asarray(all_qk[l][1]),
                                      np.asarray(kh))


# ---------------------------------------------------------------------------
# training quality (ISSUE acceptance: trained > seed, MLP >= linear)
# ---------------------------------------------------------------------------
def test_trained_linear_recall_beats_seed(trained_small):
    _, _, m_lin, _, _ = trained_small
    for m in m_lin:
        assert m.recall_trained > m.recall_seed, \
            f"layer {m.layer}: trained {m.recall_trained:.4f} <= " \
            f"seed {m.recall_seed:.4f}"


def test_mlp_recall_at_least_linear(trained_small):
    _, _, m_lin, _, m_mlp = trained_small
    for a, b in zip(m_lin, m_mlp):
        assert b.recall_trained >= a.recall_trained - 1e-6, \
            f"layer {a.layer}: mlp {b.recall_trained:.4f} < " \
            f"linear {a.recall_trained:.4f}"


def test_trained_weights_installed(small, trained_small):
    cfg, model, params, _ = small
    p_lin, tr_lin, _, _, _ = trained_small
    for l, w in tr_lin.items():
        got = T.layer_hash_weights(model, p_lin, l)
        assert hwt.tree_equal(got, w)
        seed_w = T.layer_hash_weights(model, params, l)
        assert not hwt.tree_equal(got, seed_w)


# ---------------------------------------------------------------------------
# encode parity + persistence (satellite c)
# ---------------------------------------------------------------------------
def _encode_parity(w_head, d):
    x = jax.random.normal(jax.random.PRNGKey(3), (17, d), jnp.float32)
    with ops.use_impl("xla"):
        c_xla = np.asarray(ops.hash_encode(x, w_head))
    with ops.use_impl("pallas"):      # interpret mode on CPU
        c_pal = np.asarray(ops.hash_encode(x, w_head))
    np.testing.assert_array_equal(c_xla, c_pal)


def test_trained_codes_identical_xla_vs_pallas(trained_small, small):
    cfg, model, _, _ = small
    _, tr_lin, _, tr_mlp, _ = trained_small
    l = next(iter(tr_lin))
    d = hwt.head0(tr_lin[l]).shape[0]
    _encode_parity(hwt.head0(tr_lin[l]), d)
    _encode_parity(hwt.head0(tr_mlp[l]), d)


def test_trained_weights_checkpoint_roundtrip(tmp_path, trained_small):
    _, tr_lin, _, tr_mlp, _ = trained_small
    l = next(iter(tr_lin))
    state = {"lin": tr_lin[l], "mlp": tr_mlp[l]}
    ck = Checkpointer(str(tmp_path))
    ck.save(5, state, blocking=True)
    like = jax.tree.map(jnp.zeros_like, state)
    restored = ck.restore(5, like)
    assert hwt.tree_equal(restored["lin"], tr_lin[l])
    assert hwt.tree_equal(restored["mlp"], tr_mlp[l])
    # restored weights hash identically through the real encode path
    d = hwt.head0(tr_mlp[l])["w1"].shape[0]
    x = jax.random.normal(jax.random.PRNGKey(4), (9, d), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.hash_encode(x, hwt.head0(tr_mlp[l]))),
        np.asarray(ops.hash_encode(x, hwt.head0(restored["mlp"]))))


# ---------------------------------------------------------------------------
# calibration (ISSUE acceptance: lower mean budget at >= mean recall)
# ---------------------------------------------------------------------------
def test_calibrated_table_is_valid_schema(calibrated):
    cfg, _, _, _, _, _, table, _ = calibrated
    parsed = budgets.parse_budget_table(table)
    assert parsed.n_layers == cfg.n_layers
    assert set(parsed.layers()) <= set(range(cfg.n_layers))
    # dense layers (indices < hcfg.dense_layers) are never emitted
    assert min(parsed.layers()) >= cfg.hata.dense_layers


def test_calibrated_budgets_lower_at_same_recall(calibrated):
    """The tentpole quality claim, re-derived from the raw curves: the
    emitted per-layer budgets sum strictly below all-layers-at-global-k
    while the summed recall stays >= the global-k baseline."""
    cfg, model, params2, batches, trained, _, table, baseline = calibrated
    global_k = baseline["global_budget"]
    chosen = {e["layer"]: e["budget_min"] for e in table["layers"]}
    assert baseline["mean_budget"] < global_k
    # independent re-measurement (not trusting the calibrator's cache)
    ladder = sorted(set(chosen.values()) | {global_k})
    curves = T.recall_vs_budget(model, params2, batches[-1], ladder,
                                layers=sorted(chosen), weights=trained)
    rec_chosen = sum(curves[l]["mean"][ladder.index(chosen[l])]
                     for l in chosen)
    rec_global = sum(curves[l]["mean"][ladder.index(global_k)]
                     for l in chosen)
    assert sum(chosen.values()) < len(chosen) * global_k
    assert rec_chosen >= rec_global - 1e-9


def test_calibrated_recall_beats_seed_at_global(calibrated):
    _, _, _, _, _, metrics, _, _ = calibrated
    mean_tr = float(np.mean([m.recall_trained for m in metrics]))
    mean_seed = float(np.mean([m.recall_seed for m in metrics]))
    assert mean_tr > mean_seed
