"""Speculative decoding (DESIGN.md §9): draft→verify rounds on both
serving engines.

The subsystem's contract is EXACTNESS, not luck: whatever the draft
proposes, the committed tokens are bit-identical to non-speculative
serving — greedy and sampled, dense slab and paged pool, sync and
async ticks, through forced preemption mid-speculation. Speedup comes
only from acceptance; correctness never depends on it (the
``ConstantDraft`` adversary is the proof). On top of the parity matrix:
the per-request acceptance telemetry invariant, the progress-based
livelock guard (an all-rejected round IS progress; a truly stuck
engine trips fast), and the measured donation-overlap probe that
replaced the backend-name special case.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Model
from repro.serving import (BudgetDraft, ConstantDraft, LayerSubsetDraft,
                           PagedServingEngine, Request, ServingEngine,
                           SpeculationController)
from repro.serving.base import EngineBase
from repro.serving.plane import donation_overlaps


def _setup(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    if cfg.moe:
        # dropless capacity: chunked verify and per-step decode group
        # expert routing differently, identical only when nothing drops
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)
            / cfg.moe.top_k))
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qwen():
    return _setup("qwen1.5-0.5b")


@pytest.fixture(scope="module")
def deepseek():
    return _setup("deepseek-v2-lite-16b")


def _reqs(cfg, seed, n=4, *, new_tokens=9, id0=7000):
    # explicit ids pin the per-request RNG streams, so a sampled
    # baseline run and a sampled speculative run draw identically
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(
                        0, cfg.vocab_size, (8 + i,)).astype(np.int32),
                    max_new_tokens=new_tokens + i, id=id0 + i)
            for i in range(n)]


def _outputs(done):
    return {r.id: (list(r.output), r.truncated) for r in done}


DRAFTS = {
    "budget": BudgetDraft(budget=4),
    "layers": LayerSubsetDraft(n_layers=1),
    "const": ConstantDraft(token=7),
}


def _dense(model, params, spec, **kw):
    return ServingEngine(model, params, max_batch=3, max_len=48,
                         speculate=spec, **kw)


def _paged(model, params, spec, **kw):
    kw.setdefault("num_pages", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_len_pages", 6)
    return PagedServingEngine(model, params, max_batch=3,
                              speculate=spec, **kw)


def _offload(model, params, spec, **kw):
    return _paged(model, params, spec, offload=True, **kw)


ENGINES = {"dense": _dense, "paged": _paged, "offload": _offload}


# ===========================================================================
# 1. greedy parity matrix: spec ≡ non-spec, bit-exact
# ===========================================================================
@pytest.mark.parametrize("engine,draft", [
    ("dense", "budget"), ("dense", "const"),
    ("paged", "budget"), ("paged", "layers"), ("paged", "const"),
    ("offload", "budget"),
])
def test_spec_greedy_bit_exact(qwen, engine, draft):
    cfg, model, params = qwen
    mk = ENGINES[engine]
    spec = SpeculationController(depth=3, draft=DRAFTS[draft])
    ref = mk(model, params, None).run(_reqs(cfg, 11))
    got = mk(model, params, spec).run(_reqs(cfg, 11))
    assert _outputs(got) == _outputs(ref)


@pytest.mark.parametrize("engine", ["dense", "paged"])
def test_spec_greedy_bit_exact_mla_moe(deepseek, engine):
    """MLA latent top-k + dropless MoE through the verify chunk."""
    cfg, model, params = deepseek
    mk = ENGINES[engine]
    spec = SpeculationController(depth=2, draft=BudgetDraft(budget=4))
    ref = mk(model, params, None).run(_reqs(cfg, 12, new_tokens=7))
    got = mk(model, params, spec).run(_reqs(cfg, 12, new_tokens=7))
    assert _outputs(got) == _outputs(ref)


@pytest.mark.parametrize("engine", ["dense", "paged"])
def test_spec_async_matches_sync(qwen, engine):
    cfg, model, params = qwen
    mk = ENGINES[engine]
    spec = SpeculationController(depth=3, draft=BudgetDraft(budget=4))
    ref = mk(model, params, spec).run(_reqs(cfg, 13))
    got = mk(model, params, spec,
             async_waves=True).run(_reqs(cfg, 13))
    assert _outputs(got) == _outputs(ref)


def test_spec_preemption_mid_speculation(qwen):
    """A pool too small for the working set forces eviction while
    rounds are in flight; the preempted request replays and still
    matches the roomy-pool engine bit-exactly."""
    cfg, model, params = qwen
    spec = SpeculationController(depth=3, draft=BudgetDraft(budget=4))

    def run(num_pages):
        eng = _paged(model, params, spec, num_pages=num_pages,
                     page_size=4, max_len_pages=12)
        return eng, _outputs(eng.run(_reqs(cfg, 14, new_tokens=12)))

    tight_eng, tight = run(num_pages=10)
    roomy_eng, roomy = run(num_pages=64)
    assert tight_eng.stats["preemptions"] >= 1
    assert roomy_eng.stats["preemptions"] == 0
    assert tight == roomy


def test_spec_sampled_bit_exact(qwen):
    """Categorical sampling: the verify wave derives each position's
    pick from the same (id, step) stream the plain wave would, so
    sampled speculative serving is bit-identical too."""
    cfg, model, params = qwen
    spec = SpeculationController(depth=3, draft=BudgetDraft(budget=4))
    kw = dict(sample="categorical", seed=7)
    ref = _dense(model, params, None, **kw).run(_reqs(cfg, 15))
    got = _dense(model, params, spec, **kw).run(_reqs(cfg, 15))
    assert _outputs(got) == _outputs(ref)
    ref = _paged(model, params, None, **kw).run(_reqs(cfg, 15))
    got = _paged(model, params, spec, **kw).run(_reqs(cfg, 15))
    assert _outputs(got) == _outputs(ref)


# ===========================================================================
# 2. telemetry: acceptance counters account for every token
# ===========================================================================
def test_spec_telemetry_invariants(qwen):
    cfg, model, params = qwen
    depth = 3
    spec = SpeculationController(depth=depth,
                                 draft=BudgetDraft(budget=4))
    eng = _paged(model, params, spec)
    done = eng.run(_reqs(cfg, 16))
    for r in done:
        assert not r.truncated
        # every output token except the admission-prefill pick came
        # from a speculative round
        assert len(r.output) == r.stats["spec_accepted"] + 1
        assert r.stats["spec_drafted"] == depth * r.stats["spec_rounds"]
    s = eng.stats
    assert s["spec_accepted"] == sum(
        r.stats["spec_accepted"] for r in done)
    # hist counts (slot, round) pairs by committed tokens; each commits
    # at least the verify pick and at most depth + 1
    assert len(s["spec_acc_hist"]) == depth + 1
    slot_rounds = sum(r.stats["spec_rounds"] for r in done)
    assert sum(s["spec_acc_hist"]) == slot_rounds
    assert s["spec_accepted"] <= sum(
        (i + 1) * c for i, c in enumerate(s["spec_acc_hist"]))


def test_adversarial_draft_all_rejected_still_progresses(qwen):
    """A draft that always disagrees with the target commits exactly
    the verify pick each round: one token per round is progress, the
    livelock guard stays quiet, and outputs are still exact. (The
    guard counts counter movement, not acceptance — this is the
    regression for the all-rejected speculative wave.)"""
    cfg, model, params = qwen
    spec = SpeculationController(depth=3, draft=ConstantDraft(token=3))
    ref = _paged(model, params, None).run(_reqs(cfg, 17))
    eng = _paged(model, params, spec)
    got = eng.run(_reqs(cfg, 17))
    assert _outputs(got) == _outputs(ref)
    hist = eng.stats["spec_acc_hist"]
    # the constant token essentially never matches a real argmax:
    # (almost) every round lands in the acc=1 bucket
    assert hist[0] > 0
    assert hist[0] >= sum(hist) - 2


def test_livelock_guard_trips_on_stuck_engine(qwen):
    """An engine whose ticks move no counter trips the 1000-idle-tick
    guard instead of spinning forever."""
    _, model, params = qwen

    class Stuck(EngineBase):
        def _admit(self):
            pass

        def _advance(self):
            pass

    eng = Stuck(model, params, max_batch=1)
    with pytest.raises(AssertionError, match="livelock"):
        eng.run([Request(prompt=np.zeros(4, np.int32),
                         max_new_tokens=2, id=7999)])


# ===========================================================================
# 3. donation probe: measured, cached, overridable
# ===========================================================================
def test_donation_probe_measures_and_caches():
    import repro.serving.plane as plane_mod
    saved = plane_mod._DONATION_OVERLAPS
    try:
        plane_mod._DONATION_OVERLAPS = None
        first = donation_overlaps()
        assert isinstance(first, bool)
        assert plane_mod._DONATION_OVERLAPS is first  # cached verdict
        assert donation_overlaps() is first
        assert donation_overlaps(force=True) is True
        assert donation_overlaps() is True            # force pins it
        assert donation_overlaps(force=False) is False
    finally:
        plane_mod._DONATION_OVERLAPS = saved
