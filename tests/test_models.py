"""Per-arch smoke tests (deliverable f) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_reduced
from repro.models import Model

KEY = jax.random.PRNGKey(0)


def _exact_cfg(arch, **kw):
    """f32 + dropless-MoE so decode == prefill bit-for-bit."""
    cfg = get_reduced(arch, **kw)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)
            / cfg.moe.top_k))
    return cfg


def _batch(cfg, B, S, with_labels=True):
    nb = cfg.audio.n_codebooks if cfg.family == "audio" else 0
    shape = (B, S, nb) if nb else (B, S)
    toks = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.vlm.n_image_tokens, cfg.vlm.vision_dim))
    return batch


# ---------------------------------------------------------------------------
# smoke: one forward/train step on CPU, output shapes + no NaNs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, 2, 32)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_prefill_decode_shapes(arch):
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(KEY)
    B, S, max_len = 2, 16, 48
    batch = _batch(cfg, B, S)
    caches = model.init_caches(B, max_len)
    logits, caches = model.prefill(params, batch, caches, jnp.int32(0))
    nb = cfg.audio.n_codebooks if cfg.family == "audio" else 0
    want = (B, nb, cfg.vocab_size) if nb else (B, cfg.vocab_size)
    assert logits.shape == want
    assert not bool(jnp.isnan(logits).any()), arch
    tok = (jnp.zeros((B, nb), jnp.int32) if nb
           else jnp.zeros((B,), jnp.int32))
    logits2, caches = model.decode_step(params, tok, caches,
                                        jnp.int32(S + cfg.meta_tokens))
    assert logits2.shape == want
    assert not bool(jnp.isnan(logits2).any()), arch


# ---------------------------------------------------------------------------
# decode == prefill (dense path, exact configs)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_prefill_dense(arch):
    cfg = _exact_cfg(arch)
    cfg = dataclasses.replace(
        cfg, hata=dataclasses.replace(cfg.hata, enabled=False))
    model = Model(cfg)
    params = model.init(KEY)
    B, S, max_len = 2, 24, 64
    batch = _batch(cfg, B, S + 1)
    short = dict(batch, tokens=batch["tokens"][:, :S])
    caches = model.init_caches(B, max_len)
    _, caches = model.prefill(params, short, caches, jnp.int32(0))
    got, _ = model.decode_step(params, batch["tokens"][:, S], caches,
                               jnp.int32(S + cfg.meta_tokens))
    caches2 = model.init_caches(B, max_len)
    want, _ = model.prefill(params, batch, caches2, jnp.int32(0))
    rel = float(jnp.abs(got - want).max()) \
        / (float(jnp.abs(want).max()) + 1e-9)
    assert rel < 1e-4, (arch, rel)


# ---------------------------------------------------------------------------
# list layout == stacked layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3-405b", "deepseek-v2-lite-16b",
                                  "hymba-1.5b", "mamba2-130m",
                                  "llama-3.2-vision-90b"])
def test_list_layout_matches_stacked(arch):
    cfg = _exact_cfg(arch)
    model = Model(cfg)
    params = model.init(KEY)
    B, S, max_len = 2, 20, 48
    batch = _batch(cfg, B, S + 2)
    short = dict(batch, tokens=batch["tokens"][:, :S])
    outs = {}
    for layout in ("stacked", "list"):
        caches = model.init_caches(B, max_len, layout=layout)
        lg, caches = model.prefill(params, short, caches, jnp.int32(0))
        seq = [lg]
        for i in range(2):
            lg, caches = model.decode_step(
                params, batch["tokens"][:, S + i], caches,
                jnp.int32(S + i + cfg.meta_tokens))
            seq.append(lg)
        outs[layout] = seq
    for a, b in zip(outs["stacked"], outs["list"]):
        err = float(jnp.abs(a - b).max())
        assert err < 2e-4, (arch, err)


# ---------------------------------------------------------------------------
# per-slot (vector) positions == aligned scalar positions
# ---------------------------------------------------------------------------
def test_vector_pos_decode_matches_scalar():
    cfg = _exact_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(KEY)
    B, S, max_len = 3, 16, 48
    batch = _batch(cfg, B, S)
    caches = model.init_caches(B, max_len, layout="list")
    _, caches = model.prefill(params, batch, caches, jnp.int32(0))
    tok = jnp.zeros((B,), jnp.int32)
    got_s, _ = model.decode_step(params, tok, caches, jnp.int32(S))
    got_v, _ = model.decode_step(params, tok, caches,
                                 jnp.full((B,), S, jnp.int32))
    assert float(jnp.abs(got_s - got_v).max()) < 1e-4


# ---------------------------------------------------------------------------
# param count model vs actual
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_analytic_close(arch):
    """Analytic layer param count (the 6ND roofline input) vs actual.
    Embeddings excluded: the reduced configs pad tiny vocabs to the
    shardable multiple, which swamps the comparison (full configs pad
    by <2%)."""
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(KEY)

    def is_embed(pstr):
        return ("hash" in pstr or "embed" in pstr or "lm_head" in pstr
                or "meta" in pstr)

    actual = sum(int(np.prod(l.shape)) for p, l in
                 jax.tree_util.tree_flatten_with_path(params)[0]
                 if not is_embed("/".join(str(k) for k in p)))
    v, d = cfg.vocab_size, cfg.d_model
    claimed = cfg.param_count() - v * d
    if not cfg.tie_embeddings:
        claimed -= v * d
    if cfg.family == "audio":
        claimed = cfg.param_count() - 2 * cfg.audio.n_codebooks * v * d
    if cfg.vlm is not None:
        claimed -= cfg.vlm.vision_dim * d
    assert abs(actual - claimed) / actual < 0.25, (arch, actual, claimed)
