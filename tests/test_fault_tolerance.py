"""Fault tolerance: watchdog, heartbeats, restart supervision."""
import time

import pytest

from repro.distributed.fault_tolerance import (Heartbeat, StepWatchdog,
                                               run_with_restarts)


def test_watchdog_flags_straggler():
    wd = StepWatchdog(window=20, straggler_factor=2.0)
    for i in range(15):
        wd.step_start()
        wd.durations.append(0.01)      # simulate fast steps
    wd.step_start()
    time.sleep(0.05)
    report = wd.step_end(15)
    assert report is not None and report["kind"] == "straggler"


def test_watchdog_quiet_on_uniform_steps():
    wd = StepWatchdog(window=20)
    # inject uniform durations directly — wall-clock jitter under a
    # loaded CI box must not flake this test
    wd.durations = [0.1] * 14
    wd._t0 = __import__("time").monotonic() - 0.1
    r = wd.step_end(14)
    assert r is None and wd.flagged == []


def test_heartbeat_detects_dead_peer(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0, stale_after_s=0.2)
    hb1 = Heartbeat(str(tmp_path), 1, stale_after_s=0.2)
    hb0.beat(1)
    hb1.beat(1)
    assert hb0.dead_peers() == []
    time.sleep(0.3)
    hb0.beat(2)                        # host 0 alive, host 1 silent
    assert hb0.dead_peers() == [1]


def test_run_with_restarts_recovers():
    calls = {"n": 0}

    def make_state():
        return {"ckpt": calls["n"]}

    def run(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node failure")
        state["done"] = True
        return state

    out = run_with_restarts(make_state, run, max_restarts=5)
    assert out["done"] and out["restarts"] == 2


def test_run_with_restarts_gives_up():
    def run(state):
        raise RuntimeError("persistent failure")
    with pytest.raises(RuntimeError):
        run_with_restarts(dict, run, max_restarts=2)
