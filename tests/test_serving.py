"""Serving engine: continuous batching must equal offline decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Model
from repro.serving import Request, ServingEngine


def _setup(arch="qwen1.5-0.5b"):
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)
            / cfg.moe.top_k))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _offline(model, params, prompt, n_new, max_len=64):
    caches = model.init_caches(1, max_len, layout="list")
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, caches,
        jnp.int32(0))
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt) + model.cfg.meta_tokens
    for _ in range(n_new - 1):
        logits, caches = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), caches,
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "hymba-1.5b"])
def test_continuous_batching_matches_offline(arch):
    cfg, model, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(6, 16)).astype(np.int32)
               for _ in range(5)]
    eng = ServingEngine(model, params, max_batch=2, max_len=64)
    done = eng.run([Request(prompt=p, max_new_tokens=6)
                    for p in prompts])
    assert len(done) == 5
    for r in done:
        assert r.output == _offline(model, params, r.prompt, 6), r.id


def test_engine_more_requests_than_slots_queues():
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8,
                                        dtype=np.int32),
                    max_new_tokens=4) for _ in range(7)]
    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    done = eng.run(reqs)
    assert len(done) == 7
    assert eng.stats["prefills"] == 7
    assert all(len(r.output) == 4 for r in done)


def test_engine_latency_bookkeeping():
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    req = Request(prompt=rng.integers(0, cfg.vocab_size, 8,
                                      dtype=np.int32),
                  max_new_tokens=3)
    eng = ServingEngine(model, params, max_batch=1, max_len=32)
    done = eng.run([req])
    r = done[0]
    assert r.t_first_token is not None and r.t_done is not None
    assert r.t_done >= r.t_first_token >= r.t_submit
