"""Differential harness for the batched fused decode pipeline.

Three-way parity, seeded, across GQA group sizes, ragged per-row
depths and window set/unset:

    hata_decode_batched (one dispatch, per-row pos vector)
        ≡ looped hata_decode (B=1 slices, scalar pos)   [bit-exact]
        ≡ dense decode attention when cache_len <= k    [numerical]

plus the fused Pallas kernel (interpret mode) against the XLA
reference, including the bit-exactness of its *in-kernel* validity
masking, and property tests for the selection semantics the pipeline
rests on (top-k tie-breaking on integer hash scores, recall == 1.0
=> identical attention).

The MLA section applies the same treatment to the latent-stream decode:
the batched latent pipeline (batched Hamming kernel over the shared
code stream + split-latent paged gather kernel) against the inline-jnp
path it replaced — integer scores and selection bit-exact, outputs
numerically tight — batched ≡ looped bit-exact, and ≡ dense latent
attention whenever the budget covers the cache. The stats-emitting
kernel variant is checked against its oracle under arbitrary
(non-prefix) selection masks, the two_stage SP contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from hypothesis_compat import given, settings, st
from repro.configs.base import HataConfig
from repro.core import kvcache, topk
from repro.core.hash_attention import (clamped_budget, hata_decode,
                                       hata_decode_batched, mask_scores)
from repro.kernels import ops, ref
from repro.kernels.flash_decode import (
    flash_decode_gathered_batched, flash_decode_gathered_stats_batched,
    mla_decode_gathered_batched)
from repro.kernels.hamming_score import (hamming_score_batched,
                                         hamming_score_latent)

RNG = np.random.default_rng(7)
HCFG = HataConfig(rbit=64, budget_min=16, budget_max=32,
                  budget_frac=0.5)


def _setup(b, h_kv, g, d=32, s=64, seed=0):
    """Random filled cache with *consistent* key codes + a decode step."""
    rng = np.random.default_rng(seed)
    h = h_kv * g
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=HCFG.rbit,
                                  dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, HCFG.rbit)),
                    jnp.float32) / np.sqrt(d)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32))
    cache = dataclasses.replace(
        cache, codes=ops.hash_encode_heads(cache.k, w))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    # ragged per-row depths, incl. one row at the cache edge
    pos = rng.integers(s // 4, s - 1, b)
    pos[-1] = s - 1
    return cache, w, q, k1, v1, jnp.asarray(pos, jnp.int32)


def _loop_rows(cache, w, q, k1, v1, pos, hcfg, window, fused):
    outs, idxs = [], []
    for i in range(q.shape[0]):
        ci = kvcache.LayerKVCache(k=cache.k[i:i + 1], v=cache.v[i:i + 1],
                                  codes=cache.codes[i:i + 1])
        ri = hata_decode(q[i:i + 1], k1[i:i + 1], v1[i:i + 1], w, ci,
                         hcfg=hcfg, pos=jnp.int32(int(pos[i])),
                         window=window, fused_gather=fused)
        outs.append(np.asarray(ri.out)[0])
        idxs.append(np.asarray(ri.idx)[0])
    return np.stack(outs), np.stack(idxs)


# ---------------------------------------------------------------------------
# batched == looped, bit-exact, both impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g", [1, 4, 8])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("impl,fused", [("xla", False), ("pallas", True)])
def test_batched_equals_looped(g, window, impl, fused):
    cache, w, q, k1, v1, pos = _setup(b=3, h_kv=2, g=g, seed=g)
    with ops.use_impl(impl):
        res = hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                  pos=pos, window=window,
                                  fused_gather=fused)
        out_l, idx_l = _loop_rows(cache, w, q, k1, v1, pos, HCFG,
                                  window, fused)
    assert_array_equal(np.asarray(res.idx), idx_l)
    assert_array_equal(np.asarray(res.out), out_l)


# ---------------------------------------------------------------------------
# batched == dense when the budget covers the cache
# ---------------------------------------------------------------------------
def _dense_ref(q, cache, n_valid, window):
    """Dense masked decode reference (per-row validity + SWA window)."""
    b, h, d = q.shape
    h_kv = cache.k.shape[2]
    s = cache.max_len
    pos = np.arange(s)
    nv = np.asarray(n_valid).reshape(-1, 1)
    valid = pos[None] < nv
    if window is not None:
        valid = valid & (pos[None] > nv - 1 - window)
    qf = np.asarray(q).reshape(b, h_kv, h // h_kv, d) * (d ** -0.5)
    logits = np.einsum("bhgd,bshd->bhgs", qf.astype(np.float64),
                       np.asarray(cache.k, np.float64))
    logits = np.where(valid[:, None, None], logits, -np.inf)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bhgs,bshd->bhgd", p,
                    np.asarray(cache.v, np.float64))
    return out.reshape(b, h, d)


@pytest.mark.parametrize("g", [1, 4, 8])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("impl,fused", [("xla", False), ("pallas", True)])
def test_batched_equals_dense_when_budget_covers_cache(g, window, impl,
                                                       fused):
    cache, w, q, k1, v1, pos = _setup(b=3, h_kv=2, g=g, seed=10 + g)
    s = cache.max_len
    hcfg = dataclasses.replace(HCFG, budget_min=s, budget_max=s,
                               budget_frac=1.0)
    with ops.use_impl(impl):
        res = hata_decode_batched(q, k1, v1, w, cache, hcfg=hcfg,
                                  pos=pos, window=window,
                                  fused_gather=fused)
    want = _dense_ref(q, res.cache, np.asarray(pos) + 1, window)
    assert_allclose(np.asarray(res.out), want, atol=1e-5)


# ---------------------------------------------------------------------------
# fused kernel vs XLA reference — including in-kernel masking bit-exactness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g", [1, 4, 8])
def test_fused_kernel_matches_xla_reference(g):
    cache, w, q, k1, v1, pos = _setup(b=3, h_kv=2, g=g, seed=20 + g)
    with ops.use_impl("pallas"):
        rp = hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                 pos=pos, fused_gather=True)
    with ops.use_impl("xla"):
        rx = hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                 pos=pos, fused_gather=False)
    # identical integer scores -> identical selection
    assert_array_equal(np.asarray(rp.scores), np.asarray(rx.scores))
    assert_array_equal(np.asarray(rp.idx), np.asarray(rx.idx))
    assert_allclose(np.asarray(rp.out), np.asarray(rx.out), atol=1e-5)


@pytest.mark.parametrize("block_k", [7, 8, 128])
def test_fused_kernel_masking_is_bit_exact(block_k):
    """Invalid selections must have exactly zero influence: repointing
    every invalid idx entry at different (arbitrary) cache rows cannot
    change a single output bit."""
    rng = np.random.default_rng(3)
    b, s, h_kv, g, d, k = 2, 48, 2, 4, 32, 24
    q = jnp.asarray(rng.standard_normal((b, h_kv, g, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    idx = np.asarray(rng.integers(0, s, (b, h_kv, k)), np.int32)
    nv = rng.integers(1, k + 1, (b, h_kv))
    invalid = np.arange(k)[None, None, :] >= nv[..., None]
    idx2 = np.where(invalid, rng.integers(0, s, idx.shape), idx)
    assert (idx2 != idx).any()
    out = flash_decode_gathered_batched(q, kc, vc, jnp.asarray(idx),
                                        jnp.asarray(nv, jnp.int32),
                                        block_k=block_k, interpret=True)
    out2 = flash_decode_gathered_batched(q, kc, vc, jnp.asarray(idx2),
                                         jnp.asarray(nv, jnp.int32),
                                         block_k=block_k, interpret=True)
    assert_array_equal(np.asarray(out), np.asarray(out2))
    # and the masked fused output matches the -inf-masked XLA oracle
    sel_valid = jnp.arange(k)[None, None, :] < jnp.asarray(nv)[..., None]
    want = ref.masked_gather_decode_ref(
        q.reshape(b, h_kv * g, d), kc, vc, jnp.asarray(idx), sel_valid)
    assert_allclose(np.asarray(out).reshape(b, h_kv * g, d),
                    np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# stats-emitting gather kernel (sequence-parallel variant)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block_k", [7, 128])
def test_gathered_stats_kernel_matches_ref(block_k):
    """The SP variant must agree with its oracle under an *arbitrary*
    per-selection mask (two_stage ownership filtering is not a prefix),
    including rows whose whole selection is masked (m=-1e30, l=0)."""
    rng = np.random.default_rng(11)
    b, s, h_kv, g, d, k = 2, 40, 2, 4, 32, 24
    q = jnp.asarray(rng.standard_normal((b, h_kv, g, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, s, (b, h_kv, k)), jnp.int32)
    mask = np.asarray(rng.integers(0, 2, (b, h_kv, k)), bool)
    mask[0, 0] = False                      # a shard that owns nothing
    m, l, o = flash_decode_gathered_stats_batched(
        q, kc, vc, idx, None, jnp.asarray(mask), block_k=block_k,
        interpret=True)
    mr, lr, orf = ref.gather_decode_stats_ref(
        q.reshape(b, h_kv * g, d), kc, vc, idx, jnp.asarray(mask))
    assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5)
    assert_allclose(np.asarray(l), np.asarray(lr), atol=1e-5)
    assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-5)
    # nothing-to-contribute convention for the psum merge
    assert_array_equal(np.asarray(m[0, 0]), np.full(g, -1e30, np.float32))
    assert_array_equal(np.asarray(l[0, 0]), np.zeros(g))
    assert_array_equal(np.asarray(o[0, 0]), np.zeros((g, d)))


def test_stats_merge_equals_normalized_kernel():
    """Splitting one selection across 'shards' and psum-merging the
    stats kernel's partials must reproduce the normalized kernel."""
    rng = np.random.default_rng(12)
    b, s, h_kv, g, d, k, n_shards = 2, 48, 2, 2, 16, 16, 4
    q = jnp.asarray(rng.standard_normal((b, h_kv, g, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, s, (b, h_kv, k)), jnp.int32)
    whole = flash_decode_gathered_batched(q, kc, vc, idx, interpret=True)
    owner = rng.integers(0, n_shards, (b, h_kv, k))
    stats = []
    for p_ in range(n_shards):
        mask = jnp.asarray(owner == p_)
        stats.append(flash_decode_gathered_stats_batched(
            q, kc, vc, idx, None, mask, interpret=True))
    m, l, o = (jnp.stack(x) for x in zip(*stats))
    merged = ref.merge_softmax_stats_ref((m, l, o))
    assert_allclose(np.asarray(merged), np.asarray(whole), atol=1e-5)


# ---------------------------------------------------------------------------
# MLA latent pipeline: batched kernels vs the inline-jnp path they replaced
# ---------------------------------------------------------------------------
MLA_DIMS = dict(h=6, r=48, rd=16, rbit=64, qk_dim=40)


def _mla_setup(b, s, seed=0, dims=MLA_DIMS):
    rng = np.random.default_rng(seed)
    h, r, rd = dims["h"], dims["r"], dims["rd"]
    w = jnp.asarray(rng.standard_normal((1, r + rd, dims["rbit"])),
                    jnp.float32) / np.sqrt(r + rd)
    ckv = jnp.asarray(rng.standard_normal((b, s, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((b, s, rd)), jnp.float32)
    latent = jnp.concatenate([ckv, krope], axis=-1)
    codes = ops.hash_encode(latent, w[0])            # (B, S, W)
    q_lat = jnp.asarray(rng.standard_normal((b, h, r + rd)), jnp.float32)
    pos = rng.integers(s // 4, s - 1, b)
    pos[-1] = s - 1
    return w, ckv, krope, codes, q_lat, jnp.asarray(pos, jnp.int32)


def _inline_mla_path(q_lat, w, ckv, krope, codes, n_valid, budget, *,
                     rbit, lora_rank, scale):
    """The pre-refactor inline-jnp MLA HATA decode, kept verbatim as the
    differential reference: (B, S) popcount scores, XLA row gathers,
    concatenated-latent softmax."""
    b, h, _ = q_lat.shape
    s = ckv.shape[1]
    q_codes = ops.hash_encode(q_lat, w[0])           # (B, H, W)
    x_ = jax.lax.population_count(jnp.bitwise_xor(
        q_codes[:, :, None, :], codes[:, None, :, :]))
    scores = h * rbit - jnp.sum(x_.astype(jnp.int32), axis=(1, 3))
    nv = jnp.reshape(n_valid, (-1, 1))
    scores = jnp.where(jnp.arange(s)[None] < nv, scores, -1)
    top_scores, idx = jax.lax.top_k(scores, budget)  # (B, k)
    ckv_rows = jnp.take_along_axis(ckv, idx[..., None], axis=1)
    kr_rows = jnp.take_along_axis(krope, idx[..., None], axis=1)
    kv = jnp.concatenate([ckv_rows, kr_rows], axis=-1)
    logits = jnp.einsum("bhr,bkr->bhk", q_lat.astype(kv.dtype), kv,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where((top_scores >= 0)[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", probs.astype(ckv_rows.dtype),
                       ckv_rows, preferred_element_type=jnp.float32)
    return scores, idx, o_lat


def _batched_mla_pipeline(q_lat, w, ckv, krope, codes, n_valid, budget, *,
                          rbit, lora_rank, scale, block_k=None):
    """The refactored pipeline exactly as models/attention.py runs it."""
    q_codes = ops.hash_encode(q_lat, w[0])
    scores = hamming_score_latent(q_codes, codes, rbit=rbit,
                                  interpret=True)
    scores = mask_scores(scores[:, None], n_valid)[:, 0]
    top_scores, idx = jax.lax.top_k(scores, budget)
    nv_sel = jnp.sum((top_scores >= 0).astype(jnp.int32), -1)
    o_lat = mla_decode_gathered_batched(
        q_lat, ckv, krope, idx, nv_sel, lora_rank=lora_rank, scale=scale,
        block_k=block_k, interpret=True)
    return scores, idx, o_lat


@pytest.mark.parametrize("budget", [12, 32])
def test_mla_batched_pipeline_matches_inline_path(budget):
    """Integer scores and the selected rows must be bit-identical to the
    inline path (same popcount math, same lax.top_k tie-breaks); the
    attention output agrees numerically (online vs plain softmax)."""
    b, s = 3, 64
    w, ckv, krope, codes, q_lat, pos = _mla_setup(b, s, seed=31)
    dims = MLA_DIMS
    kw = dict(rbit=dims["rbit"], lora_rank=dims["r"],
              scale=dims["qk_dim"] ** -0.5)
    n_valid = pos + 1
    s_i, i_i, o_i = _inline_mla_path(q_lat, w, ckv, krope, codes,
                                     n_valid, budget, **kw)
    s_b, i_b, o_b = _batched_mla_pipeline(q_lat, w, ckv, krope, codes,
                                          n_valid, budget, **kw)
    assert_array_equal(np.asarray(s_b), np.asarray(s_i))
    assert_array_equal(np.asarray(i_b), np.asarray(i_i))
    assert_allclose(np.asarray(o_b), np.asarray(o_i), atol=1e-5)


@pytest.mark.parametrize("block_k", [5, 128])
def test_mla_batched_equals_looped(block_k):
    """One batched dispatch over ragged per-row depths ≡ running the
    same kernel on B=1 slices — bit-exact (independent grid cells)."""
    b, s, budget = 3, 64, 16
    w, ckv, krope, codes, q_lat, pos = _mla_setup(b, s, seed=32)
    dims = MLA_DIMS
    kw = dict(rbit=dims["rbit"], lora_rank=dims["r"],
              scale=dims["qk_dim"] ** -0.5, block_k=block_k)
    s_b, i_b, o_b = _batched_mla_pipeline(q_lat, w, ckv, krope, codes,
                                          pos + 1, budget, **kw)
    for i in range(b):
        s_1, i_1, o_1 = _batched_mla_pipeline(
            q_lat[i:i + 1], w, ckv[i:i + 1], krope[i:i + 1],
            codes[i:i + 1], pos[i:i + 1] + 1, budget, **kw)
        assert_array_equal(np.asarray(s_b[i]), np.asarray(s_1[0]))
        assert_array_equal(np.asarray(i_b[i]), np.asarray(i_1[0]))
        assert_array_equal(np.asarray(o_b[i]), np.asarray(o_1[0]))


def test_mla_batched_equals_dense_when_budget_covers_cache():
    """budget >= cache fill selects every valid latent row, so the
    pipeline must reproduce dense masked latent attention."""
    b, s = 3, 48
    w, ckv, krope, codes, q_lat, pos = _mla_setup(b, s, seed=33)
    dims = MLA_DIMS
    _, _, o_b = _batched_mla_pipeline(
        q_lat, w, ckv, krope, codes, pos + 1, s, rbit=dims["rbit"],
        lora_rank=dims["r"], scale=dims["qk_dim"] ** -0.5)
    # float64 dense latent reference
    kv = np.concatenate([np.asarray(ckv), np.asarray(krope)], axis=-1)
    logits = np.einsum("bhr,bsr->bhs", np.asarray(q_lat, np.float64),
                       kv.astype(np.float64)) * dims["qk_dim"] ** -0.5
    valid = np.arange(s)[None] < (np.asarray(pos) + 1)[:, None]
    logits = np.where(valid[:, None, :], logits, -np.inf)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = np.einsum("bhs,bsr->bhr", p, np.asarray(ckv, np.float64))
    assert_allclose(np.asarray(o_b), want, atol=1e-5)


def test_mla_stats_kernel_matches_ref_under_arbitrary_mask():
    """The SP stats variant of the latent kernel vs its oracle with a
    two_stage-style ownership mask (non-prefix, one all-masked row)."""
    rng = np.random.default_rng(34)
    b, s, budget = 3, 64, 16
    w, ckv, krope, codes, q_lat, pos = _mla_setup(b, s, seed=34)
    dims = MLA_DIMS
    idx = jnp.asarray(rng.integers(0, s, (b, budget)), jnp.int32)
    mask = np.asarray(rng.integers(0, 2, (b, budget)), bool)
    mask[0] = False
    kw = dict(lora_rank=dims["r"], scale=dims["qk_dim"] ** -0.5)
    m, l, o = mla_decode_gathered_batched(
        q_lat, ckv, krope, idx, None, jnp.asarray(mask),
        return_stats=True, interpret=True, **kw)
    mr, lr, orf = ref.mla_gather_decode_ref(
        q_lat, ckv, krope, idx, jnp.asarray(mask), return_stats=True,
        **kw)
    assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5)
    assert_allclose(np.asarray(l), np.asarray(lr), atol=1e-5)
    assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-5)
    assert_array_equal(np.asarray(m[0]),
                       np.full(dims["h"], -1e30, np.float32))
    assert_array_equal(np.asarray(l[0]), np.zeros(dims["h"]))


def test_batched_hamming_kernel_matches_ref():
    rng = np.random.default_rng(4)
    b, s, h_kv, g, w_words, rbit = 2, 70, 3, 4, 2, 64
    qc = jnp.asarray(rng.integers(0, 2 ** 32, (b, h_kv, g, w_words),
                                  dtype=np.uint32))
    kc = jnp.asarray(rng.integers(0, 2 ** 32, (b, s, h_kv, w_words),
                                  dtype=np.uint32))
    got = hamming_score_batched(qc, kc, rbit=rbit, block_s=32,
                                interpret=True)
    want = ref.hamming_score_batched_ref(qc, kc, rbit)
    assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# selection-semantics properties (hypothesis; self-skip when absent)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 24),
       st.sampled_from([16, 32, 64]))
def test_chunked_topk_bit_identical_to_flat(seed, k, chunk):
    """The pipeline's two-stage on-device top-k must match lax.top_k
    bit-for-bit — values, indices AND tie ordering — on heavily-tied
    integer hash scores (the regime the selection runs in)."""
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.integers(-1, 6, (2, 256)), jnp.int32)
    v1, i1 = jax.lax.top_k(scores, k)
    v2, i2 = topk.chunked_topk(scores, k, chunk=chunk)
    assert_array_equal(np.asarray(v1), np.asarray(v2))
    assert_array_equal(np.asarray(i1), np.asarray(i2))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(1, 24))
def test_topk_tie_breaking_matches_batched_kernel_scores(seed, g, k):
    """The batched kernel's integer scores are bit-identical to the
    oracle's, so lax.top_k (ties -> lowest index) picks the same rows
    on both paths — the invariant batched/looped parity rests on."""
    rng = np.random.default_rng(seed)
    b, s, h_kv, w_words, rbit = 2, 32, 2, 2, 64
    qc = jnp.asarray(rng.integers(0, 2 ** 32, (b, h_kv, g, w_words),
                                  dtype=np.uint32))
    kc = jnp.asarray(rng.integers(0, 2 ** 32, (b, s, h_kv, w_words),
                                  dtype=np.uint32))
    kernel = hamming_score_batched(qc, kc, rbit=rbit, interpret=True)
    oracle = ref.hamming_score_batched_ref(qc, kc, rbit)
    assert_array_equal(np.asarray(kernel), np.asarray(oracle))
    _, ik = topk.topk(kernel, min(k, s))
    _, io = topk.topk(oracle, min(k, s))
    assert_array_equal(np.asarray(ik), np.asarray(io))
    # tie-breaking contract: stable descending sort by (score, -index)
    sc = np.asarray(oracle)
    order = np.argsort(-sc, axis=-1, kind="stable")[..., :min(k, s)]
    assert_array_equal(np.asarray(io), order)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_recall_one_implies_identical_attention(seed):
    """selection_recall == 1.0 means the estimated top-k *set* equals
    the true top-k set, so attending over either selection (rows taken
    in cache order) is bit-identical."""
    rng = np.random.default_rng(seed)
    s, k, h, d = 32, 8, 2, 16
    true = rng.permutation(s).astype(np.float32)
    # same top-k set, different ordering inside and outside the set
    est = true.copy()
    top = np.argsort(-true, kind="stable")[:k]
    est[top] = true[top][::-1]
    rest = np.setdiff1d(np.arange(s), top)
    est[rest] = rng.permutation(est[rest])
    rec = topk.selection_recall(jnp.asarray(est)[None],
                                jnp.asarray(true)[None], k)
    assert float(rec[0]) == 1.0
    idx_t = np.sort(np.argsort(-true, kind="stable")[:k])
    idx_e = np.sort(np.argsort(-est, kind="stable")[:k])
    assert_array_equal(idx_t, idx_e)
    q = jnp.asarray(rng.standard_normal((1, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((1, s, 1, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((1, s, 1, d)), jnp.float32)
    out_t = ref.masked_gather_decode_ref(q, kc, vc,
                                         jnp.asarray(idx_t)[None, None])
    out_e = ref.masked_gather_decode_ref(q, kc, vc,
                                         jnp.asarray(idx_e)[None, None])
    assert_array_equal(np.asarray(out_t), np.asarray(out_e))
